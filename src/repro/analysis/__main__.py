"""``python -m repro.analysis [paths] [--select RL00x,..] [--json-out f]``

The repro-lint CLI. Exit 0 when the tree is clean (suppressions with
reasons included), 1 when any diagnostic survives. Runs on a bare
interpreter — no jax, no third-party imports.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.diagnostics import RULES
from repro.analysis.engine import lint_paths, parse_select

_DEFAULT_PATHS = ["src", "tests", "benchmarks"]


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: AST-enforced invariants (RL001 bitwise-"
                    "stability, RL002 trace-safety, RL003 lock-discipline, "
                    "RL004 key-completeness, RL005 kernel purity)")
    parser.add_argument("paths", nargs="*", default=None,
                        help=f"files/dirs to lint (default: "
                             f"{' '.join(_DEFAULT_PATHS)})")
    parser.add_argument("--select", default=None, metavar="CODES",
                        help="comma-separated rule codes to run "
                             "(default: all; disables stale-suppression "
                             "checking)")
    parser.add_argument("--json-out", default=None, metavar="FILE",
                        help="write a BENCH-schema JSON artifact "
                             "(files/diagnostics/suppressions/rules)")
    parser.add_argument("--explain", action="store_true",
                        help="print the rule registry and exit")
    args = parser.parse_args(argv)

    if args.explain:
        for code in sorted(RULES):
            print(f"{code}  {RULES[code]}")
        return 0

    try:
        select = parse_select(args.select)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    try:
        result = lint_paths(args.paths or _DEFAULT_PATHS, select=select)
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    for diag in result.diagnostics:
        print(diag.render())

    if args.json_out:
        payload = {
            "files": len(result.files),
            "diagnostics": [
                {"path": d.path, "line": d.line, "code": d.code,
                 "message": d.message}
                for d in result.diagnostics],
            "suppressions": result.suppressions,
            "rules": {code: RULES[code] for code in sorted(RULES)},
        }
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")

    n = len(result.diagnostics)
    scanned = len(result.files)
    if n:
        counts = ", ".join(f"{c}×{k}" for c, k in
                           sorted(result.rule_counts.items()))
        print(f"\n{n} finding(s) in {scanned} file(s) [{counts}]; "
              f"{result.suppressions} suppression(s) honored",
              file=sys.stderr)
        return 1
    print(f"clean: {scanned} file(s), {result.suppressions} explained "
          f"suppression(s)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
