"""End-to-end driver: train a ~100M-param LM with the AsySVRG optimizer for
a few hundred steps on synthetic data, with checkpointing enabled; compares
against the plain-SGD baseline (the Hogwild!-equivalent compute).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]

--small shrinks to a CPU-friendly ~1M model (used by CI/smoke).
"""
import argparse


from repro.config import ModelConfig, SVRGConfig, TrainConfig
from repro.data.synthetic_lm import SyntheticLMDataset
from repro.models.factory import build_model
from repro.train.loop import train


def model_cfg(small: bool) -> ModelConfig:
    if small:
        return ModelConfig(
            name="lm-small", family="dense", num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
            vocab_size=512, dtype="float32", param_dtype="float32",
            remat="none", tie_embeddings=True)
    # ~100M params: 12L x 768 (gpt2-small scale), llama-style blocks
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32768, dtype="float32", param_dtype="float32",
        remat="none", tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_cfg(args.small)
    bundle = build_model(cfg)
    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch)

    for opt in ("svrg", "sgd"):
        print(f"\n=== optimizer: {opt} ===")
        tcfg = TrainConfig(
            steps=args.steps, optimizer=opt, learning_rate=0.3,
            warmup_steps=10, schedule="cosine", grad_clip=1.0,
            checkpoint_dir=(args.checkpoint_dir + "_" + opt),
            checkpoint_every=100, log_every=25,
            svrg=SVRGConfig(snapshot_every=50, snapshot_batches=4),
        )
        losses = []
        train(bundle, tcfg, ds.batch_at,
              hooks=lambda s, m: losses.append(m["loss"]))
        print(f"{opt}: first={losses[0]:.4f} last={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
