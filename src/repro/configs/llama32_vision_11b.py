"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

40L total = 32 self + 8 cross-attention layers (hf indices 3,8,...,38),
d_model=4096, 32 heads (kv=8), d_ff=14336, vocab=128256. Vision tower is a
STUB: input pipeline supplies precomputed patch embeddings
[B, 1601, 1280]; a learned projector maps them to d_model. Cross layers
are tanh-gated (gates init 0).
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_image_tokens=1601,
    image_embed_dim=1280,
    norm="rmsnorm",
    activation="silu",
    glu=True,
))
