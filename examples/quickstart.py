"""Quickstart: AsySVRG on the paper's own workload (logistic regression).

Reproduces the core claim in ~30 seconds on CPU: AsySVRG (all three reading
schemes) converges linearly and beats Hogwild! per effective pass. EVERY
scenario here runs in ONE `run_sweep` call on the multi-algorithm sweep
engine (repro.core.sweep): the three AsySVRG schemes, the serial-SVRG
baseline (``algo="svrg"``, the τ=0 degenerate case on the same engine), AND
the Hogwild! baseline (``algo="hogwild"``, γ-decay inside the compiled
scan) — the Hogwild! row carries its own 3× per-row ``epochs`` budget (1
pass/epoch vs AsySVRG's ~3) via the masked-epoch axis, so equal effective
passes no longer need a second call. Adding a scenario is one more
SweepSpec row — no new compiles, no new driver code. On a multi-device
host, pass ``mesh=make_sweep_mesh()`` to shard the rows across devices.

Serving sweeps: re-running grids is as cheap as running them — every
dispatch goes through the persistent compiled-runner cache
(`repro.service.cache`), so a second same-shape sweep compiles nothing —
and the serving tier (`repro.server`) makes the whole thing a deployable
HTTP service: clients submit over the wire and a background flush daemon
coalesces tenants' specs into shared compiled dispatches on a deadline
policy, nobody ever calling flush() (see the "serving sweeps" section
below; examples/serve_sweeps.py is the full multi-tenant demo with
priorities and a time-sliced giant job, examples/sweep_service.py the
in-process + checkpoint-resume one).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (LogisticRegression, SweepSpec, make_grid, run_sweep,
                        svrg_sweep_spec)
from repro.data.libsvm import make_synthetic_libsvm
from repro.server import FlushPolicy, SweepClient, SweepServer
from repro.service import SweepService, cache_stats


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.05)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    print(f"dataset rcv1-like: n={obj.n} p={obj.p}  f*={f_star:.6f}\n")

    # AsySVRG × 3 schemes + serial SVRG + pass-matched Hogwild!, one call:
    # 6 epochs × ~3 passes for the SVRG family, 18 × 1 for Hogwild!
    specs = make_grid(schemes=("consistent", "inconsistent", "unlock"),
                      seeds=(0,), step_sizes=(2.0,), taus=(9,),
                      num_threads=10)
    specs += [svrg_sweep_spec(step_size=2.0)]
    specs += [SweepSpec(algo="hogwild", scheme="unlock", step_size=2.0,
                        num_threads=10, tau=9, epochs=18)]
    res = run_sweep(obj, 6, specs)

    print(f"{'method':28s} {'passes':>7s} {'final gap':>12s}")
    for c, spec in enumerate(res.specs):
        name = {"svrg": "SVRG-serial",
                "hogwild": f"Hogwild!-{spec.scheme}"}.get(
                    spec.algo, f"AsySVRG-{spec.scheme}")
        passes, hist = res.curve(c)
        gap = hist[-1] - f_star
        print(f"{name:28s} {passes[-1]:7.0f} {gap:12.3e}")

    print("\nAsySVRG reaches a much smaller gap at EQUAL effective passes —")
    print("the paper's Figure 1 (right) in one table, from one compile-set.")

    # ---- serving sweeps: the same shapes again, served over HTTP. Two
    # tenants submit to a SweepServer and simply wait: the background
    # flush daemon's 25ms deadline fires once, their 2+1 rows coalesce
    # into ONE 3-row compiled group — the exact shape the 3-scheme grid
    # above already compiled — so the dispatch fetches the cached runner
    # and compiles NOTHING. Results come back over the wire bit-identical
    # to an in-process run_sweep.
    base = cache_stats()
    with SweepServer(SweepService(obj, epochs=6),
                     policy=FlushPolicy(max_rows=24,
                                        max_delay_ms=25)) as server:
        client = SweepClient(server.url)
        rid_a = client.submit(make_grid(schemes=("inconsistent",),
                                        seeds=(1, 2), step_sizes=(2.0,),
                                        taus=(9,), num_threads=10),
                              tenant="team-a")
        rid_b = client.submit(make_grid(schemes=("unlock",), seeds=(3,),
                                        step_sizes=(1.0,), taus=(9,),
                                        num_threads=10), tenant="team-b")

        def best_gap(res):
            return min(res.curve(c)[1][-1] - f_star
                       for c in range(len(res.specs)))

        gap_a = best_gap(client.result(rid_a, timeout=600))
        gap_b = best_gap(client.result(rid_b, timeout=600))
        stats = client.stats()

    s, q = stats["service"], stats["request_latency"]
    print(f"\nserving sweeps over HTTP: 2 tenants, {s['rows_submitted']} "
          f"rows -> {s['flushes']} deadline flush, "
          f"{s['rows_coalesced']} rows coalesced, "
          f"{cache_stats().since(base).compiles} new compile(s), "
          f"request p95 {q['p95_ms']:.0f} ms")
    print(f"  team-a best gap {gap_a:.3e}, team-b best gap {gap_b:.3e}"
          "  (each bit-identical to its own run_sweep)")


if __name__ == "__main__":
    main()
