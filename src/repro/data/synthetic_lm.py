"""Deterministic synthetic LM token pipeline.

Produces packed (tokens, targets, mask) batches from a counter-based hash so
any (step, shard) pair regenerates identical data — restart-safe without
storing a cursor beyond the step number, and shardable across data-parallel
hosts by slicing the global batch. This is the production-pipeline stand-in:
the interface (``batch_at(step)``) matches what a real tokenized-corpus
loader would expose.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """xxhash-style integer mix, vectorized (counter-based RNG)."""
    x = x.astype(np.uint64)
    x = (x ^ (x >> 33)) * np.uint64(0xFF51AFD7ED558CCD)
    x = (x ^ (x >> 33)) * np.uint64(0xC4CEB9FE1A85EC53)
    x = x ^ (x >> 33)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


class SyntheticLMDataset:
    """Counter-based synthetic corpus of ``vocab_size`` tokens.

    Tokens follow a mixture of a hash stream and a deterministic bigram map so
    the LM loss is learnable (non-uniform next-token structure) — useful for
    the end-to-end driver example where loss must visibly decrease.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, shard_index: int = 0, num_shards: int = 1):
        assert global_batch % num_shards == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b, s = self.local_batch, self.seq_len
        row0 = step * self.global_batch + self.shard_index * self.local_batch
        rows = np.arange(row0, row0 + b, dtype=np.uint64)[:, None]
        cols = np.arange(s + 1, dtype=np.uint64)[None, :]
        ctr = rows * np.uint64(1_000_003) + cols + np.uint64(self.seed) * np.uint64(0x9E3779B9)
        stream = _hash_u32(ctr)
        # learnable structure: with prob 3/4 the next token = f(prev token)
        raw = (stream % np.uint32(self.vocab_size)).astype(np.int32)
        toks = raw.copy()
        follow = (stream % np.uint32(4)) != 0
        for j in range(1, s + 1):
            mapped = (toks[:, j - 1] * 7 + 13) % self.vocab_size
            toks[:, j] = np.where(follow[:, j], mapped, raw[:, j])
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((b, s), np.float32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def lm_batch_specs(global_batch: int, seq_len: int,
                   mesh=None, rules=None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for an LM training batch (dry-run path)."""
    from jax.sharding import NamedSharding
    from repro.sharding.rules import batch_pspec

    def mk(shape, dtype):
        sharding = None
        if mesh is not None:
            sharding = NamedSharding(mesh, batch_pspec(mesh))
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)

    return {
        "tokens": mk((global_batch, seq_len), jnp.int32),
        "targets": mk((global_batch, seq_len), jnp.int32),
        "mask": mk((global_batch, seq_len), jnp.float32),
    }
