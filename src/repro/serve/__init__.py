from repro.serve.loop import ServeSession, generate

__all__ = ["ServeSession", "generate"]
