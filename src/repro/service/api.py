"""`SweepService` — the multi-tenant front-end over the coalescing
scheduler and the persistent runner cache.

Usage (the serving loop a production deployment wraps in RPC):

    svc = SweepService(obj, epochs=6)
    rid_a = svc.submit(client_a_specs)          # admit; nothing runs yet
    rid_b = svc.submit(client_b_specs, epochs=12)
    svc.flush()                                 # coalesce + dispatch once
    res_a = svc.result(rid_a)                   # == run_sweep(obj, 6, a)
    print(svc.stats())                          # rows coalesced, hit rate…

`submit` only queues; `flush` coalesces every pending request into shared
compiled groups (repro.service.scheduler) and dispatches them through the
module-level runner cache (repro.service.cache), so a warm service
compiles nothing and fills the sharded row axis across tenants.
``result()`` flushes implicitly if its request is still pending. Each
request's result is bit-identical to a standalone `run_sweep` of its specs.

Long-running sweeps checkpoint through the existing
`repro.checkpoint.Checkpointer`: :meth:`run_job` dispatches a job group by
group, saving partial results atomically after each, and resumes from the
newest valid checkpoint — a preempted job re-runs only its unfinished
groups and the final result is still bit-identical to one `run_sweep`
call. ``max_groups`` bounds one call's work (the graceful-preemption /
time-slicing hook the tests and the example use).
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import Checkpointer
from repro.core.objective import Objective
from repro.core.sweep import (
    SweepResult,
    SweepSpec,
    _active_mesh,
    _assemble_result,
    _dispatch_group,
    _write_row_history,
    group_label,
    plan_sweep,
)
from repro.obs import progress as _progress
from repro.obs.metrics import ServiceHistograms
from repro.obs.trace import tracer as _tracer
from repro.service import cache as _cache
from repro.service.scheduler import (FlushSelector, SweepRequest,
                                     WidthPolicy, coalesce, dispatch)


def _row_loss_series(histories, epochs_per_row):
    """Per-row ``(losses, deltas)`` for live-progress events, each row
    trimmed to its own epoch budget. Host-side numpy over the RETURNED
    histories (never inside jit — RL006), and value-exact: a float32
    history entry round-trips through the Python float unchanged, so a
    watcher can compare streamed losses bit-for-bit against the final
    ``SweepResult``."""
    losses = []
    deltas = []
    for c in range(histories.shape[0]):
        h = histories[c, :int(epochs_per_row[c]) + 1]
        losses.append(tuple(float(v) for v in h))
        deltas.append(tuple(float(v) for v in np.diff(h)))
    return tuple(losses), tuple(deltas)


class ResultEvictedError(KeyError):
    """The request id WAS completed, but its result has been released —
    evicted past the service's ``max_results`` FIFO retention bound or
    explicitly ``discard()``ed. Distinct from the bare KeyError an id that
    never existed raises, so a client of a busy server knows to re-submit
    (or raise ``max_results``) instead of chasing a phantom id."""


@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """Service-lifetime accounting. The cache counters are credited at the
    LOOKUP SITE through a thread-scoped sink (`repro.service.cache
    .scoped_counters`), so they cover exactly this service's own lookups —
    another service flushing concurrently in the same process cannot
    pollute them (regression-tested in tests/test_service.py)."""
    requests_submitted: int
    requests_completed: int
    rows_submitted: int
    rows_coalesced: int          # rows that shared a group across requests
    groups_dispatched: int
    groups_merged: int           # dispatched groups holding >1 request
    flushes: int
    cache_hits: int
    cache_misses: int
    compiles: int
    rows_padded: int = 0         # stable-width pad rows ever dispatched
    rows_diverged: int = 0       # rows the divergence watchdog flagged

    @property
    def cache_hit_rate(self) -> float:
        lookups = self.cache_hits + self.cache_misses
        return self.cache_hits / lookups if lookups else 0.0


class SweepService:
    """Admit many clients' `SweepSpec` rows, run them as shared compiled
    groups, hand back per-request results.

    One service instance is bound to one DEFAULT objective (`obj` — any
    `repro.core.objective.Objective`, backing specs with ``objective="")``,
    one default epoch budget, one ``drop_prob``/``w0`` and one mesh policy
    — the things `run_sweep` takes as call arguments. ``obj`` may be None
    when every submitted spec names a REGISTERED objective; one service
    then sweeps many objectives, and the objective fingerprint in the
    group key keeps their compiled dispatches apart. ``mesh=None``
    re-resolves the ambient `repro.sharding.context` mesh at every flush,
    so a service created inside a launcher's `mesh_context` shards its
    dispatches.
    """

    def __init__(self, obj: Optional[Objective], *, epochs: int = 10,
                 drop_prob: float = 0.02, mesh: Optional[Mesh] = None,
                 w0=None, max_results: int = 1024,
                 width_policy: Optional[WidthPolicy] = None,
                 latency_window: int = 512, max_tenants: int = 1024,
                 watchdog=None):
        self.obj = obj
        self.default_epochs = epochs
        self.drop_prob = drop_prob
        self.mesh = mesh
        self.w0 = w0
        # divergence watchdog (repro.obs.watchdog.Watchdog, or None):
        # inspects every dispatched group's histories at flush/slice
        # boundaries and applies the owning tenant's policy. Config like
        # width_policy — set before serving, never mutated mid-flight.
        self.watchdog = watchdog
        # flush-policy hooks the serving tier (repro.server) installs: a
        # width policy keeps dispatched batch widths at previously-compiled
        # values; submit listeners wake the background flush daemon
        self.width_policy = width_policy
        self._submit_listeners: List[Callable[[], None]] = []  # guarded-by: _lock
        # queue/id/results/stats mutations hold _lock so concurrent tenant
        # threads can't mint duplicate ids or lose a submit that races a
        # flush; the XLA dispatch itself runs OUTSIDE the lock (re-entrant
        # so helpers can lock themselves when called from either path)
        self._lock = threading.RLock()
        # ids detached from the queue but not yet in _results; result()
        # waits on this condition instead of misreporting a mid-dispatch
        # request as unknown
        self._inflight: set = set()  # guarded-by: _lock
        self._done_cv = threading.Condition(self._lock)
        self._pending: List[SweepRequest] = []  # guarded-by: _lock
        # completed results are FIFO-bounded (like the LRU-bounded runner
        # cache one layer down): a long-lived server must not accumulate
        # every tenant's histories forever. Clients read soon after flush;
        # evicted ids raise KeyError like unknown ones.
        self._results: "OrderedDict[int, SweepResult]" = OrderedDict()  # guarded-by: _lock
        self._max_results = max_results
        # ids a thread is currently blocked on in wait_result()/result():
        # the retention eviction skips these — a result must never be
        # thrown away while its consumer is blocked waiting for it
        self._watched: Dict[int, int] = {}  # guarded-by: _lock
        self._next_id = 0  # guarded-by: _lock
        # service-local cache accounting, credited PER LOOKUP: dispatch
        # windows install this sink on their thread via
        # `cache.scoped_counters`, so only lookups this service actually
        # performs land here — exact even when several services flush
        # concurrently (the old absorb-the-global-delta scheme was racy
        # across services and is gone)
        self._cache_sink = _cache._Counters()
        self._requests_submitted = 0  # guarded-by: _lock
        self._requests_completed = 0  # guarded-by: _lock
        self._rows_submitted = 0  # guarded-by: _lock
        self._rows_coalesced = 0  # guarded-by: _lock
        self._groups_dispatched = 0  # guarded-by: _lock
        self._groups_merged = 0  # guarded-by: _lock
        self._rows_padded = 0  # guarded-by: _lock
        self._rows_diverged = 0  # guarded-by: _lock
        self._flushes = 0  # guarded-by: _lock
        # tenant -> [rows submitted, rows completed] (metrics endpoint);
        # FIFO-bounded like the results store — tenant tags are arbitrary
        # client-supplied strings, so an adversarial/buggy client minting a
        # fresh tag per request must not grow the map without bound
        self._tenant_rows: "OrderedDict[str, List[int]]" = OrderedDict()  # guarded-by: _lock
        self._max_tenants = max_tenants
        # recent flush dispatch durations + request submit->complete
        # latencies (seconds), bounded so a long-lived server can't grow
        # them; the metrics layer derives p50/p95 from these
        self._flush_latencies: deque = deque(maxlen=latency_window)  # guarded-by: _lock
        self._request_latencies: deque = deque(maxlen=latency_window)  # guarded-by: _lock
        # request id -> flight-recorder trace id (empty entries are never
        # stored); bounded like the results store so a long-lived server
        # can't accumulate ids forever. The histograms self-lock, so
        # observes happen wherever is convenient.
        self._trace_ids: "OrderedDict[int, str]" = OrderedDict()  # guarded-by: _lock
        self.histograms = ServiceHistograms()

    # ---------------------------------------------------------------- queue
    def submit(self, specs: Sequence[SweepSpec],
               epochs: Optional[int] = None, *, tenant: str = "default",
               priority: int = 0) -> int:
        """Admit one request (one logical client). Returns its id; nothing
        executes until `flush` (or a `result` call forces one).

        ``tenant``/``priority`` tag the request for admission control —
        the fair-share flush selector (`repro.server.fairness`) slices
        flushes by them; they never affect the numeric result.

        Specs are VALIDATED here, not at flush: the request is fully
        planned (normalized AND resolved against the objective, the same
        `plan_sweep` a flush would run), so an invalid spec — bad
        algo/scheme/delay, contradictory svrg τ, non-positive epochs or
        inner-step counts — raises to the submitting client only and can
        never poison a shared flush (which would wedge every other
        tenant's pending request).
        """
        specs = tuple(specs)
        if not specs:
            raise ValueError("empty request")
        default = epochs if epochs is not None else self.default_epochs
        tr = _tracer()
        tid = tr.new_trace()
        with tr.span(tid, "submit", rows=len(specs), tenant=str(tenant)):
            with tr.span(tid, "plan", parent_name="submit"):
                plan_sweep(self.obj, default, specs)  # raises on bad spec
            with self._lock:
                rid = self._next_id
                self._next_id += 1
                self._pending.append(SweepRequest(
                    request_id=rid, specs=specs, epochs=default,
                    tenant=str(tenant), priority=int(priority),
                    submitted_at=time.monotonic(), trace_id=tid))
                if tid:
                    self._trace_ids[rid] = tid
                    while len(self._trace_ids) > self._max_results:
                        self._trace_ids.popitem(last=False)
                self._requests_submitted += 1
                self._rows_submitted += len(specs)
                rows = self._tenant_rows.setdefault(str(tenant), [0, 0])
                rows[0] += len(specs)
                while len(self._tenant_rows) > self._max_tenants:
                    self._tenant_rows.popitem(last=False)
                listeners = tuple(self._submit_listeners)
            tr.annotate(request_id=rid)
        for cb in listeners:                     # outside the lock: a
            cb()                                 # listener may touch us
        return rid

    def add_submit_listener(self, cb: Callable[[], None]) -> None:
        """Register a callback fired after every successful submit (the
        background flush daemon's wake-up hook)."""
        with self._lock:
            self._submit_listeners.append(cb)

    def remove_submit_listener(self, cb: Callable[[], None]) -> None:
        with self._lock:
            if cb in self._submit_listeners:
                self._submit_listeners.remove(cb)

    def flush(self, selector: Optional[FlushSelector] = None) -> List[int]:
        """Coalesce + dispatch pending requests; returns completed ids.

        ``selector`` (the fair-share admission hook) partitions the queue
        into the requests this flush takes and the ones it keeps for the
        next; ``None`` takes everything. Kept requests stay pending in
        their selector-returned order.

        The queue is detached BEFORE dispatch (one atomic swap), so a
        request submitted while the XLA work runs lands in the fresh queue
        for the next flush instead of being silently dropped by a
        post-dispatch clear; if dispatch fails the detached requests are
        re-queued rather than lost."""
        with self._lock:
            if selector is None:
                pending, self._pending = self._pending, []
            else:
                before = sorted(r.request_id for r in self._pending)
                take, keep = selector(tuple(self._pending))
                pending, keep = list(take), list(keep)
                after = sorted(r.request_id for r in pending + keep)
                if after != before:
                    raise ValueError(
                        "flush selector must partition the pending queue "
                        f"(got ids {after}, queue held {before})")
                self._pending = keep
            self._inflight.update(r.request_id for r in pending)
        if not pending:
            return []
        tr = _tracer()
        tids = tuple(r.trace_id for r in pending) if tr.enabled else ()
        t0 = time.perf_counter()
        try:
            with tr.span_all(tids, "coalesce", parent_name="submit",
                             requests=len(pending)):
                batch = coalesce(self.obj, tuple(pending))
            with _cache.scoped_counters(self._cache_sink):
                results, info = dispatch(self.obj, batch, w0=self.w0,
                                         drop_prob=self.drop_prob,
                                         mesh=_active_mesh(self.mesh),
                                         width_policy=self.width_policy,
                                         watchdog=self.watchdog)
        except Exception as exc:
            for r in pending:
                tr.record_error(r.trace_id, exc)
            with self._lock:
                self._pending = pending + self._pending
                self._inflight.difference_update(
                    r.request_id for r in pending)
                self._done_cv.notify_all()
            raise
        now = time.monotonic()
        dt = time.perf_counter() - t0
        if self.histograms.enabled:
            self.histograms.flush_latency_seconds.observe(dt)
            self.histograms.rows_per_flush.observe(info.rows_dispatched)
            if info.rows_dispatched:
                self.histograms.pad_factor.observe(
                    (info.rows_dispatched + info.rows_padded)
                    / info.rows_dispatched)
        if _progress.progress_enabled():
            self._publish_flush_events(pending, results, dt)
        with self._lock:
            self._results.update(results)
            # evict oldest first, but never a result a thread is blocked
            # waiting on — one wide flush completing more requests than
            # max_results must not throw away work whose consumer is
            # already parked on the condition variable
            evictable = [rid for rid in self._results
                         if rid not in self._watched]
            while len(self._results) > self._max_results and evictable:
                del self._results[evictable.pop(0)]
            self._inflight.difference_update(results)
            self._requests_completed += len(results)
            self._rows_coalesced += info.rows_coalesced
            self._groups_dispatched += info.groups_dispatched
            self._groups_merged += info.groups_merged
            self._rows_padded += info.rows_padded
            self._rows_diverged += info.rows_diverged
            self._flushes += 1
            self._flush_latencies.append(dt)
            for req in pending:
                self._tenant_rows.setdefault(req.tenant, [0, 0])[1] += \
                    req.rows
                if req.submitted_at:
                    latency = now - req.submitted_at
                    self._request_latencies.append(latency)
                    if self.histograms.enabled:
                        self.histograms.request_latency_seconds.observe(
                            latency)
            self._done_cv.notify_all()
        return sorted(results)

    def _publish_flush_events(self, pending, results, dt: float) -> None:
        """One live-progress event per request this flush completed, on the
        ``req-<id>`` watch channel. Losses are the request's OWN result
        histories (each row trimmed to its epoch budget), so what a
        watcher streams is exactly what ``result()`` later returns."""
        bus = _progress.progress_bus()
        by_id = {r.request_id: r for r in pending}
        for rid, res in results.items():
            req = by_id[rid]
            losses, deltas = _row_loss_series(res.histories,
                                              res.epochs_per_row)
            diverged = ()
            if res.diverged_rows is not None:
                diverged = tuple(int(c) for c in
                                 np.flatnonzero(res.diverged_rows >= 0))
            bus.publish(kind="flush", watch_id=f"req-{rid}",
                        tenant=req.tenant, rows=tuple(range(len(res.specs))),
                        losses=losses, loss_deltas=deltas, diverged=diverged,
                        wall_s=dt, trace_id=req.trace_id)

    def _missing(self, request_id: int) -> KeyError:  # holds: _lock
        """The right error for an id that is not pending/inflight/stored.
        Every minted id enters the queue, so an id below the mint counter
        MUST have completed and been released — distinguishable from a
        phantom id with no bookkeeping at all."""
        if 0 <= request_id < self._next_id:
            return ResultEvictedError(
                f"result for request {request_id} was evicted: completed "
                f"results are FIFO-bounded (max_results={self._max_results})"
                " or were explicitly discarded; re-submit the specs or "
                "raise max_results")
        return KeyError(f"unknown request id {request_id}")

    def _watch(self, request_id: int) -> None:
        """Mark an id as actively awaited (refcounted): the retention
        eviction will not drop it while any waiter is parked on it."""
        with self._lock:
            self._watched[request_id] = self._watched.get(request_id, 0) + 1

    def _unwatch(self, request_id: int) -> None:
        with self._lock:
            count = self._watched.get(request_id, 0) - 1
            if count <= 0:
                self._watched.pop(request_id, None)
            else:
                self._watched[request_id] = count

    def result(self, request_id: int) -> SweepResult:
        """This request's `SweepResult` (bit-identical to a standalone
        `run_sweep` of its specs). Flushes first if it is still queued,
        and WAITS if another thread's flush has the request in flight.
        Raises `ResultEvictedError` for completed-then-released ids and
        bare KeyError for ids that never existed."""
        tr = _tracer()
        self._watch(request_id)
        try:
            with tr.span(self.trace_id(request_id), "result",
                         parent_name="submit"):
                while True:
                    with self._done_cv:        # shares the service lock
                        if request_id in self._results:
                            return self._results[request_id]
                        if request_id in self._inflight:
                            self._done_cv.wait()
                            continue
                        queued = any(r.request_id == request_id
                                     for r in self._pending)
                        if not queued:
                            raise self._missing(request_id)
                    self.flush()
        finally:
            self._unwatch(request_id)

    def wait_result(self, request_id: int,
                    timeout: Optional[float] = None) -> SweepResult:
        """Like :meth:`result` but NEVER triggers a flush itself — it
        waits for someone else's (the background flush daemon's deadline
        policy, another tenant's size-triggered flush). The serving tier's
        result path uses this so a result poll can't defeat coalescing.
        Raises TimeoutError if the deadline passes first."""
        deadline = None if timeout is None else time.monotonic() + timeout
        tr = _tracer()
        self._watch(request_id)
        try:
            with tr.span(self.trace_id(request_id), "result",
                         parent_name="submit"):
                with self._done_cv:
                    while True:
                        if request_id in self._results:
                            return self._results[request_id]
                        if (request_id not in self._inflight
                                and not any(r.request_id == request_id
                                            for r in self._pending)):
                            raise self._missing(request_id)
                        remaining = (None if deadline is None
                                     else deadline - time.monotonic())
                        if remaining is not None and remaining <= 0:
                            raise TimeoutError(
                                f"request {request_id} not completed "
                                f"within {timeout}s (still queued or in "
                                "flight)")
                        self._done_cv.wait(remaining)
        finally:
            self._unwatch(request_id)

    def discard(self, request_id: int) -> None:
        """Release a completed result early (no-op if absent) — the
        explicit retention hook for clients that have consumed it."""
        with self._lock:
            self._results.pop(request_id, None)

    def sweep(self, specs: Sequence[SweepSpec],
              epochs: Optional[int] = None) -> SweepResult:
        """submit + flush + result in one call (the single-tenant path —
        still coalesced with anything already queued, still cache-warm)."""
        return self.result(self.submit(specs, epochs))

    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def pending_rows(self) -> int:
        """Total spec rows waiting in the queue (the flush-size trigger)."""
        with self._lock:
            return sum(r.rows for r in self._pending)

    def oldest_pending_age(self) -> Optional[float]:
        """Seconds since the OLDEST queued request was admitted (the
        flush-deadline trigger), or None when the queue is empty."""
        with self._lock:
            stamps = [r.submitted_at for r in self._pending
                      if r.submitted_at]
            if not stamps:
                return None
            return time.monotonic() - min(stamps)

    def trace_id(self, request_id: int) -> str:
        """The flight-recorder trace id :meth:`submit` minted for a
        request ("" when tracing was off at submit, or the id aged out of
        the bounded map). The serving tier echoes this in response
        headers so a client can fetch the span tree from ``/trace``."""
        with self._lock:
            return self._trace_ids.get(request_id, "")

    def tenant_rows(self) -> Dict[str, Tuple[int, int]]:
        """Per-tenant (rows submitted, rows completed) snapshot."""
        with self._lock:
            return {t: (v[0], v[1]) for t, v in self._tenant_rows.items()}

    def latencies(self) -> Tuple[Tuple[float, ...], Tuple[float, ...]]:
        """(recent flush dispatch durations, recent request submit->result
        latencies), both in seconds and bounded by ``latency_window`` —
        the raw series `repro.server.metrics` derives p50/p95 from."""
        with self._lock:
            return tuple(self._flush_latencies), \
                tuple(self._request_latencies)

    # ---------------------------------------------------------------- stats
    def stats(self) -> ServiceStats:
        """A LOCKED snapshot: the service-level fields are read under the
        service lock in one critical section, so a completed flush is
        counted all-or-nothing across them. The cache counters are the one
        exception — they advance at lookup/trace time MID-dispatch (under
        the cache lock), so a snapshot taken during a flush can show its
        lookups before its ``flushes`` increment; successive snapshots are
        monotonic either way."""
        with self._lock:
            cache = self._cache_sink.snapshot()
            return ServiceStats(
                requests_submitted=self._requests_submitted,
                requests_completed=self._requests_completed,
                rows_submitted=self._rows_submitted,
                rows_coalesced=self._rows_coalesced,
                groups_dispatched=self._groups_dispatched,
                groups_merged=self._groups_merged,
                flushes=self._flushes,
                cache_hits=cache.hits,
                cache_misses=cache.misses,
                compiles=cache.compiles,
                rows_padded=self._rows_padded,
                rows_diverged=self._rows_diverged)

    # ------------------------------------------------------ checkpointed job
    def run_job(self, specs: Sequence[SweepSpec],
                epochs: Optional[int] = None, *,
                checkpointer: Checkpointer,
                max_groups: Optional[int] = None,
                tenant: str = "default",
                progress_id: Optional[str] = None,
                ) -> Tuple[Optional[SweepResult], bool]:
        """Run one long sweep group-by-group with checkpoint-resume.

        After every dispatched group the partial result is saved through
        ``checkpointer`` (atomic rename — a crash mid-job loses at most the
        in-flight group). A rerun with the same specs/epochs restores the
        newest checkpoint and dispatches only the unfinished groups; a
        fingerprint of the resolved plan guards against resuming a
        DIFFERENT job from the same directory. ``max_groups`` caps how many
        groups this call dispatches (preemption budget).

        Each group boundary is a live-observability slice: when progress
        streaming is on (`repro.obs.progress`) a ``slice`` event carrying
        the group's per-row loss series is published to ``progress_id``
        (the serving daemon passes ``job-<id>``), plus a final ``done``
        event. When ``self.watchdog`` is set, each slice's histories are
        inspected; ``tenant`` selects the per-tenant policy, and a
        ``cancel_job`` verdict raises `repro.obs.watchdog.JobDiverged`
        (finished groups stay checkpointed). Watchdog truncations persist
        in the checkpoint (``epochs_eff``/``diverged`` arrays), so a
        resumed job keeps its frozen rows.

        Returns ``(result, done)`` — ``result`` is None until every group
        has run, then bit-identical to ``run_sweep(obj, epochs, specs)``
        (with ``diverged_rows`` marked when the watchdog intervened).
        """
        epochs = epochs if epochs is not None else self.default_epochs
        plan = plan_sweep(self.obj, epochs, specs)
        job_obj = plan.objective
        group_items = list(plan.groups.items())
        resolved = plan.resolved
        C = len(plan.specs)
        max_epochs = max(r.epochs for r in resolved)
        epochs_per_row = np.asarray([r.epochs for r in resolved], np.int64)
        # the fingerprint pins the RESOLVED plan AND the numeric inputs:
        # specs + epochs + drop_prob + the objective fingerprint (its static
        # config AND every data leaf's bytes — arbitrary pytree objectives
        # included) + the actual w0 bytes. Groups checkpointed from one
        # starting point or dataset must never be blended with groups
        # resumed under another (same-shape data or a different w0 would
        # otherwise slip through). The objective digest is memoized per
        # instance: a preemption loop calling run_job once per group hashes
        # the data once, not once per call.
        w_init = (job_obj.init_flat() if self.w0 is None
                  else job_obj.as_flat(self.w0))
        fp = zlib.crc32(repr((plan.specs, tuple(epochs_per_row.tolist()),
                              self.drop_prob,
                              job_obj.fingerprint())).encode())
        fp = zlib.crc32(
            np.ascontiguousarray(np.asarray(w_init)).tobytes(), fp)

        state = {
            "histories": np.zeros((C, max_epochs + 1), np.float32),
            "final_w": np.zeros((C, job_obj.flat_dim), np.float32),
            "done": np.zeros((len(group_items),), np.int8),
            "fingerprint": np.asarray(fp, np.int64),
            # watchdog bookkeeping: the EFFECTIVE per-row epoch budget
            # (cancel_row truncations land here) and the diverged marker
            # (-1 healthy, else last trusted epoch). Checkpointed so a
            # resumed job keeps its frozen rows. (Checkpoints written
            # before these keys existed restore as "different job" — the
            # template-keyed restore already rejects them.)
            "epochs_eff": epochs_per_row.copy(),
            "diverged": np.full((C,), -1, np.int64),
        }
        try:
            state, _ = checkpointer.restore(state)
        except FileNotFoundError:
            pass                                 # fresh job
        except (KeyError, ValueError) as e:
            # same directory, different tree/shapes: a different job
            raise ValueError(
                f"checkpoint directory {checkpointer.dir!r} holds a "
                f"different job (incompatible checkpoint: {e})") from e
        else:
            if int(state["fingerprint"]) != fp:
                raise ValueError(
                    "checkpoint directory holds a different job "
                    f"(fingerprint {int(state['fingerprint'])} != {fp})")

        mesh = _active_mesh(self.mesh)
        watch_id = progress_id if progress_id is not None else "job"
        dispatched = 0
        with _cache.scoped_counters(self._cache_sink):
            for gi, (key_, members) in enumerate(group_items):
                if state["done"][gi]:
                    continue
                if max_groups is not None and dispatched >= max_groups:
                    return None, False
                group_epochs = plan.group_epochs(key_)
                # the slice's resolved rows honour earlier truncations
                # (this call's or a restored checkpoint's)
                res_rows = [r._replace(epochs=int(e)) if int(e) != r.epochs
                            else r
                            for r, e in zip(resolved, state["epochs_eff"])]
                t0 = time.perf_counter()
                hist, w_fin = _dispatch_group(job_obj, plan.specs,
                                              res_rows, members, key_,
                                              group_epochs, w_init,
                                              self.drop_prob, mesh)
                if self.watchdog is not None:
                    from repro.obs.watchdog import enforce_group

                    hist, w_fin, bad, overrides = enforce_group(
                        self.watchdog, hist, w_fin, members=members,
                        resolved=res_rows, tenant_of=lambda c: tenant,
                        redispatch=lambda amended: _dispatch_group(
                            job_obj, plan.specs, amended, members, key_,
                            group_epochs, w_init, self.drop_prob, mesh))
                    for c, e in bad.items():
                        state["diverged"][c] = e
                    for c, k in overrides.items():
                        state["epochs_eff"][c] = k
                    if bad:
                        with self._lock:
                            self._rows_diverged += len(bad)
                wall_s = time.perf_counter() - t0
                for row, c in enumerate(members):
                    _write_row_history(state["histories"][c], hist[row],
                                       group_epochs)
                    state["final_w"][c] = w_fin[row]
                state["done"][gi] = 1
                dispatched += 1
                with self._lock:
                    self._groups_dispatched += 1
                checkpointer.save(state, step=int(state["done"].sum()),
                                  extra={"job_fingerprint": int(fp),
                                         "groups_total": len(group_items)})
                if _progress.progress_enabled():
                    self._publish_slice_event(
                        watch_id, tenant, key_, gi, len(group_items),
                        members, state, wall_s)
        result = _assemble_result(
            plan.specs,
            [r._replace(epochs=int(e)) if int(e) != r.epochs else r
             for r, e in zip(resolved, state["epochs_eff"])],
            state["histories"], state["final_w"],
            param_shapes=job_obj.param_shapes(), w_init=w_init,
            diverged={int(c): int(e)
                      for c, e in enumerate(state["diverged"]) if e >= 0})
        if _progress.progress_enabled():
            _progress.progress_bus().publish(
                kind="done", watch_id=watch_id, tenant=tenant,
                slices_total=len(group_items))
        return result, True

    def _publish_slice_event(self, watch_id, tenant, key_, gi, n_groups,
                             members, state, wall_s) -> None:
        """One ``slice`` event per dispatched job group: the slice's rows
        with their loss series AS CHECKPOINTED (each trimmed to the row's
        effective epoch budget — watchdog freezes included), so streaming
        watchers see exactly the final result's histories, incrementally."""
        hist_rows = state["histories"][list(members)]
        eff = state["epochs_eff"][list(members)]
        losses, deltas = _row_loss_series(hist_rows, eff)
        diverged = tuple(int(c) for c in members
                         if state["diverged"][c] >= 0)
        _progress.progress_bus().publish(
            kind="slice", watch_id=watch_id, tenant=tenant,
            group=group_label(key_), slice_index=gi, slices_total=n_groups,
            rows=tuple(int(c) for c in members), losses=losses,
            loss_deltas=deltas, diverged=diverged, wall_s=wall_s)
