"""Optimizers over pytree directions.

The paper's update is plain SGD on the variance-reduced direction v
(Algorithm 1: u ← u − η v); `sgd` is therefore the paper-faithful choice.
`momentum` and `adamw` are beyond-paper options that consume v as the
gradient estimate (SVRG-as-estimator), useful for the LM examples.

Each optimizer is (init(params) -> opt_state, apply(v, opt_state, lr,
params) -> (new_params, new_opt_state)). States are pytrees so the
checkpointer and pjit shard them like params.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.utils.tree import global_norm, tree_zeros_like


class Optimizer(NamedTuple):
    name: str
    init: Callable[[Any], Any]
    apply: Callable[..., Tuple[Any, Any]]   # (v, opt_state, lr, params, step)


def clip_by_global_norm(tree, max_norm: float):
    if max_norm <= 0:
        return tree, jnp.zeros((), jnp.float32)
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: x * scale, tree), norm


def _sgd(cfg: TrainConfig) -> Optimizer:
    wd = cfg.weight_decay

    def init(params):
        return {}

    def apply(v, opt_state, lr, params, step):
        def upd(p, g):
            g = g + wd * p if wd else g
            return (p - lr * g).astype(p.dtype)
        return jax.tree.map(upd, params, v), opt_state

    return Optimizer("sgd", init, apply)


def _momentum(cfg: TrainConfig) -> Optimizer:
    beta = cfg.beta1
    wd = cfg.weight_decay

    def init(params):
        return {"m": tree_zeros_like(params)}

    def apply(v, opt_state, lr, params, step):
        m = jax.tree.map(lambda mo, g: beta * mo + g, opt_state["m"], v)
        def upd(p, mi):
            g = mi + wd * p if wd else mi
            return (p - lr * g).astype(p.dtype)
        return jax.tree.map(upd, params, m), {"m": m}

    return Optimizer("momentum", init, apply)


def _adamw(cfg: TrainConfig) -> Optimizer:
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay

    def init(params):
        return {"m": tree_zeros_like(params), "v": tree_zeros_like(params)}

    def apply(v, opt_state, lr, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        m = jax.tree.map(lambda mo, g: b1 * mo + (1 - b1) * g,
                         opt_state["m"], v)
        s = jax.tree.map(lambda so, g: b2 * so + (1 - b2) * g * g,
                         opt_state["v"], v)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, mi, si):
            mhat = mi / c1
            shat = si / c2
            return (p - lr * (mhat / (jnp.sqrt(shat) + eps) + wd * p)).astype(p.dtype)

        return jax.tree.map(upd, params, m, s), {"m": m, "v": s}

    return Optimizer("adamw", init, apply)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    name = "sgd" if cfg.optimizer == "svrg" else cfg.optimizer
    if name == "sgd":
        return _sgd(cfg)
    if name == "momentum":
        return _momentum(cfg)
    if name == "adamw":
        return _adamw(cfg)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
