"""Paper Figure 1 (right column): objective gap vs effective passes —
AsySVRG (lock/unlock, 10 threads) vs Hogwild! (lock/unlock, 10 threads).

All four curves come from the multi-algorithm sweep engine: the two AsySVRG
rows share one jit, and the two Hogwild! rows share one jit (they run 3×
the epochs so both families cover equal effective passes — AsySVRG does ~3
passes per epoch, Hogwild! does 1)."""
from __future__ import annotations

import sys

from benchmarks.artifacts import write_bench_json
from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm

P = 10


def run(dataset="rcv1", scale=0.03, epochs=8, quick=False):
    if quick:
        epochs = 4
    ds = make_synthetic_libsvm(dataset, scale=scale)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    curves = {}
    asy = [SweepSpec(seed=0, scheme=scheme, step_size=2.0, num_threads=P,
                     tau=P - 1)
           for scheme in ("inconsistent", "unlock")]
    res = run_sweep(obj, epochs, asy)
    for c, spec in enumerate(asy):
        curves[f"asysvrg-{spec.scheme}"] = (
            tuple(res.effective_passes[c]), tuple(res.histories[c]))
    hog = [SweepSpec(algo="hogwild", seed=0, scheme=scheme, step_size=2.0,
                     num_threads=P, tau=P - 1)
           for scheme in ("inconsistent", "unlock")]
    res_h = run_sweep(obj, 3 * epochs, hog)
    for c, spec in enumerate(hog):
        curves[f"hogwild-{spec.scheme}"] = (
            tuple(res_h.effective_passes[c]), tuple(res_h.histories[c]))
    return {"f_star": f_star, "curves": curves}


def main(quick=True):
    out = run(quick=quick)
    write_bench_json("fig1_convergence", {
        "f_star": out["f_star"],
        "curves": {name: {"passes": list(passes), "loss": list(hist)}
                   for name, (passes, hist) in out["curves"].items()}})
    print("name,us_per_call,derived")
    for name, (passes, hist) in out["curves"].items():
        final_gap = hist[-1] - out["f_star"]
        print(f"fig1_convergence_{name},0,"
              f"final_gap={final_gap:.3e};passes={passes[-1]:.0f}")
    # full curves as CSV comment rows for plotting
    for name, (passes, hist) in out["curves"].items():
        pts = ";".join(f"{p:.0f}:{h - out['f_star']:.3e}"
                       for p, h in zip(passes, hist))
        print(f"# curve {name}: {pts}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
