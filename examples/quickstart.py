"""Quickstart: AsySVRG on the paper's own workload (logistic regression).

Reproduces the core claim in ~30 seconds on CPU: AsySVRG (all three reading
schemes) converges linearly and beats Hogwild! per effective pass.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.config import SVRGConfig
from repro.core import LogisticRegression, run_asysvrg, run_hogwild
from repro.data.libsvm import make_synthetic_libsvm


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.05)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    print(f"dataset rcv1-like: n={obj.n} p={obj.p}  f*={f_star:.6f}\n")

    print(f"{'method':28s} {'passes':>7s} {'final gap':>12s}")
    for scheme in ("consistent", "inconsistent", "unlock"):
        cfg = SVRGConfig(scheme=scheme, step_size=2.0, num_threads=10, tau=9)
        res = run_asysvrg(obj, epochs=6, cfg=cfg)
        gap = res.history[-1] - f_star
        print(f"AsySVRG-{scheme:20s} {res.effective_passes[-1]:7.0f} "
              f"{gap:12.3e}")

    res = run_hogwild(obj, epochs=18, step_size=2.0, num_threads=10)
    gap = res.history[-1] - f_star
    print(f"{'Hogwild!-unlock':28s} {res.effective_passes[-1]:7.0f} "
          f"{gap:12.3e}")
    print("\nAsySVRG reaches a much smaller gap at EQUAL effective passes —")
    print("the paper's Figure 1 (right) in one table.")


if __name__ == "__main__":
    main()
