"""Mixture-of-Experts family (qwen3-moe-235b-a22b, deepseek-moe-16b).

Token-choice top-k routing with GShard-style capacity dispatch: static
shapes, einsum dispatch/combine (TPU-native — no dynamic gather/scatter),
experts sharded over the `model` mesh axis (expert parallelism). Shared
experts (deepseek) run densely on every token. `first_dense_layers` keeps the
leading layer(s) dense (deepseek's fine-grained design); the dense layer's
hidden size defaults to moe_d_ff·(top_k + shared) to match activated compute.

Routing priority is (rank, position): rank-r assignments claim capacity
before rank-r+1, tokens in group order — the standard GShard tie-break.
Dropped tokens (over capacity) fall through with zero expert contribution
(the residual path carries them), matching dropping-MoE semantics.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import layers as nn
from repro.models import transformer as tf
from repro.sharding.context import constrain
from repro.sharding.rules import ParamDef

CAPACITY_FACTOR = 1.25
GROUP_SIZE = 256          # tokens per routing group (seq blocks; see moe_ffn)


def _moe_mlp_defs(cfg: ModelConfig, L: int, dtype: str) -> Dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": ParamDef((L, D, E), ("layers", "embed_no_fsdp", "expert"), dtype=dtype),
        "w_gate": ParamDef((L, E, D, F), ("layers", "expert", "embed", "expert_mlp"), dtype=dtype),
        "w_up": ParamDef((L, E, D, F), ("layers", "expert", "embed", "expert_mlp"), dtype=dtype),
        "w_down": ParamDef((L, E, F, D), ("layers", "expert", "expert_mlp", "embed"), dtype=dtype),
    }
    if cfg.num_shared_experts > 0:
        Fs = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": ParamDef((L, D, Fs), ("layers", "embed", "mlp"), dtype=dtype),
            "w_up": ParamDef((L, D, Fs), ("layers", "embed", "mlp"), dtype=dtype),
            "w_down": ParamDef((L, Fs, D), ("layers", "mlp", "embed"), dtype=dtype),
        }
    return p


def param_defs(cfg: ModelConfig) -> Dict:
    dt = cfg.param_dtype
    D, V = cfg.d_model, cfg.vocab_size
    n0 = cfg.first_dense_layers
    Lm = cfg.num_layers - n0
    p = {
        "tok_embed": ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt),
        "moe_blocks": {
            **{k: v for k, v in tf.block_param_defs(cfg, Lm, dt).items() if k != "mlp"},
            "moe": _moe_mlp_defs(cfg, Lm, dt),
        },
        "final_norm": tf._norm_defs((D,), cfg, dt),
    }
    if n0 > 0:
        dense_ff = cfg.d_ff if cfg.d_ff > 0 else cfg.moe_d_ff * (
            cfg.experts_per_token + cfg.num_shared_experts)
        dense_cfg = cfg.with_overrides(d_ff=dense_ff)
        p["dense_blocks"] = tf.block_param_defs(dense_cfg, n0, dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt)
    return p


# ---------------------------------------------------------------------------
# Routing + expert computation
# ---------------------------------------------------------------------------

def moe_ffn(x, p: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,D] -> (y [B,S,D], aux_loss scalar).

    Routing groups are SEQ BLOCKS of Sg=256 tokens kept as a separate dim
    [B, n, Sg, ...] (never flattened across batch x seq): the n dim aligns
    with the 16-way sequence sharding so every routing group is device-local,
    and the small per-group capacity keeps the dispatch one-hots at
    tokens*E*C ≈ 5 GiB global (vs 43 GiB with 2048-token groups). Expert
    tensors are constrained to (expert→model, batch→data)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    Sg = min(GROUP_SIZE, S)
    while S % Sg != 0:
        Sg //= 2
    n = S // Sg
    C = max(1, int(np.ceil(Sg * k * CAPACITY_FACTOR / E)))

    xg = x.reshape(B, n, Sg, D)
    logits = jnp.einsum("bnsd,de->bnse", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)  # renormalize

    counts = jnp.zeros((B, n, 1, E), jnp.float32)
    dispatch = jnp.zeros((B, n, Sg, E, C), x.dtype)
    combine = jnp.zeros((B, n, Sg, E, C), x.dtype)
    for r in range(k):
        m = jax.nn.one_hot(topi[..., r], E, dtype=jnp.float32)    # [B,n,Sg,E]
        pos = jnp.cumsum(m, axis=2) - m + counts                  # queue position
        pos_tok = jnp.sum(pos * m, axis=-1)                       # [B,n,Sg]
        within = (pos_tok < C).astype(jnp.float32)
        m_kept = m * within[..., None]
        counts = counts + jnp.sum(m_kept, axis=2, keepdims=True)
        slot = jax.nn.one_hot(pos_tok.astype(jnp.int32), C, dtype=jnp.float32)
        slot = slot * within[..., None]                           # [B,n,Sg,C]
        contrib = (m_kept[..., :, None] * slot[..., None, :]).astype(x.dtype)
        dispatch = dispatch + contrib
        combine = combine + contrib * topv[..., r][..., None, None].astype(x.dtype)

    moe_tok_axes = ("batch", "seq_shard", None, None, None)
    expert_axes = ("expert", "batch", None, None, None)
    dispatch = constrain(dispatch, moe_tok_axes)
    combine = constrain(combine, moe_tok_axes)
    xin = jnp.einsum("bnsec,bnsd->ebncd", dispatch, xg)           # [E,B,n,C,D]
    xin = constrain(xin, expert_axes)
    hg = nn._act(cfg.activation,
                 jnp.einsum("ebncd,edf->ebncf", xin, p["w_gate"]))
    hu = jnp.einsum("ebncd,edf->ebncf", xin, p["w_up"])
    out_e = jnp.einsum("ebncf,efd->ebncd", hg * hu, p["w_down"])
    out_e = constrain(out_e, expert_axes)
    y = jnp.einsum("bnsec,ebncd->bnsd", combine, out_e).reshape(B, S, D)

    if cfg.num_shared_experts > 0:
        sp = p["shared"]
        gate = nn._act(cfg.activation, jnp.einsum("bsd,df->bsf", x, sp["w_gate"]))
        up = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        y = y + jnp.einsum("bsf,fd->bsd", gate * up, sp["w_down"])

    # load-balancing aux (Switch/GShard): E * Σ_e f_e · p̄_e
    sel_frac = jnp.mean(jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32),
                        axis=(0, 1, 2))
    mean_prob = jnp.mean(probs, axis=(0, 1, 2))
    aux = E * jnp.sum(sel_frac * mean_prob)
    return y, aux


def _moe_block(cfg: ModelConfig, lp: Dict, h, pos, window,
               kv_override=None, pos_k=None):
    x = nn.apply_norm(cfg, h, lp["attn_norm"])
    q, kk, vv = nn.gqa_project(x, lp["attn"], cfg, cfg.use_qkv_bias)
    q, kk = tf._qk_normalize(cfg, lp["attn"], q, kk)
    q = nn.apply_rope(q, pos, cfg)
    kk = nn.apply_rope(kk, pos, cfg)
    k_new, v_new = kk, vv
    if kv_override is not None:
        kk, vv = kv_override
        pk = pos_k
    else:
        pk = pos
    out = nn.attention(q, kk, vv, pos, pk, causal=True, window=window,
                       chunk_q=2048)
    h = h + nn.attn_output(out, lp["attn"], cfg.use_bias)
    x = nn.apply_norm(cfg, h, lp["mlp_norm"])
    y, aux = moe_ffn(x, lp["moe"], cfg)
    return h + y, aux, (k_new, v_new)


def hidden_states(cfg: ModelConfig, params, tokens, positions=None,
                  collect_cache: bool = False):
    B, S = tokens.shape
    pos = positions if positions is not None else jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    h = embed = tf.embed_tokens(cfg, params, tokens)
    n0 = cfg.first_dense_layers
    aux_total = jnp.zeros((), jnp.float32)
    caches = []

    if n0 > 0:
        dense_ff = cfg.d_ff if cfg.d_ff > 0 else cfg.moe_d_ff * (
            cfg.experts_per_token + cfg.num_shared_experts)
        dense_cfg = cfg.with_overrides(d_ff=dense_ff)
        for i in range(n0):
            lp = jax.tree.map(lambda x: x[i], params["dense_blocks"])
            h, kv = tf.block_apply(dense_cfg, lp, h, pos, 0)
            caches.append(kv)

    def body(carry, lp):
        hh, aux = carry
        hh = tf.constrain(hh, tf.RESIDUAL_AXES)
        hh, a, kv = _moe_block(cfg, lp, hh, pos, 0)
        return (tf.constrain(hh, tf.RESIDUAL_AXES), aux + a), kv

    step = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "full" else body
    (h, aux_total), kvs = jax.lax.scan(step, (h, aux_total), params["moe_blocks"])
    h = nn.apply_norm(cfg, h, params["final_norm"])
    if collect_cache:
        return h, aux_total, (caches, kvs)
    return h, aux_total


def loss_fn(cfg: ModelConfig, params, batch):
    h, aux = hidden_states(cfg, params, batch["tokens"])
    ce = nn.lm_loss(h, tf.unembed(cfg, params), batch["targets"], batch["mask"],
                    softcap=cfg.logits_softcap)
    return ce + cfg.router_aux_loss * aux


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

cache_defs = tf.cache_defs     # same layout: [L, B, K, S, h]


def prefill(cfg: ModelConfig, params, tokens, cache_len: int):
    B, S = tokens.shape
    h, _, (dense_kvs, moe_kvs) = hidden_states(cfg, params, tokens,
                                               collect_cache=True)
    logits = jnp.einsum("bd,vd->bv", h[:, -1, :], tf.unembed(cfg, params))

    def stack_cache(dense_list, scanned):
        if dense_list:
            d = jnp.stack([kv for kv in dense_list])     # [n0,B,S,K,h]
            return jnp.concatenate([d, scanned], axis=0)
        return scanned

    ks = stack_cache([kv[0] for kv in dense_kvs], moe_kvs[0])
    vs = stack_cache([kv[1] for kv in dense_kvs], moe_kvs[1])

    def pad_cache(x):  # [L,B,S,K,h] -> [L,B,K,cache_len,h]
        x = x.transpose(0, 1, 3, 2, 4)
        pad = cache_len - x.shape[3]
        return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))).astype(jnp.dtype(cfg.dtype))

    return logits.astype(jnp.float32), {"k": pad_cache(ks), "v": pad_cache(vs)}


def decode_step(cfg: ModelConfig, params, cache: Dict, tokens, pos_scalar):
    """Carry-DUS cache update (in-place with donation; see transformer.py)."""
    B = tokens.shape[0]
    S = cache["k"].shape[3]
    n0 = cfg.first_dense_layers
    Lm = cfg.num_layers - n0
    tok = tokens[:, None]
    pos_q = jnp.broadcast_to(pos_scalar[None, None], (B, 1)).astype(jnp.int32)
    pos_k = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    h = tf.embed_tokens(cfg, params, tok)
    ck_all, cv_all = cache["k"], cache["v"]

    def attend(lp, hh, ck, cv):
        x = nn.apply_norm(cfg, hh, lp["attn_norm"])
        q, k, v = nn.gqa_project(x, lp["attn"], cfg, cfg.use_qkv_bias)
        q, k = tf._qk_normalize(cfg, lp["attn"], q, k)
        q = nn.apply_rope(q, pos_q, cfg)
        k = nn.apply_rope(k, pos_q, cfg)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.transpose(0, 2, 1, 3).astype(ck.dtype), pos_scalar, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.transpose(0, 2, 1, 3).astype(cv.dtype), pos_scalar, axis=2)
        out = nn.attention(q, ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
                           pos_q, pos_k, causal=True, window=0)
        return hh + nn.attn_output(out, lp["attn"], cfg.use_bias), ck, cv

    if n0 > 0:
        dense_ff = cfg.d_ff if cfg.d_ff > 0 else cfg.moe_d_ff * (
            cfg.experts_per_token + cfg.num_shared_experts)
        dense_cfg = cfg.with_overrides(d_ff=dense_ff)
        for i in range(n0):
            lp = jax.tree.map(lambda x: x[i], params["dense_blocks"])
            h, ck, cv = attend(lp, h, ck_all[i], cv_all[i])
            x = nn.apply_norm(cfg, h, lp["mlp_norm"])
            h = h + nn.mlp(x, lp["mlp"], dense_cfg)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)

    def body(carry, xs):
        hh, ck_all, cv_all = carry
        lp, i = xs
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        hh, ck, cv = attend(lp, hh, ck, cv)
        x = nn.apply_norm(cfg, hh, lp["mlp_norm"])
        y, _ = moe_ffn(x, lp["moe"], cfg)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
        return (hh + y, ck_all, cv_all), None

    (h, ck_all, cv_all), _ = jax.lax.scan(
        body, (h, ck_all, cv_all),
        (params["moe_blocks"], n0 + jnp.arange(Lm)))
    h = nn.apply_norm(cfg, h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h[:, 0, :], tf.unembed(cfg, params))
    return logits.astype(jnp.float32), {"k": ck_all, "v": cv_all}
