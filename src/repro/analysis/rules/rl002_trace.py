"""RL002 — trace-safety for jit/pallas-reachable code.

Three failure modes the runner-cache architecture (PR 4) forbids, each
of which burned us or nearly did:

  1. **Array closure captures.** A lambda handed to ``jax.jit`` /
     ``pl.pallas_call`` that closes over an ndarray bakes the array into
     the traced program: the jit cache keys on the captured object's id,
     retraces per instance, and pins device memory. House rule: data
     enters as runtime arguments; closures may capture only hashable
     statics and an objective's pure methods. The checker resolves a
     jitted lambda's free names against the enclosing scope's simple
     assignments and flags bindings that are array-ish (``jnp.*``/``np.*``
     constructors, ``jax.random.*``, ``*.data_args()``).

  2. **Python ``if``/``while`` on a tracer.** In the traced cores the
     house convention is positional params = tracers, kw-only params
     (after ``*``) = static config. Branching a Python conditional on a
     positional param raises ConcretizationTypeError at trace time — or
     worse, silently specializes. Scope: functions named
     ``*_epoch_core``/``*_epochs_core`` and functions decorated with
     ``jax.jit``. Shape/dtype probes (``x.shape``, ``x.ndim``,
     ``x.dtype``, ``x.size``, ``len(x)``, ``isinstance(x, …)``) are
     static and exempt.

  3. **Unhashable static keys.** ``static_key`` / ``runner_static_key`` /
     ``runner_key`` feed dict-key material for the runner cache; a list /
     dict / set / bare ``sorted(...)`` in the return value raises
     TypeError only on the cache path, far from the author. Wrapping in
     ``tuple(...)`` or ``frozenset(...)`` is the sanctioned fix and is
     recognized.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.astutil import (
    FUNC_NODES,
    call_name,
    dotted_name,
    free_names,
    local_bindings,
    positional_params,
)
from repro.analysis.diagnostics import Diagnostic

_JIT_CALLS = {"jax.jit", "jit", "pl.pallas_call", "pallas_call", "jax.pmap"}
_CORE_SUFFIXES = ("_epoch_core", "_epochs_core")
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_STATIC_PROBES = {"len", "isinstance"}
_KEY_FUNCS = {"static_key", "runner_static_key", "runner_key"}
_ARRAYISH_ROOTS = ("jnp.", "np.", "numpy.", "jax.numpy.", "jax.random.")
_UNHASHABLE_CALLS = {"list", "dict", "set", "sorted"}
_UNHASHABLE_NODES = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp, ast.GeneratorExp)


def _is_arrayish(expr: ast.AST) -> bool:
    """Heuristic: does this bound value look like device/host array data?"""
    if isinstance(expr, ast.Call):
        name = call_name(expr)
        if name is None:
            return False
        if name.startswith(_ARRAYISH_ROOTS):
            return True
        if name.endswith(".data_args") or name.endswith(".load_data"):
            return True
    if isinstance(expr, (ast.Tuple, ast.List)):
        return any(_is_arrayish(el) for el in expr.elts)
    if isinstance(expr, ast.Subscript):
        return _is_arrayish(expr.value)
    return False


def _is_jit_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        name = dotted_name(dec)
        if name in ("jax.jit", "jit"):
            return True
        if isinstance(dec, ast.Call):
            name = call_name(dec)
            if name in ("jax.jit", "jit"):
                return True
            if name in ("partial", "functools.partial") and dec.args:
                inner = dotted_name(dec.args[0])
                if inner in ("jax.jit", "jit"):
                    return True
    return False


def _tracer_refs(node: ast.AST, tracers: set) -> List[ast.Name]:
    """Tracer-name loads in a conditional's test, pruning static probes
    (.shape/.ndim/.dtype/.size, len(), isinstance())."""
    if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
        return []
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in _STATIC_PROBES:
            return []
    refs: List[ast.Name] = []
    if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
            and node.id in tracers):
        refs.append(node)
    for child in ast.iter_child_nodes(node):
        refs.extend(_tracer_refs(child, tracers))
    return refs


def _find_unhashable(node: ast.AST) -> Optional[ast.AST]:
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in ("tuple", "frozenset") and len(node.args) == 1:
            return None  # explicit conversion to a hashable container
        if name in _UNHASHABLE_CALLS:
            return node
    if isinstance(node, _UNHASHABLE_NODES):
        return node
    for child in ast.iter_child_nodes(node):
        hit = _find_unhashable(child)
        if hit is not None:
            return hit
    return None


def _scopes_with_bindings(tree: ast.AST) -> Dict[int, dict]:
    """id(scope node) -> local simple-assignment bindings, module included."""
    scopes = {id(tree): local_bindings(tree)}
    for node in ast.walk(tree):
        if isinstance(node, FUNC_NODES):
            scopes[id(node)] = local_bindings(node)
    return scopes


def _enclosing_scope(tree: ast.AST) -> Dict[int, ast.AST]:
    """id(node) -> nearest enclosing function (or module) for every node."""
    owner: Dict[int, ast.AST] = {}

    def visit(node: ast.AST, scope: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            owner[id(child)] = scope
            visit(child, child if isinstance(child, FUNC_NODES) else scope)

    owner[id(tree)] = tree
    visit(tree, tree)
    return owner


def check(path: str, tree: ast.AST, source: str) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    bindings_by_scope = _scopes_with_bindings(tree)
    owner = _enclosing_scope(tree)

    for node in ast.walk(tree):
        # 1. array captures into jit/pallas lambdas
        if isinstance(node, ast.Call) and call_name(node) in _JIT_CALLS:
            for arg in node.args[:1]:
                if not isinstance(arg, ast.Lambda):
                    continue
                scope = owner.get(id(node), tree)
                bindings = bindings_by_scope.get(id(scope), {})
                seen = set()
                for ref in free_names(arg):
                    if ref.id in seen:
                        continue
                    seen.add(ref.id)
                    bound = bindings.get(ref.id)
                    if bound is not None and _is_arrayish(bound):
                        out.append(Diagnostic(
                            path, arg.lineno, "RL002",
                            f"jitted lambda closes over array-valued "
                            f"{ref.id!r} — captured arrays key the jit "
                            "cache by object id and pin memory; pass it "
                            "as a runtime argument instead"))

        # 2. python control flow on tracer params in traced cores
        if isinstance(node, FUNC_NODES) and (
                node.name.endswith(_CORE_SUFFIXES)
                or _is_jit_decorated(node)):
            tracers = set(positional_params(node))
            if tracers:
                for sub in ast.walk(node):
                    if isinstance(sub, (ast.If, ast.While)):
                        for ref in _tracer_refs(sub.test, tracers):
                            out.append(Diagnostic(
                                path, sub.lineno, "RL002",
                                f"Python `{type(sub).__name__.lower()}` on "
                                f"tracer param {ref.id!r} in traced core "
                                f"{node.name!r} — positional params are "
                                "tracers (statics go after `*`); use "
                                "lax.cond/jnp.where or make it kw-only"))
                            break

        # 3. unhashable values returned from cache-key functions
        if isinstance(node, FUNC_NODES) and node.name in _KEY_FUNCS:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Return) and sub.value is not None:
                    hit = _find_unhashable(sub.value)
                    if hit is not None:
                        out.append(Diagnostic(
                            path, sub.lineno, "RL002",
                            f"{node.name}() returns an unhashable "
                            "container — cache keys must be hashable; "
                            "wrap in tuple(...)/frozenset(...)"))
    return out
