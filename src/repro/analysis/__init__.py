"""repro-lint: AST-enforced house invariants for the sweep stack.

``python -m repro.analysis [paths]`` — a ruff-style checker for the
contracts the type system cannot carry:

  RL001  vmap-bitwise-stable math in *_stable / loss_fixed_order scopes
  RL002  trace-safety of jit/pallas-reachable functions
  RL003  guarded-by lock discipline in the service/server tier
  RL004  group/runner cache-key completeness (the buf_len bug class)
  RL005  Pallas kernel-module purity
  RL000  suppression hygiene (reasons mandatory, stale ignores reported)

Per-line escapes: ``# repro-lint: ignore[RL002] <why it is fine>``.
Contracts are documented in docs/INVARIANTS.md. The package is
stdlib-only so the CI lane needs no installs.
"""
from repro.analysis.diagnostics import RULES, Diagnostic
from repro.analysis.engine import LintResult, lint_paths, lint_source

__all__ = ["RULES", "Diagnostic", "LintResult", "lint_paths",
           "lint_source"]
