import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) cell:
  1. build the production mesh (16,16) or (2,16,16),
  2. construct ShapeDtypeStruct stand-ins for params / train state / KV
     caches / batches — NO device allocation ever happens for full-size
     models,
  3. jit(...).lower(...).compile() the cell's step function
     (train_step / prefill / decode_step),
  4. record memory_analysis(), cost_analysis() and the collective-bytes
     parse of the post-SPMD HLO into experiments/dryrun/*.json —
     the roofline table (EXPERIMENTS.md §Roofline) is generated from these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single multi --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import SHAPE_GRID, SVRGConfig, ShapeConfig, TrainConfig
from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    collective_bytes_with_trips, count_params, jaxpr_cost, model_flops,
    parse_collective_bytes)
from repro.models.factory import build_model
from repro.sharding.context import mesh_context
from repro.sharding.rules import defs_to_shape_structs, defs_to_shardings
from repro.train.state import make_train_state_defs, make_train_step
from repro.utils.misc import log

ARCHS = [
    "whisper-large-v3", "chatglm3-6b", "stablelm-12b", "gemma3-4b",
    "command-r-plus-104b", "qwen3-moe-235b-a22b", "deepseek-moe-16b",
    "llama-3.2-vision-11b", "recurrentgemma-2b", "falcon-mamba-7b",
]

SUBQUADRATIC = {"recurrentgemma-2b", "falcon-mamba-7b"}


def cell_skip_reason(arch: str, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and arch not in SUBQUADRATIC:
        return "full-attention arch: 500k decode is quadratic (DESIGN.md §5)"
    return None


# gradient-accumulation splits for train_4k, sized so activations fit
# 16 GB/chip (recorded in EXPERIMENTS.md; microbatching is the standard
# lever — MaxText does the same)
MICROBATCHES = {
    "command-r-plus-104b": 8,
    "qwen3-moe-235b-a22b": 8,
    "llama-3.2-vision-11b": 8,
    "deepseek-moe-16b": 4,
    "stablelm-12b": 4,
    "chatglm3-6b": 2,
    "recurrentgemma-2b": 2,
    "gemma3-4b": 2,
    "falcon-mamba-7b": 2,
    "whisper-large-v3": 1,
}


def lower_cell(arch: str, shape: ShapeConfig, mesh, variant: str = "svrg",
               microbatches: int = 0):
    """Returns (lowered, aux) for one cell."""
    cfg = get_config(arch)
    bundle = build_model(cfg)

    if shape.kind == "train":
        tcfg = TrainConfig(optimizer="svrg" if variant == "svrg" else variant,
                           learning_rate=1e-3,
                           microbatches=microbatches or MICROBATCHES.get(arch, 1),
                           svrg=SVRGConfig())
        state_defs = make_train_state_defs(bundle, tcfg)
        state = defs_to_shape_structs(state_defs, mesh)
        state_sh = defs_to_shardings(state_defs, mesh)
        batch = bundle.input_specs(shape, mesh)
        step = make_train_step(bundle, tcfg)
        # out_shardings pins the output state (params, snapshots, opt moments)
        # to the input layout — without it the backward pass materializes
        # REPLICATED f32 gradients per device (observed +24 GiB on chatglm).
        metrics_sh = {"loss": None, "v_norm": None, "lr": None}
        with mesh_context(mesh):
            lowered = jax.jit(
                step, donate_argnums=(0,),
                out_shardings=(state_sh, metrics_sh)).lower(state, batch)
            jcost = jaxpr_cost(jax.make_jaxpr(step)(state, batch))
        return lowered, {"defs": bundle.param_defs, "cfg": cfg,
                         "jaxpr_cost": jcost}

    # serving cells: params in activation dtype (bf16)
    params = defs_to_shape_structs(bundle.param_defs, mesh, dtype=cfg.dtype)
    cache_d = bundle.cache_defs(shape.global_batch, shape.seq_len)
    cache_sh = defs_to_shardings(cache_d, mesh)
    if shape.kind == "prefill":
        batch = bundle.input_specs(shape, mesh)

        def fn(p, b):
            return bundle.prefill_fn(p, b, shape.seq_len)

        with mesh_context(mesh):
            lowered = jax.jit(fn, out_shardings=(None, cache_sh)).lower(
                params, batch)
            jcost = jaxpr_cost(jax.make_jaxpr(fn)(params, batch))
        return lowered, {"defs": bundle.param_defs, "cfg": cfg,
                         "jaxpr_cost": jcost}

    # decode: out_shardings must match the donated input cache layout or the
    # donation can't alias and the cache is copied (+4.3 GiB on command-r)
    cache = defs_to_shape_structs(cache_d, mesh)
    from jax.sharding import NamedSharding
    from repro.sharding.rules import logical_to_pspec
    tokens = jax.ShapeDtypeStruct(
        (shape.global_batch,), jnp.int32,
        sharding=NamedSharding(mesh, logical_to_pspec(
            (shape.global_batch,), ("batch",), mesh)))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    with mesh_context(mesh):
        lowered = jax.jit(bundle.decode_fn, donate_argnums=(1,),
                          out_shardings=(None, cache_sh)).lower(
            params, cache, tokens, pos)
        jcost = jaxpr_cost(jax.make_jaxpr(bundle.decode_fn)(
            params, cache, tokens, pos))
    return lowered, {"defs": bundle.param_defs, "cfg": cfg,
                     "jaxpr_cost": jcost}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             variant: str = "svrg") -> Dict:
    shape = SHAPE_GRID[shape_name]
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "status": "ok",
    }
    skip = cell_skip_reason(arch, shape)
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        _write(record, out_dir)
        return record

    t0 = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        record["num_devices"] = mesh.size
        lowered, aux = lower_cell(arch, shape, mesh, variant)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_per_device_bytes": int(
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        record["cost"] = {k: float(v) for k, v in ca.items()
                          if k in ("flops", "bytes accessed",
                                   "optimal_seconds", "utilization")}
        hlo = compiled.as_text()
        record["hlo_bytes"] = len(hlo)
        record["collectives"] = parse_collective_bytes(hlo)
        record["collectives_trips"] = collective_bytes_with_trips(hlo)
        record["jaxpr_cost"] = aux["jaxpr_cost"]   # GLOBAL flops/bytes
        total, active = count_params(aux["cfg"], aux["defs"])
        record["params_total"] = total
        record["params_active"] = active
        record["model_flops"] = model_flops(aux["cfg"], shape, aux["defs"])
        record["t_lower_s"] = round(t_lower, 2)
        record["t_compile_s"] = round(t_compile, 2)
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    _write(record, out_dir)
    return record


def _write(record: Dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{record['mesh']}__{record['arch']}__{record['shape']}"
        + (f"__{record['variant']}" if record.get("variant", "svrg") != "svrg" else "")
        + ".json")
    slim = {k: v for k, v in record.items() if k != "traceback"}
    with open(path, "w") as f:
        json.dump(slim, f, indent=1)
    status = record["status"]
    extra = ""
    if status == "ok":
        peak = record["memory"]["peak_per_device_bytes"] / 2**30
        extra = (f" peak={peak:.2f}GiB/dev flops/dev={record['cost'].get('flops', 0):.3g}"
                 f" colls={record['collectives'].get('count', 0)}"
                 f" compile={record['t_compile_s']}s")
    elif status == "failed":
        extra = " " + record["error"][:200]
    log(f"[{status}] {record['mesh']} {record['arch']} {record['shape']}{extra}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"],
                    choices=["single", "multi"])
    ap.add_argument("--variant", default="svrg",
                    help="train-step optimizer variant (svrg|sgd|adamw)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCHS if args.arch == ["all"] else args.arch
    shapes = list(SHAPE_GRID) if args.shape == ["all"] else args.shape

    n_ok = n_skip = n_fail = 0
    for mesh_kind in args.mesh:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind, args.out, args.variant)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_fail += rec["status"] == "failed"
    log(f"dry-run done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
