"""Batched serving example: prefill a batch of prompts through a reduced
arch (any of the 10 assigned, --arch selectable) and decode with the KV
cache / recurrent-state path.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b
    PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import list_configs, reduced_config
from repro.models.factory import build_model
from repro.serve.loop import generate
from repro.sharding.rules import init_from_defs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b",
                    choices=[a for a in list_configs() if a != "paper-logreg"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    bundle = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_from_defs(key, bundle.param_defs)

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_feats"] = np.ones(
            (args.batch, cfg.encoder_seq, cfg.encoder_feature_dim), np.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = np.ones(
            (args.batch, cfg.num_image_tokens, cfg.image_embed_dim), np.float32)

    cache_len = args.prompt_len + args.new_tokens
    t0 = time.perf_counter()
    out = generate(bundle, params, batch, args.new_tokens, cache_len)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} family={cfg.family}")
    print(f"generated {out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s, includes compile)")
    print("first rows:", np.asarray(out)[:2, :10])


if __name__ == "__main__":
    main()
