"""Objectives: the pluggable protocol the whole sweep stack optimizes, and
the paper's own instance (L2-regularized logistic regression, paper §5):

    f(w) = (1/n) Σ_i log(1 + exp(-y_i x_i·w)) + (λ/2)||w||²

The engine (`repro.core.asysvrg` / `repro.core.hogwild` / `repro.core.sweep`
and the service/server tiers above them) is objective-agnostic: anything
implementing :class:`Objective` — pytree params ``w``, per-sample gradients,
a fixed-order loss — runs through the same compiled sweep groups, the same
runner cache, and the same HTTP tier. `repro.core.objectives` adds an MLP
LM and a nonconvex-regularized logistic objective on top of this protocol.

## The vmap-bitwise-stable contract

The sweep engine runs a batch of configurations through `jax.vmap` and must
reproduce the sequential driver BIT-identically — and a row's bits must not
depend on which other rows share the batch (that is what makes request
coalescing, stable-width padding and row sharding bit-exact). XLA:CPU keeps
row-reduces over a trailing axis and elementwise ops bitwise-stable under
an added leading batch axis, but changes the summation order of full
reductions to a scalar (jnp.mean, jnp.vdot, X @ w). Every `Objective`
implementation must therefore build its ``*_stable`` methods from:

  * elementwise ops and broadcasts;
  * single-axis reduces over a TRAILING axis (row-reduces, logsumexp,
    keepdims-mean) — express a matmul ``x @ W`` as
    ``sum(x[..., None, :] * W.T, axis=-1)`` when its bits matter;
  * `_fixed_order_sum` (a lax.scan) for any accumulation to a scalar or
    across samples.

`jax.grad` of a function built from these pieces stays stable (pinned by
tests/test_objective_protocol.py). The contract is CALIBRATED ON XLA:CPU;
re-validate per backend.
"""
from __future__ import annotations

import zlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_ravel, tree_unravel_fn


def _log1pexp(z):
    """Numerically stable log(1 + e^z)."""
    return jnp.logaddexp(0.0, z)


# ---------------------------------------------------------------------------
# vmap-bitwise-stable formulations (used by the AsySVRG engine + sweep)
#
# The sweep engine (repro.core.sweep) runs a batch of configurations through
# jax.vmap and must reproduce the sequential driver BIT-identically. XLA:CPU
# keeps row-reduces over a trailing axis and elementwise ops bitwise-stable
# under an added leading batch axis, but changes the summation order of
# full reductions to a scalar (jnp.mean, jnp.vdot, X @ w). The functions
# below therefore use only row-reduces plus a fixed-order lax.scan for
# scalar accumulation.
# ---------------------------------------------------------------------------

def _fixed_order_sum(v):
    """Σ v_i accumulated strictly in index order (vmap-bitwise-stable)."""
    acc, _ = jax.lax.scan(lambda a, x: (a + x, None),
                          jnp.zeros((), v.dtype), v)
    return acc


def _margins_stable(X, y, w):
    """y ⊙ (X w) as a row-reduce (stable under a leading batch axis on w)."""
    return y * jnp.sum(X * w[None, :], axis=1)


def loss_fixed_order(X, y, l2: float, w):
    """f(w) with fixed-order reductions; equals LogisticRegression.loss up to
    summation order (differences are O(n·eps))."""
    t = _log1pexp(-_margins_stable(X, y, w))
    n = X.shape[0]
    return _fixed_order_sum(t) / n + 0.5 * l2 * _fixed_order_sum(w * w)


def full_grad_stable(X, y, l2: float, w):
    """∇f(w) via row-reduces only (vmap-bitwise-stable)."""
    n = X.shape[0]
    s = jax.nn.sigmoid(-_margins_stable(X, y, w))
    return jnp.sum((-(y * s))[:, None] * X, axis=0) / n + l2 * w


def sample_grad_stable(X, y, l2: float, w, i):
    """∇f_i(w) (vmap-bitwise-stable)."""
    x = X[i]
    yi = y[i]
    s = jax.nn.sigmoid(-yi * jnp.sum(x * w, axis=-1))
    return -yi * s * x + l2 * w


# ---------------------------------------------------------------------------
# The pluggable objective protocol
# ---------------------------------------------------------------------------

class Objective:
    """Base class for pluggable objectives: pytree params, per-sample grads.

    A subclass provides the PURE pieces (they receive ``data`` — the tuple
    `data_args` returns — as an argument and must not read arrays off
    ``self``; only static config may live in the closure, so that two
    instances with equal `runner_static_key` trace identical programs and
    share one cached runner across tenants):

      * ``n`` — number of samples (set in ``__init__``);
      * :meth:`data_args` — tuple of jnp arrays/scalars entering the
        compiled runner as RUNTIME arguments (replicated under shard_map);
      * :meth:`init_params` — the w₀ pytree (single array, or a possibly
        nested dict of same-dtype arrays);
      * :meth:`loss_fixed_order(data, w)` — f(w), fixed-order reductions;
      * :meth:`full_grad_stable(data, w)` — ∇f(w) as a pytree;
      * :meth:`sample_grad_stable(data, i, w)` — ∇f_i(w) as a pytree;
      * :meth:`static_key` — hashable tuple of everything (beyond data
        shapes) that changes the traced program.

    All three math methods must obey the vmap-bitwise-stable contract in
    the module docstring.

    The base supplies the flat-vector adapters the engine actually calls
    (`flat_loss` / `flat_full_grad` / `flat_sample_grad` — ravel/unravel
    is bit-exact data movement, see `repro.utils.tree`), fingerprinting
    for cache/checkpoint keys, and serializable `param_shapes` metadata
    the wire format round-trips.
    """

    n: int

    # -- subclass-provided pieces -------------------------------------------
    def data_args(self) -> Tuple:
        raise NotImplementedError

    def init_params(self):
        raise NotImplementedError

    def loss_fixed_order(self, data, w):                  # noqa: ARG002
        raise NotImplementedError

    def full_grad_stable(self, data, w):                  # noqa: ARG002
        raise NotImplementedError

    def sample_grad_stable(self, data, i, w):             # noqa: ARG002
        raise NotImplementedError

    def static_key(self) -> Tuple:
        return ()

    # -- sizing / template (cached: shapes are static per instance) ---------
    @property
    def _template(self):
        tpl = getattr(self, "_template_cache", None)
        if tpl is None:
            tpl = self.init_params()
            self._template_cache = tpl
        return tpl

    @property
    def flat_dim(self) -> int:
        """Total parameter count — the engine's per-row vector width."""
        return int(sum(int(np.prod(x.shape)) if x.shape else 1
                       for x in jax.tree.leaves(self._template)))

    def num_samples(self, data) -> int:
        """n, derived from the runtime data (trace-time constant). The
        default assumes the first data arg is sample-leading."""
        return data[0].shape[0]

    # -- flat <-> pytree bridge ---------------------------------------------
    def ravel_params(self, tree):
        return tree_ravel(tree)

    def unravel_params(self, flat):
        fn = getattr(self, "_unravel_cache", None)
        if fn is None:
            fn = tree_unravel_fn(self._template)
            self._unravel_cache = fn
        return fn(flat)

    def as_flat(self, w):
        """Accept params as a pytree OR an already-flat vector."""
        if (hasattr(w, "ndim") and getattr(w, "ndim", None) == 1
                and not isinstance(w, (dict, list, tuple))):
            w = jnp.asarray(w)
            if w.shape[0] != self.flat_dim:
                raise ValueError(
                    f"flat params have {w.shape[0]} entries, objective "
                    f"expects {self.flat_dim}")
            return w
        return self.ravel_params(w)

    def init_flat(self):
        return self.ravel_params(self.init_params())

    # -- engine-facing flat adapters ----------------------------------------
    # Subclasses whose params ARE a flat vector (logreg and friends) should
    # override these to call their math directly — zero indirection, and
    # the compiled graph is unchanged from the pre-protocol engine.
    def flat_loss(self, data, w_flat):
        return self.loss_fixed_order(data, self.unravel_params(w_flat))

    def flat_full_grad(self, data, w_flat):
        return self.ravel_params(
            self.full_grad_stable(data, self.unravel_params(w_flat)))

    def flat_sample_grad(self, data, i, w_flat):
        return self.ravel_params(
            self.sample_grad_stable(data, i, self.unravel_params(w_flat)))

    # -- serial-driver conveniences (pytree in, pytree out) ------------------
    # Defaults delegate to the stable math; subclasses may override with
    # faster (non-vmap-stable) formulations for standalone use.
    def loss(self, w):
        return self.loss_fixed_order(self.data_args(), w)

    def full_grad(self, w):
        return self.full_grad_stable(self.data_args(), w)

    def sample_grad(self, w, i):
        return self.sample_grad_stable(self.data_args(), i, w)

    # -- identity ------------------------------------------------------------
    def runner_static_key(self) -> Tuple:
        """Hashable program identity (joined with data shapes/dtypes in the
        runner-cache key): instances agreeing here MUST trace identical
        group programs."""
        return (type(self).__name__,) + tuple(self.static_key())

    def fingerprint(self) -> int:
        """Digest of the objective's identity AND its numeric data (pytree-
        general: every `data_args` leaf's bytes). Joins the sweep group key
        — rows of different objectives never share a compiled group — and
        pins checkpoint-resume jobs to their exact dataset. Memoized: the
        data is immutable for the objective's lifetime."""
        fp = getattr(self, "_fingerprint_cache", None)
        if fp is None:
            fp = zlib.crc32(repr(self.runner_static_key()).encode())
            for leaf in jax.tree.leaves(self.data_args()):
                arr = np.ascontiguousarray(np.asarray(leaf))
                fp = zlib.crc32(arr.tobytes(),
                                zlib.crc32(str(arr.dtype).encode(), fp))
            self._fingerprint_cache = fp
        return fp

    def param_shapes(self) -> Tuple:
        """Serializable ((path, shape, dtype), ...) description of the param
        pytree — `SweepResult` carries it so a wire round-trip can rebuild
        pytree params bit-exactly. A single bare array is ``(("", shape,
        dtype),)``; dict trees use "/"-joined key paths."""
        out = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._template)[0]:
            keys = []
            for entry in path:
                key = getattr(entry, "key", getattr(entry, "idx", None))
                keys.append(str(key))
            out.append(("/".join(keys), tuple(leaf.shape), str(leaf.dtype)))
        return tuple(out)


def params_from_flat(flat: np.ndarray, param_shapes):
    """Rebuild a param pytree from a flat vector + `Objective.param_shapes`
    metadata (numpy-side; the wire-format consumer). A single unnamed leaf
    comes back as the bare (reshaped) array; named leaves as a nested dict."""
    if not param_shapes:
        return flat
    arrays = []
    off = 0
    for _, shape, dtype in param_shapes:
        size = int(np.prod(shape)) if shape else 1
        arrays.append(np.asarray(flat[off:off + size], dtype)
                      .reshape(tuple(shape)))
        off += size
    if off != len(flat):
        raise ValueError(f"param_shapes cover {off} entries, flat vector "
                         f"has {len(flat)}")
    if len(param_shapes) == 1 and param_shapes[0][0] == "":
        return arrays[0]
    tree: Dict = {}
    for (path, _, _), arr in zip(param_shapes, arrays):
        node = tree
        keys = path.split("/")
        for key in keys[:-1]:
            node = node.setdefault(key, {})
        node[keys[-1]] = arr
    return tree


# ---------------------------------------------------------------------------
# Named-objective registry (the service/server tier's wire addressing):
# `SweepSpec.objective` names a registered instance; empty string means
# "the call's default objective".
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, "Objective"] = {}


def register_objective(name: str, obj: "Objective") -> "Objective":
    """Register an objective instance under ``name`` (re-registering a name
    replaces it — tests and notebook reloads rebuild objectives freely)."""
    if not name:
        raise ValueError("objective name must be non-empty")
    if not isinstance(obj, Objective):
        raise TypeError(f"expected an Objective, got {type(obj).__name__}")
    _REGISTRY[name] = obj
    return obj


def get_objective(name: str) -> "Objective":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no objective registered under {name!r} "
            f"(registered: {sorted(_REGISTRY)})") from None


def registered_objectives() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def unregister_objective(name: str) -> None:
    _REGISTRY.pop(name, None)


class LogisticRegression(Objective):
    """Stateless objective bound to a dataset (X, y, λ) — the paper's own
    workload, now one `Objective` among several. Params are a single flat
    (p,) vector, so the flat adapters below bypass the generic
    ravel/unravel entirely: the engine's compiled graphs are IDENTICAL to
    the pre-protocol ones (regression-pinned in
    tests/test_objective_protocol.py)."""

    def __init__(self, X, y, l2_reg: float = 1e-4):
        self.X = jnp.asarray(X)
        self.y = jnp.asarray(y)
        self.l2 = float(l2_reg)
        self.n, self.p = self.X.shape

    # -- protocol ------------------------------------------------------------
    def data_args(self) -> Tuple:
        return (self.X, self.y, jnp.float32(self.l2))

    def init_params(self):
        return jnp.zeros(self.p)

    def static_key(self) -> Tuple:
        return ()

    def loss_fixed_order(self, data, w):
        X, y, l2 = data
        return loss_fixed_order(X, y, l2, w)

    def full_grad_stable(self, data, w):
        X, y, l2 = data
        return full_grad_stable(X, y, l2, w)

    def sample_grad_stable(self, data, i, w):
        X, y, l2 = data
        return sample_grad_stable(X, y, l2, w, i)

    # flat == pytree for a (p,) parameter vector: skip the generic bridge
    flat_loss = loss_fixed_order
    flat_full_grad = full_grad_stable

    def flat_sample_grad(self, data, i, w_flat):
        X, y, l2 = data
        return sample_grad_stable(X, y, l2, w_flat, i)

    # -- objective ---------------------------------------------------------
    def loss(self, w) -> jnp.ndarray:
        margins = self.y * (self.X @ w)
        return jnp.mean(_log1pexp(-margins)) + 0.5 * self.l2 * jnp.vdot(w, w)

    # -- gradients ---------------------------------------------------------
    def full_grad(self, w) -> jnp.ndarray:
        """∇f(w) — the snapshot full gradient of Algorithm 1."""
        margins = self.y * (self.X @ w)
        s = jax.nn.sigmoid(-margins)             # σ(-y x·w)
        return (-(self.y * s) @ self.X) / self.n + self.l2 * w

    def partial_full_grad(self, w, lo: int, size: int) -> jnp.ndarray:
        """Partitioned full-gradient contribution (one thread's φ_a).

        Returns an UN-normalized sum over rows [lo, lo+size); the caller sums
        the partitions and divides by n — exactly the paper's parallel
        snapshot pass.
        """
        Xs = jax.lax.dynamic_slice_in_dim(self.X, lo, size, 0)
        ys = jax.lax.dynamic_slice_in_dim(self.y, lo, size, 0)
        margins = ys * (Xs @ w)
        s = jax.nn.sigmoid(-margins)
        return -(ys * s) @ Xs

    def sample_grad(self, w, i) -> jnp.ndarray:
        """∇f_i(w) for one instance (the paper's inner-loop gradient)."""
        x = self.X[i]
        yi = self.y[i]
        s = jax.nn.sigmoid(-yi * jnp.dot(x, w))
        return -yi * s * x + self.l2 * w

    def minibatch_grad(self, w, idx) -> jnp.ndarray:
        """Mean gradient over a batch of indices (beyond-paper batching)."""
        Xb = self.X[idx]
        yb = self.y[idx]
        s = jax.nn.sigmoid(-yb * (Xb @ w))
        return (-(yb * s) @ Xb) / idx.shape[0] + self.l2 * w

    # -- constants for the theory-facing tests ------------------------------
    def smoothness(self) -> float:
        row_sq = jnp.sum(self.X * self.X, axis=1)
        return float(jnp.max(row_sq) / 4.0 + self.l2)

    def strong_convexity(self) -> float:
        return self.l2

    def optimum(self, tol: float = 1e-12, max_iter: int = 5000) -> Tuple[jnp.ndarray, float]:
        """High-accuracy reference optimum via deterministic gradient descent
        with backtracking-free fixed step 1/L (used to compute the paper's
        "gap < 1e-4" stopping metric)."""
        L = self.smoothness()
        step = 1.0 / L

        def body(carry, _):
            w, = carry
            g = self.full_grad(w)
            return (w - step * g,), None

        (w,), _ = jax.lax.scan(body, (jnp.zeros(self.p),), None, length=max_iter)
        return w, float(self.loss(w))
