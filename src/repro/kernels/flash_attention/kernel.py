"""Pallas TPU flash-attention (prefill) kernel.

TPU adaptation of the flash algorithm (no warps / shared-memory banking —
VMEM block streaming + online softmax instead):

  grid = (B*H, nq, nk) with the kv axis innermost and SEQUENTIAL
  ("arbitrary" dimension semantics): the kernel carries the running max m,
  normalizer l and output accumulator across kv blocks in VMEM scratch,
  rescaling on each new block (the standard online-softmax recurrence).
  Causal/windowed masking is computed from block indices; fully-masked
  kv blocks are skipped via pl.when (the causal lower-triangle saves ~2x).

Block sizes default to (128, 512): q-tile 128 rows aligns the MXU; the kv
tile bounds VMEM at ~ (128·d + 512·d·2 + 128·512) · 4B ≈ 1.3 MiB for d=128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import tpu_compiler_params

DEFAULT_BQ = 128
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # skip blocks that are fully masked (above the causal diagonal /
    # left of the local window)
    relevant = True
    if causal:
        relevant = k_start <= q_start + bq - 1
    if window > 0:
        relevant = jnp.logical_and(
            relevant, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # [bq, d]
        k = k_ref[0].astype(jnp.float32)              # [bk, d]
        v = v_ref[0].astype(jnp.float32)              # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= pos_q >= pos_k
        if window > 0:
            ok &= (pos_q - pos_k) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                           # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                        # [bq, bk]
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l_sum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l_sum).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q,k,v [BH, S, d] -> [BH, S, d]. S % max(bq,bk) == 0."""
    BH, S, d = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # running normalizer l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
