"""Logical-axis sharding rules (MaxText-style) and the ParamDef system.

Models declare parameters as :class:`ParamDef` pytrees: shape + logical axis
names + initializer. The launcher turns logical names into
``PartitionSpec``/``NamedSharding`` via a rule table, so the SAME model code
runs on a 1-chip CPU smoke test, a 256-chip pod, or a multi-pod mesh — only
the rules/mesh change.

Sharding strategy (defaults):
  * ``fsdp``-tagged dims shard over ("pod","data")  — ZeRO-3 style weight
    sharding: required to fit 104B/235B params + SVRG snapshot state.
  * ``tp``-tagged dims (heads / mlp / vocab / expert) shard over "model".
  * batch shards over ("pod","data"); sequence optionally over "model"
    (long-context cells).
A dim whose size does not divide the assigned mesh axes falls back to
replication (GSPMD would pad, but an explicit fallback keeps memory
analysis honest).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim (None = replicated)
    init: str = "normal"                 # normal | zeros | ones | scaled | embed
    scale: float = 1.0
    dtype: str = "float32"

    def __repr__(self):  # compact for debugging
        return f"ParamDef({self.shape}, {self.axes}, {self.init})"


def is_param_def(x) -> bool:
    return isinstance(x, ParamDef)


# Logical axis name -> mesh axis (or tuple of mesh axes). None = replicated.
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "model",          # sequence-parallel KV cache (long context)
    "vocab": "model",
    "embed": ("pod", "data"),      # fsdp dim of most weights
    "embed_no_fsdp": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "expert": "model",
    "expert_mlp": None,
    "cache_kv": None,
    "layers": None,
    "conv": None,
    "state": None,
    "features": "model",           # logreg feature dim
}


def _axis_size(mesh: Mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    n = 1
    for a in mesh_axes:
        n *= mesh.shape.get(a, 1)
    return n


def _present(mesh: Mesh, mesh_axes):
    """Filter a rule target down to axes that exist in this mesh."""
    if mesh_axes is None:
        return None
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    kept = tuple(a for a in mesh_axes if a in mesh.shape)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_pspec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Dict[str, Any]] = None,
) -> P:
    """Map logical axis names to a PartitionSpec, with divisibility fallback."""
    rules = rules or DEFAULT_RULES
    spec = []
    used = set()
    for dim, name in zip(shape, axes):
        if name is None:
            spec.append(None)
            continue
        target = _present(mesh, rules.get(name))
        if target is None:
            spec.append(None)
            continue
        t_axes = (target,) if isinstance(target, str) else tuple(target)
        if dim % _axis_size(mesh, target) != 0 or used & set(t_axes):
            spec.append(None)        # replicate rather than pad/conflict
        else:
            used.update(t_axes)
            spec.append(target)
    return P(*spec)


def layer_axes_strs(defs):
    """ParamDef tree (stacked layer params) -> tree of axis-name STRINGS with
    the leading "layers" dim dropped, e.g. "embed|mlp". Strings (not tuples)
    so the result is a pytree-leaf-per-param matching the param tree
    structure — consumed by sharding.context.constrain_tree inside scan
    bodies (forces per-layer cotangent sharding; see DESIGN §4)."""
    def enc(d: ParamDef) -> str:
        axes = d.axes[1:] if d.axes and d.axes[0] == "layers" else d.axes
        return "|".join(a or "" for a in axes)

    return jax.tree.map(enc, defs, is_leaf=is_param_def)


def defs_to_shardings(defs, mesh: Mesh, rules=None):
    """ParamDef tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda d: NamedSharding(mesh, logical_to_pspec(d.shape, d.axes, mesh, rules)),
        defs,
        is_leaf=is_param_def,
    )


def defs_to_shape_structs(defs, mesh: Mesh = None, rules=None, dtype=None):
    """ParamDef tree -> ShapeDtypeStruct tree (optionally with shardings).

    This is the dry-run path: no memory is ever allocated for the full-size
    parameters; jit.lower() consumes the structs directly.
    """
    def mk(d: ParamDef):
        dt = jnp.dtype(dtype or d.dtype)
        sharding = None
        if mesh is not None:
            sharding = NamedSharding(mesh, logical_to_pspec(d.shape, d.axes, mesh, rules))
        return jax.ShapeDtypeStruct(d.shape, dt, sharding=sharding)

    return jax.tree.map(mk, defs, is_leaf=is_param_def)


# ---------------------------------------------------------------------------
# Initialization (smoke tests / small-scale training only)
# ---------------------------------------------------------------------------

def _init_one(key, d: ParamDef):
    dt = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "normal":
        fan_in = d.shape[0] if d.shape else 1
        std = d.scale / math.sqrt(max(1, fan_in))
        return (jax.random.normal(key, d.shape) * std).astype(dt)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape) * d.scale).astype(dt)
    if d.init == "scaled":
        return (jax.random.normal(key, d.shape) * d.scale).astype(dt)
    raise ValueError(f"unknown init {d.init}")


def init_from_defs(key, defs):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_param_def)
    keys = jax.random.split(key, len(leaves))
    inited = [_init_one(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, inited)


# ---------------------------------------------------------------------------
# Activation helpers
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, *, seq_axis: Optional[str] = None) -> P:
    """PartitionSpec for (batch, seq, ...) activations."""
    batch = _present(mesh, DEFAULT_RULES["batch"])
    seq = _present(mesh, DEFAULT_RULES.get(seq_axis)) if seq_axis else None
    return P(batch, seq)


def act_sharding_constraint(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that no-ops outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except (ValueError, RuntimeError):
        return x
