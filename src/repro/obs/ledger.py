"""Per-group performance ledger: compile time, FLOPs, attained fraction.

Each compiled group runner (the unit the service caches — one jit per
``(objective, engine, M̃, option, buf_len, fused)`` group at a given
row width and epoch budget) gets one ledger entry recording

* how many dispatches ran through it and how many traced+compiled,
* the wall-clock of the compiling dispatch(es) (``compile_s``) and the
  best warm dispatch (``warm_wall_min_s``),
* FLOPs/bytes from XLA's own ``jit(...).lower().compile()
  .cost_analysis()`` when the backend provides it, falling back to the
  analytic epoch model from :mod:`repro.launch.roofline`,
* the attained-vs-roofline fraction: the roofline step lower bound for
  the group's path (vmap or fused) divided by the best measured warm
  wall time — the live form of the BENCH_kernel_sweep comparison, and
  the signal the multi-host fabric will route cache-affinity on.

The ledger is **opt-in** (``enable_ledger``) and entirely host-side:
the only thing it adds to a dispatch is two ``perf_counter`` reads
bracketing the runner call, gated by one bool (RL006 boundary).  It is
exported as ``repro_ledger_*`` Prometheus series, the ``GET /ledger``
JSON dump, and the schema-gated ``BENCH_progress_ledger.json``.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "LedgerEntry",
    "PerfLedger",
    "ledger",
    "ledger_enabled",
    "enable_ledger",
    "disable_ledger",
    "note_compile",
]

_TLS = threading.local()


def note_compile() -> None:
    """Trace-time hook: ``service.cache._counted`` calls this when the
    wrapped group fn actually traces, so the in-flight
    ``record_dispatch`` on the same thread can attribute the wall time
    it measured to compilation."""
    _TLS.compiled = True


def _take_compiled() -> bool:
    c = getattr(_TLS, "compiled", False)
    _TLS.compiled = False
    return c


@dataclasses.dataclass
class LedgerEntry:
    label: str
    engine: str
    fused: bool
    rows: int
    dim: int
    total: int
    buf_len: int
    epochs: int
    dispatches: int = 0
    compiles: int = 0
    compile_s: float = 0.0        # wall of dispatches that traced+compiled
    wall_s_total: float = 0.0
    warm_wall_min_s: float = 0.0  # best non-compiling dispatch (0 until one lands)
    flops: Optional[float] = None
    bytes: Optional[float] = None
    flops_source: str = ""        # "cost_analysis" | "analytic"
    roofline_s: float = 0.0       # analytic step lower bound for this path

    def attained_frac(self) -> float:
        wall = self.warm_wall_min_s or (
            self.wall_s_total / self.dispatches if self.dispatches else 0.0)
        return self.roofline_s / wall if wall > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "engine": self.engine,
            "fused": int(self.fused),
            "rows": self.rows,
            "dim": self.dim,
            "total": self.total,
            "buf_len": self.buf_len,
            "epochs": self.epochs,
            "dispatches": self.dispatches,
            "compiles": self.compiles,
            "compile_s": self.compile_s,
            "wall_s_total": self.wall_s_total,
            "warm_wall_min_s": self.warm_wall_min_s,
            "flops": self.flops if self.flops is not None else 0.0,
            "bytes": self.bytes if self.bytes is not None else 0.0,
            "roofline_s": self.roofline_s,
            "attained_frac": self.attained_frac(),
        }


def _roofline(entry: LedgerEntry) -> dict:
    # lazy: launch.roofline is analytic stdlib math but lives in a package
    # whose __init__ pulls jax; only touched on the cold path
    from repro.launch.roofline import attained_fraction

    rf = attained_fraction(rows=entry.rows, dim=entry.dim,
                           total=entry.total, epochs=entry.epochs,
                           buf_len=entry.buf_len, fused=entry.fused,
                           wall_s=0.0)
    return {"flops": float(rf["flops"]), "bytes": float(rf["bytes"]),
            "step_lower_bound_s": float(rf["roofline_s"])}


class PerfLedger:
    """Thread-safe map from group/runner identity to a ``LedgerEntry``."""

    def __init__(self, max_entries: int = 256):
        self._lock = threading.Lock()
        self._entries: Dict[Tuple, LedgerEntry] = {}  # guarded-by: _lock
        self._max = max_entries

    def record_dispatch(
        self,
        *,
        key: Tuple,
        rows: int,
        dim: int,
        epochs: int,
        wall_s: float,
        cost_fn: Optional[Callable[[], Optional[dict]]] = None,
    ) -> None:
        """Account one runner call.  ``key`` is the group key from
        ``plan_sweep``; ``rows`` the dispatched (padded) width; ``cost_fn``
        an AOT ``cost_analysis`` thunk, invoked at most once per entry and
        only on the compiling (cold) dispatch so the warm path never pays
        for it."""
        compiled = _take_compiled()
        _, engine, total, option, buf_len, fused = key
        ek = (key, int(rows), int(epochs))
        label = (f"{engine}-{'fused' if fused else 'vmap'}-M{int(total)}"
                 f"-opt{option}-buf{int(buf_len)}-rows{int(rows)}-E{int(epochs)}")
        with self._lock:
            entry = self._entries.get(ek)
            if entry is None:
                if len(self._entries) >= self._max:
                    return
                entry = LedgerEntry(label=label, engine=str(engine),
                                    fused=bool(fused), rows=int(rows),
                                    dim=int(dim), total=int(total),
                                    buf_len=int(buf_len), epochs=int(epochs))
                rf = _roofline(entry)
                entry.roofline_s = rf["step_lower_bound_s"]
                entry.flops = rf["flops"]
                entry.bytes = rf["bytes"]
                entry.flops_source = "analytic"
                self._entries[ek] = entry
            entry.dispatches += 1
            entry.wall_s_total += wall_s
            if compiled:
                entry.compiles += 1
                entry.compile_s += wall_s
            elif entry.warm_wall_min_s == 0.0 or wall_s < entry.warm_wall_min_s:
                entry.warm_wall_min_s = wall_s
            want_cost = compiled and cost_fn is not None \
                and entry.flops_source != "cost_analysis"
        if not want_cost:
            return
        try:
            cost = cost_fn()
        except Exception:
            cost = None
        if not cost:
            return
        flops = cost.get("flops")
        nbytes = cost.get("bytes accessed")
        with self._lock:
            if flops is not None:
                entry.flops = float(flops)
                entry.flops_source = "cost_analysis"
            if nbytes is not None:
                entry.bytes = float(nbytes)

    def snapshot(self) -> Dict[str, dict]:
        """``label -> numeric leaves`` — the shape the Prometheus walker
        fans out under the ``group`` label and ``/ledger`` serves raw."""
        with self._lock:
            entries = list(self._entries.values())
        out: Dict[str, dict] = {}
        for e in entries:
            d = e.as_dict()
            if e.flops_source:
                d["flops_source"] = e.flops_source
            out[e.label] = d
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


_LEDGER = PerfLedger()
_ENABLED = False


def ledger() -> PerfLedger:
    return _LEDGER


def ledger_enabled() -> bool:
    """The one-bool fast path checked at every dispatch site."""
    return _ENABLED


def enable_ledger() -> PerfLedger:
    global _ENABLED
    _ENABLED = True
    return _LEDGER


def disable_ledger(clear: bool = False) -> None:
    global _ENABLED
    _ENABLED = False
    if clear:
        _LEDGER.clear()
