import os

# Smoke tests and benches must see ONE CPU device (the dry-run sets its own
# 512-device flag in its own process). Nothing here touches device counts.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
