"""Divergence watchdog evaluated at slice/flush boundaries.

A diverging row (NaN/Inf in its loss history, or a loss-explosion
ratio past threshold — the nonconvex regime of Reddi et al.,
1506.06840) is detected **after** a group dispatch returns, on the
host-side numpy histories.  Per-tenant policy decides what happens:

``record``
    Mark the row in ``SweepResult.diverged_rows``; keep its outputs.
``cancel_row``
    Freeze the row at its last trusted epoch by re-dispatching the
    group once with the row's epoch budget truncated via the existing
    per-row epoch-mask semantics (``_Resolved._replace(epochs=k)`` —
    ``epochs`` is a runtime array, never a static, so the re-dispatch
    hits the same cached runner with 0 recompiles).  Surviving rows
    keep their **first**-dispatch outputs, so their bit-identity is
    trivially untouched; only the cancelled rows take the re-dispatched
    (genuinely frozen) history and final iterate.
``cancel_job``
    Raise :class:`JobDiverged` — ``run_job`` propagates it and the
    serving daemon fails the job handle.  Coalesced ``flush`` batches
    mix tenants, so there the policy degrades to ``cancel_row``
    (one tenant's divergence must not cancel another's rows).

The watchdog never runs inside a compiled program (RL006): detection
and the freeze decision are pure host code bracketing the dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Watchdog", "JobDiverged", "POLICIES", "first_bad_epoch"]

POLICIES = ("record", "cancel_row", "cancel_job")


class JobDiverged(RuntimeError):
    """Raised under the ``cancel_job`` policy; carries the offenders."""

    def __init__(self, rows: Dict[int, int]):
        self.rows = dict(rows)  # flat row index -> last trusted epoch
        super().__init__(
            "watchdog: job cancelled, diverged rows "
            + ", ".join(f"{r} (last trusted epoch {e})" for r, e in sorted(rows.items()))
        )


def first_bad_epoch(
    history: np.ndarray, epochs: int, explosion_ratio: float
) -> Optional[int]:
    """First epoch ``e >= 1`` whose loss is non-finite or exploded.

    ``history[0]`` is the initial loss (trusted by construction);
    entries past the row's own ``epochs`` budget are frozen re-emits
    and not inspected.  Explosion means ``|loss[e]|`` exceeding
    ``explosion_ratio * max(|loss[0]|, eps)``.
    """
    hist = np.asarray(history, dtype=np.float64)
    limit = min(int(epochs), hist.shape[0] - 1)
    if limit < 1:
        return None
    bound = explosion_ratio * max(abs(float(hist[0])), 1e-12)
    for e in range(1, limit + 1):
        v = float(hist[e])
        if not np.isfinite(v) or abs(v) > bound:
            return e
    return None


@dataclasses.dataclass(frozen=True)
class Watchdog:
    """Divergence policy: a default plus per-tenant overrides."""

    policy: str = "cancel_row"
    explosion_ratio: float = 1e3
    tenant_policies: Optional[Mapping[str, str]] = None

    def __post_init__(self):
        bad = [p for p in (self.policy, *(self.tenant_policies or {}).values())
               if p not in POLICIES]
        if bad:
            raise ValueError(f"unknown watchdog policy {bad[0]!r}; choose from {POLICIES}")
        if self.explosion_ratio <= 0:
            raise ValueError("explosion_ratio must be positive")

    def policy_for(self, tenant: str) -> str:
        if self.tenant_policies:
            return self.tenant_policies.get(tenant, self.policy)
        return self.policy


def enforce_group(
    wd: Watchdog,
    hist: np.ndarray,
    w_fin: np.ndarray,
    *,
    members: Sequence[int],
    resolved: Sequence,
    tenant_of: Callable[[int], str],
    redispatch: Callable[[list], Tuple[np.ndarray, np.ndarray]],
    real: Optional[int] = None,
    allow_cancel_job: bool = True,
) -> Tuple[np.ndarray, np.ndarray, Dict[int, int], Dict[int, int]]:
    """Inspect one dispatched group's histories and apply the policy.

    ``members`` maps local history rows to flat spec indices (it may
    contain width-stabilizing pad duplicates past ``real``); only the
    first ``real`` rows are inspected.  ``redispatch`` re-runs the
    group against an amended resolved list — same static shape, so the
    runner cache stays warm.

    Returns ``(hist, w_fin, diverged, overrides)`` where ``diverged``
    maps flat row -> last trusted epoch for every detected row (any
    policy) and ``overrides`` maps flat row -> truncated epoch budget
    for the rows actually frozen (``cancel_row``).
    """
    real = len(members) if real is None else real
    bad: Dict[int, int] = {}  # local row -> last trusted epoch
    for i in range(real):
        c = members[i]
        e = first_bad_epoch(hist[i], resolved[c].epochs, wd.explosion_ratio)
        if e is not None:
            bad[i] = e - 1
    if not bad:
        return hist, w_fin, {}, {}

    policies = {i: wd.policy_for(tenant_of(members[i])) for i in bad}
    diverged = {int(members[i]): int(k) for i, k in bad.items()}
    if allow_cancel_job and any(p == "cancel_job" for p in policies.values()):
        raise JobDiverged(diverged)

    cancel = {i: bad[i] for i, p in policies.items() if p != "record"}
    overrides: Dict[int, int] = {}
    if cancel:
        amended = list(resolved)
        for i, k in cancel.items():
            c = int(members[i])
            amended[c] = amended[c]._replace(epochs=int(k))
            overrides[c] = int(k)
        hist2, w2 = redispatch(amended)
        hist = np.array(hist, copy=True)
        w_fin = np.array(w_fin, copy=True)
        for i in cancel:
            hist[i] = hist2[i]
            w_fin[i] = w2[i]
    return hist, w_fin, diverged, overrides
