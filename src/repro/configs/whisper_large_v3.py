"""whisper-large-v3 [audio] — enc-dec, conv frontend STUB.
[arXiv:2212.04356; unverified]

32 encoder + 32 decoder layers, d_model=1280, 20 MHA heads (kv=20),
d_ff=5120, vocab=51866. Frontend (mel + 2x conv) is a stub: input_specs()
provides precomputed frame embeddings [B, 1500, 1280].

Deviations recorded: sinusoidal decoder positions instead of whisper's
448-entry learned table (needed for the 32k decode dry-run cells);
bias kept on q/k/v (whisper omits the k bias).
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,          # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    encoder_seq=1500,
    encoder_feature_dim=1280,
    rope_style="none",
    norm="layernorm",
    activation="gelu",
    glu=False,
    use_bias=True,
    use_qkv_bias=True,
    tie_embeddings=True,
))
