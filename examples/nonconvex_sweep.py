"""Nonconvex + pytree workloads through the sweep service.

The objective protocol (`repro.core.Objective`) decouples the async-SVRG
engine from the paper's logistic-regression workload. This example runs the
two bundled beyond-paper objectives end-to-end through the coalescing
`SweepService`:

  * `NonconvexLogistic` — logistic loss + smoothly-clipped (bounded,
    nonconvex) penalty on a libsvm-shaped set; params stay a flat vector.
  * `MLPObjective` (via `mlp_lm_objective`) — a tiny MLP language model on
    the deterministic synthetic-LM corpus; params are a NESTED PYTREE
    {embed, norm, w1, b1, w2}. The engine runs on the bit-exactly flattened
    vector and `SweepResult.final_params` rebuilds the tree.

Both requests land in ONE flush: the group key leads with the objective
fingerprint, so rows for different objectives coalesce in the same dispatch
window without ever sharing a compiled program. The MLP request addresses
its objective BY NAME through the registry (`register_objective`) — the
same addressing an HTTP client uses (`SweepSpec.objective`), so this demo
is one `SweepServer(...)` away from being served over the wire.

    PYTHONPATH=src python examples/nonconvex_sweep.py
"""
import numpy as np

from repro.core import (NonconvexLogistic, SweepSpec, mlp_lm_objective,
                        register_objective)
from repro.data.libsvm import make_synthetic_libsvm
from repro.service import SweepService


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.03)
    ncv = NonconvexLogistic(ds.X, ds.y, lam=1e-3, alpha=10.0)
    mlp = register_objective(
        "tiny-lm", mlp_lm_objective(n=32, vocab_size=16, seq_len=4,
                                    d_model=8, d_hidden=16))
    print(f"nonconvex logistic: n={ncv.n} p={ncv.p}   "
          f"tiny-lm: n={mlp.n} params={mlp.flat_dim}\n")

    # the service holds the nonconvex objective; the MLP request rides in
    # by registry name — one flush, two objectives, zero shared groups
    svc = SweepService(ncv, epochs=3)
    rid_ncv = svc.submit(
        [SweepSpec(scheme="inconsistent", step_size=s, tau=3, num_threads=4)
         for s in (0.5, 1.0, 2.0)], tenant="nonconvex")
    rid_mlp = svc.submit(
        [SweepSpec(scheme="unlock", step_size=s, tau=2, num_threads=4,
                   inner_steps=mlp.n, objective="tiny-lm")
         for s in (0.05, 0.1)], tenant="lm")
    svc.flush()

    res = svc.result(rid_ncv)
    print("nonconvex clipped-penalty logistic (flat params):")
    for c, spec in enumerate(res.specs):
        print(f"  step={spec.step_size:3.1f}: loss "
              f"{res.histories[c, 0]:.4f} -> {res.histories[c, -1]:.4f}")

    res = svc.result(rid_mlp)
    print("\ntiny MLP language model (pytree params, same engine):")
    for c, spec in enumerate(res.specs):
        params = res.final_params(c)             # dict rebuilt bit-exactly
        norms = {k: float(np.linalg.norm(v)) for k, v in params.items()}
        print(f"  step={spec.step_size:4.2f}: loss "
              f"{res.histories[c, 0]:.4f} -> {res.histories[c, -1]:.4f}  "
              f"|embed|={norms['embed']:.3f} |w2|={norms['w2']:.3f}")

    stats = svc.stats()
    print(f"\none flush: {stats.rows_submitted} rows, "
          f"{stats.groups_dispatched} compiled groups "
          "(objectives never share a group)")


if __name__ == "__main__":
    main()
