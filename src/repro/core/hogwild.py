"""Hogwild! (Recht et al. 2011) — the paper's baseline, same delay engine.

Plain asynchronous SGD: v_m = ∇f_{i_m}(û_m) with NO control variate. Run
under the same bounded-delay read semantics so the comparison against
AsySVRG isolates exactly the paper's contribution (variance reduction under
asynchrony). Experiment settings follow the paper §5.1: each epoch runs n/p
iterations per thread (1 effective pass), constant step γ decayed by 0.9
per epoch ("These settings are the same as those in the experiments in
Hogwild!").

Like `repro.core.asysvrg`, the epoch body (`_hogwild_epoch_core`) is written
to be `vmap`-able over a batch of (seed, scheme, step, τ, delay-kind, decay)
configurations: scheme/delay dispatch is data (`read_dispatch` /
`_delay_schedule_core`), every reduction is vmap-bitwise-stable, and the
per-epoch γ ← decay·γ schedule is threaded through the `lax.scan` carry of
`_hogwild_epochs_core` so the whole multi-epoch run — decay included — is
ONE compiled program. `repro.core.sweep` vmaps that program over a config
grid; `run_hogwild` here drives the identical program for a single config,
which is what makes the sweep rows bit-identical to this sequential driver
on XLA:CPU (tests/test_sweep_hogwild.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.asysvrg import (
    _UNLOCK,
    AsyRunResult,
    DELAY_IDS,
    SCHEME_IDS,
    _delay_schedule_core,
    read_dispatch,
)
from repro.core.objective import Objective


def _resolve_hogwild_steps(n: int, num_threads: int, tau: int):
    """(p, total = (n // p)·p, clamped τ) — the ONE place this arithmetic
    lives; `run_hogwild`'s update bookkeeping and the sweep engine both
    derive from it, so the two can never drift."""
    p_threads = max(1, num_threads)
    total = max(1, n // p_threads) * p_threads          # n/p per thread
    tau = (p_threads - 1) if tau < 0 else tau
    tau = max(0, min(tau, total - 1))
    return p_threads, total, tau


def _hogwild_epoch_core(obj: Objective, data, w, key, gamma, tau, scheme_id,
                        delay_id, *, total: int, buf_len: int,
                        drop_prob: float):
    """One Hogwild! epoch (total async updates), vmap-able over configs.

    ``obj``/``data`` follow the same protocol split as
    `asysvrg._epoch_core`: pure methods + static config from ``obj``, every
    numeric input in ``data``, params as the objective's FLAT vector.

    Dynamic (batchable): w, key, gamma, tau, scheme_id, delay_id.
    Static (shared by the batch): total, buf_len ≥ max τ + 1, drop_prob.
    """
    n = obj.num_samples(data)
    dim = w.shape[0]
    k_idx, k_delay, k_scan = jax.random.split(key, 3)
    idx = jax.random.randint(k_idx, (total,), 0, n)
    delays = _delay_schedule_core(delay_id, total, tau, k_delay)
    buffer = jnp.tile(w[None, :], (buf_len, 1))         # slot m%(τ+1) = u_m

    def body(carry, inp):
        u, buffer = carry
        m, i, d, k = inp
        k_read, k_drop = jax.random.split(k)
        a = jnp.maximum(m - d, 0)
        u_read = read_dispatch(scheme_id, buffer, tau, a, m, k_read, dim)
        v = obj.flat_sample_grad(data, i, u_read)
        if drop_prob > 0:
            # unlock write-write race: drop a random coordinate fraction
            keep = jax.random.bernoulli(
                k_drop, 1.0 - drop_prob, (dim,)).astype(u.dtype)
            mask = jnp.where(scheme_id == _UNLOCK, keep, jnp.ones_like(keep))
            v = v * mask
        u_next = u - gamma * v
        buffer = buffer.at[jnp.mod(m + 1, tau + 1)].set(u_next)
        return (u_next, buffer), None

    keys = jax.random.split(k_scan, total)
    ms = jnp.arange(total)
    (u_last, _), _ = jax.lax.scan(body, (w, buffer), (ms, idx, delays, keys))
    return u_last


def _hogwild_epochs_core(obj: Objective, data, w0, key, gamma0, decay, tau,
                         scheme_id, delay_id, *, epochs: int, total: int,
                         buf_len: int, drop_prob: float, row_epochs=None):
    """`epochs` Hogwild! epochs as one `lax.scan`, γ ← decay·γ in the carry.

    Returns (w_final, losses[epochs+1]) with the fixed-order loss recorded
    after every epoch (index 0 = loss at w0) — the decay schedule and the
    history both live INSIDE the compiled program, so a vmap over configs
    batches them too.

    ``row_epochs`` (a dynamic, batchable scalar; default = the static
    ``epochs`` bound) is this config's own epoch budget: once the epoch
    index reaches it the row FREEZES — carry passthrough (w, γ) and masked
    loss writes (the last live loss is re-emitted) — so a sweep row with a
    shorter budget is bit-identical to an independent shorter run while
    scanning to the group's shared static bound.
    """
    loss0 = obj.flat_loss(data, w0)
    bound = jnp.int32(epochs) if row_epochs is None else row_epochs

    def step(carry, e):
        w, key, gamma, loss_prev = carry
        key, sub = jax.random.split(key)
        active = e < bound
        w_new = _hogwild_epoch_core(
            obj, data, w, sub, gamma, tau, scheme_id, delay_id,
            total=total, buf_len=buf_len, drop_prob=drop_prob)
        w_next = jnp.where(active, w_new, w)
        gamma_next = jnp.where(active, gamma * decay, gamma)
        loss_next = jnp.where(active, obj.flat_loss(data, w_next),
                              loss_prev)
        return (w_next, key, gamma_next, loss_next), loss_next

    (w_fin, _, _, _), losses = jax.lax.scan(
        step, (w0, key, gamma0, loss0), jnp.arange(epochs))
    return w_fin, jnp.concatenate([loss0[None], losses])


def hogwild_epoch(obj: Objective, w, key, step_size: float,
                  num_threads: int, tau: int = -1, scheme: str = "unlock",
                  drop_prob: float = 0.02, delay_kind: str = "fixed"):
    """One Hogwild! epoch (public single-config wrapper over the core)."""
    if scheme not in SCHEME_IDS:
        raise ValueError(f"unknown scheme {scheme!r}")
    if delay_kind not in DELAY_IDS:
        raise ValueError(f"unknown delay schedule {delay_kind!r}")
    _, total, tau = _resolve_hogwild_steps(obj.n, num_threads, tau)
    delay_id = DELAY_IDS["zero"] if tau == 0 else DELAY_IDS[delay_kind]
    return _hogwild_epoch_core(
        obj, obj.data_args(), obj.as_flat(w), key,
        jnp.float32(step_size), jnp.int32(tau),
        jnp.int32(SCHEME_IDS[scheme]), jnp.int32(delay_id),
        total=total, buf_len=tau + 1, drop_prob=drop_prob)


def run_hogwild(obj: Objective, epochs: int, step_size: float,
                num_threads: int = 8, decay: float = 0.9,
                scheme: str = "unlock", tau: int = -1, seed: int = 0,
                w0=None, delay_kind: str = "fixed",
                drop_prob: float = 0.02) -> AsyRunResult:
    """Multi-epoch driver (one configuration, ONE jit for the whole run).

    The γ-decay schedule and the per-epoch loss history are computed inside
    the compiled epochs-scan (`_hogwild_epochs_core`), so a `run_sweep` over
    Hogwild! configs reproduces this driver bit-identically from a single
    batched compilation. `total_updates` derives from the same
    `total = (n // p)·p` expression the epoch core scans over.
    """
    if scheme not in SCHEME_IDS:
        raise ValueError(f"unknown scheme {scheme!r}")
    if delay_kind not in DELAY_IDS:
        raise ValueError(f"unknown delay schedule {delay_kind!r}")
    w = obj.init_flat() if w0 is None else obj.as_flat(w0)
    key = jax.random.PRNGKey(seed)
    _, total, tau = _resolve_hogwild_steps(obj.n, num_threads, tau)
    delay_id = DELAY_IDS["zero"] if tau == 0 else DELAY_IDS[delay_kind]
    data = obj.data_args()

    runner = jax.jit(lambda w0_, k, g0, d: _hogwild_epochs_core(  # repro-lint: ignore[RL002] sequential reference driver: single-shot jit per call, capture is intentional; the cached-runner path (service/cache) passes data as arguments
        obj, data, w0_, k, g0, d,
        jnp.int32(tau), jnp.int32(SCHEME_IDS[scheme]), jnp.int32(delay_id),
        epochs=epochs, total=total, buf_len=tau + 1, drop_prob=drop_prob))
    w_fin, losses = runner(w, key, jnp.float32(step_size),
                           jnp.float32(decay))

    return AsyRunResult(
        w=w_fin,
        history=tuple(float(v) for v in losses),
        effective_passes=tuple(float(e) for e in range(epochs + 1)),
        total_updates=epochs * total)               # same total as the scan
