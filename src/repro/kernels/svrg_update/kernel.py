"""Pallas TPU kernel: fused SVRG control-variate parameter update.

Why a kernel: the inner-loop update reads FOUR param-sized arrays
(u, g, g0, gf) and writes one — pure HBM traffic, zero reuse. Unfused, XLA
may materialize v = g − g0 + gf as an intermediate (6 streams); the fused
kernel is exactly 4 reads + 1 write at peak HBM bandwidth. Tiles are
(8·ROWS, 128)-aligned for the VPU lanes; lr is scalar-prefetched via a
(1,1) SMEM-like operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8
BLOCK_ROWS = 64          # rows of 128 lanes per VMEM tile (64*128*4B = 32 KiB/operand)


def _update_kernel(lr_ref, u_ref, g_ref, g0_ref, gf_ref, out_ref, *, wd: float):
    lr = lr_ref[0, 0]
    u = u_ref[...]
    v = g_ref[...] - g0_ref[...] + gf_ref[...]
    if wd:
        v = v + wd * u.astype(v.dtype)
    out_ref[...] = (u.astype(jnp.float32) - lr * v.astype(jnp.float32)).astype(out_ref.dtype)


def svrg_update_2d(u, g, g0, gf, lr, wd: float = 0.0,
                   interpret: bool = False):
    """u, g, g0, gf: [R, 128] with R % BLOCK_ROWS == 0. lr: [1,1] f32."""
    R = u.shape[0]
    assert u.shape[1] == LANES and R % BLOCK_ROWS == 0, u.shape
    grid = (R // BLOCK_ROWS,)
    block = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_update_kernel, wd=wd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),   # lr (broadcast scalar)
            block, block, block, block,
        ],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=interpret,
    )(lr, u, g, g0, gf)
