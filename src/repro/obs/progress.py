"""Live progress streaming: a bounded, thread-safe event bus.

``ProgressBus`` carries per-slice progress events out of the serving
tier while a sweep is still running: ``service/api.run_job`` publishes
one event per dispatched group slice, ``SweepService.flush`` one event
per completed request, and ``SweepServer`` exposes the stream over
``GET /watch`` with cursor-based resume.  Everything here is
**host-side** — events are built from numpy histories *after* the
compiled program returned (the RL006 obs boundary), and the
publishing fast path when streaming is off is a single bool check.

The bus is a bounded deque: a slow or absent consumer can never grow
memory without bound, at the cost that a consumer more than
``maxlen`` events behind misses the overwritten prefix (the cursor it
gets back is still monotone, so it knows only that events up to that
sequence number existed).

This module is stdlib-only so ``repro.obs`` stays importable in the
zero-install repro-lint CI lane.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

__all__ = [
    "ProgressEvent",
    "ProgressBus",
    "progress_bus",
    "progress_enabled",
    "enable_progress",
    "disable_progress",
]


@dataclasses.dataclass(frozen=True)
class ProgressEvent:
    """One slice/flush worth of live progress.

    ``losses`` holds, per row dispatched in this slice, the row's loss
    history **exactly as it will appear in the final ``SweepResult``**
    (trimmed to the row's own epoch budget) — recomputed on the host
    from the returned slice histories, never from inside jit.
    ``loss_deltas`` are the per-epoch first differences of the same
    series, the signal a live tuner promotes/retires on.
    """

    seq: int                                  # bus-assigned, monotone
    kind: str                                 # "slice" | "flush" | "done"
    watch_id: str                             # e.g. "job-3", "req-17"
    tenant: str
    group: str                                # group label (engine/M/opt/...)
    slice_index: int
    slices_total: int
    rows: Tuple[int, ...]                     # row indices within the job/request
    losses: Tuple[Tuple[float, ...], ...]     # per row, trimmed history
    loss_deltas: Tuple[Tuple[float, ...], ...]
    diverged: Tuple[int, ...]                 # rows the watchdog flagged
    wall_s: float                             # dispatch wall-clock for the slice
    trace_id: str
    ts: float                                 # host wall-clock at publish

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class ProgressBus:
    """Bounded multi-producer / multi-consumer event stream.

    Consumers poll with a cursor (the highest ``seq`` they have seen);
    ``watch`` returns every retained event past the cursor, optionally
    filtered to one ``watch_id``, blocking up to ``timeout`` seconds
    for the first match.  Publishing never blocks.
    """

    def __init__(self, maxlen: int = 1024):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._events: Deque[ProgressEvent] = deque(maxlen=maxlen)  # guarded-by: _lock
        self._seq = 0  # guarded-by: _lock

    def publish(
        self,
        *,
        kind: str,
        watch_id: str,
        tenant: str = "default",
        group: str = "",
        slice_index: int = 0,
        slices_total: int = 1,
        rows: Tuple[int, ...] = (),
        losses: Tuple[Tuple[float, ...], ...] = (),
        loss_deltas: Tuple[Tuple[float, ...], ...] = (),
        diverged: Tuple[int, ...] = (),
        wall_s: float = 0.0,
        trace_id: str = "",
    ) -> ProgressEvent:
        with self._cv:
            self._seq += 1
            ev = ProgressEvent(
                seq=self._seq, kind=kind, watch_id=watch_id, tenant=tenant,
                group=group, slice_index=slice_index, slices_total=slices_total,
                rows=tuple(rows), losses=tuple(losses),
                loss_deltas=tuple(loss_deltas), diverged=tuple(diverged),
                wall_s=float(wall_s), trace_id=trace_id, ts=time.time(),
            )
            self._events.append(ev)
            self._cv.notify_all()
            return ev

    def watch(
        self,
        cursor: int = 0,
        watch_id: Optional[str] = None,
        timeout: float = 0.0,
    ) -> Tuple[List[ProgressEvent], int]:
        """Return ``(events, next_cursor)`` with ``seq > cursor``.

        ``next_cursor`` advances to the last matching event's ``seq``
        (or stays put when nothing matched), so callers resume with
        ``cursor=next_cursor`` and never see an event twice.
        """
        deadline = time.monotonic() + max(0.0, timeout)
        with self._cv:
            while True:
                evs = [
                    e for e in self._events
                    if e.seq > cursor and (watch_id is None or e.watch_id == watch_id)
                ]
                if evs:
                    return evs, evs[-1].seq
                remaining = deadline - time.monotonic()
                if remaining <= 0.0:
                    return [], cursor
                self._cv.wait(remaining)

    def latest_seq(self) -> int:
        with self._lock:
            return self._seq

    def clear(self) -> None:
        with self._cv:
            self._events.clear()


_BUS = ProgressBus()
_ENABLED = False


def progress_bus() -> ProgressBus:
    return _BUS


def progress_enabled() -> bool:
    """The one-bool fast path checked at every publish site."""
    return _ENABLED


def enable_progress() -> ProgressBus:
    global _ENABLED
    _ENABLED = True
    return _BUS


def disable_progress(clear: bool = False) -> None:
    global _ENABLED
    _ENABLED = False
    if clear:
        _BUS.clear()
