"""Gradient compression with error feedback (beyond-paper optimization).

The paper's "unlock" result says: cheaper coordination wins wall-clock even
at some statistical cost. At pod scale the scarce resource is the inter-pod
link, so the TPU-native analogue is compressing the reconcile all-reduce.
Implemented: top-k / random-k sparsification and int8 stochastic
quantization, each with error feedback (Stich et al. 2018) so the
compression error is re-injected — preserving convergence the same way the
paper's τ-bounded staleness does.

All operators work leaf-wise on pytrees and are jit-safe. `compressed_update`
is the drop-in used by the distributed trainer on the gradient tree before
the cross-pod reduction.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: any    # pytree matching the gradient tree


def init_error_feedback(tree) -> ErrorFeedbackState:
    return ErrorFeedbackState(jax.tree.map(jnp.zeros_like, tree))


# ---------------------------------------------------------------------------
# leaf-wise compressors: x -> (compressed_dense, residual)
# ---------------------------------------------------------------------------

def _topk_leaf(x, frac: float):
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    kept = flat * mask
    return kept.reshape(x.shape), (flat - kept).reshape(x.shape)


def _randk_leaf(x, frac: float, key):
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = max(1, int(n * frac))
    idx = jax.random.choice(key, n, (k,), replace=False)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    kept = flat * mask * (n / k)          # unbiased scaling
    return kept.reshape(x.shape), (flat - flat * mask).reshape(x.shape)


def _int8_leaf(x, key):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(x / scale + noise), -127, 127)
    deq = q * scale
    return deq, x - deq


def topk_compress(tree, frac: float):
    """Returns (compressed tree, residual tree)."""
    pairs = jax.tree.map(lambda x: _topk_leaf(x, frac), tree)
    comp = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda p: isinstance(p, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda p: isinstance(p, tuple))
    return comp, res


def _split_keys(key, tree):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def randk_compress(tree, frac: float, key):
    keys = _split_keys(key, tree)
    pairs = jax.tree.map(lambda x, k: _randk_leaf(x, frac, k), tree, keys)
    comp = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda p: isinstance(p, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda p: isinstance(p, tuple))
    return comp, res


def int8_compress(tree, key):
    keys = _split_keys(key, tree)
    pairs = jax.tree.map(_int8_leaf, tree, keys)
    comp = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda p: isinstance(p, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda p: isinstance(p, tuple))
    return comp, res


def compressed_update(grads, ef: ErrorFeedbackState, method: str,
                      frac: float, key) -> Tuple[any, ErrorFeedbackState]:
    """Error-feedback compression: compress(g + residual); carry the error.

    Returns (to_transmit, new_ef). `to_transmit` is what enters the
    cross-pod all-reduce; with method="none" it is `grads` unchanged.
    """
    if method == "none":
        return grads, ef
    corrected = jax.tree.map(jnp.add, grads, ef.residual)
    if method == "topk":
        comp, res = topk_compress(corrected, frac)
    elif method == "randk":
        comp, res = randk_compress(corrected, frac, key)
    elif method == "int8":
        comp, res = int8_compress(corrected, key)
    else:
        raise ValueError(f"unknown compression {method!r}")
    return comp, ErrorFeedbackState(res)


def compressed_bytes(tree, method: str, frac: float) -> int:
    """Wire-size estimate of the compressed payload (for the roofline's
    collective term): topk/randk send k (value+index) pairs; int8 sends
    1 byte/elem + scale."""
    total = 0
    for x in jax.tree.leaves(tree):
        n = 1
        for d in x.shape:
            n *= int(d)
        if method == "none":
            total += 4 * n
        elif method in ("topk", "randk"):
            k = max(1, int(n * frac))
            total += k * (4 + 4)
        elif method == "int8":
            total += n + 4
    return total
