"""Serving loop: batched generate, greedy determinism, session reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.factory import build_model
from repro.serve.loop import ServeSession, generate
from repro.sharding.rules import init_from_defs


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("chatglm3-6b").with_overrides(
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=128)
    bundle = build_model(cfg)
    params = init_from_defs(jax.random.PRNGKey(0), bundle.param_defs)
    return bundle, params


def test_generate_shapes_and_determinism(setup):
    bundle, params = setup
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (3, 8),
                                          0, 128)}
    out1 = generate(bundle, params, batch, max_new_tokens=6, cache_len=16)
    out2 = generate(bundle, params, batch, max_new_tokens=6, cache_len=16)
    assert out1.shape == (3, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.min()) >= 0 and int(out1.max()) < 128


def test_generate_matches_stepwise_session(setup):
    bundle, params = setup
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8),
                                          0, 128)}
    out = generate(bundle, params, batch, max_new_tokens=4, cache_len=16)

    sess = ServeSession(bundle, params, cache_len=16)
    logits = sess.prefill(batch)
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for _ in range(3):
        logits = sess.decode(toks[-1])
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32))
    manual = jnp.stack(toks, 1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(manual))


def test_temperature_sampling_in_range(setup):
    bundle, params = setup
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    out = generate(bundle, params, batch, max_new_tokens=5, cache_len=16,
                   temperature=1.0, seed=7)
    assert out.shape == (2, 5)
    assert int(out.min()) >= 0 and int(out.max()) < 128
