"""Public flash-attention wrapper: GQA expansion + layout + dispatch.

Accepts the model-layer layout q [B,S,N,h], k/v [B,S,K,h] and handles
GQA by repeating kv heads (the kernel sees MHA). Mode selection (compiled /
interpret / jnp reference) goes through
`repro.kernels.dispatch.kernel_mode` — the one policy all kernels share.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dispatch import kernel_mode
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def gqa_flash(q, k, v, *, causal: bool = True, window: int = 0,
              interpret: bool = False, force_kernel: bool = False,
              bq: int = 128, bk: int = 512):
    """q [B,S,N,h], k/v [B,S,K,h] -> [B,S,N,h]."""
    B, S, N, h = q.shape
    K = k.shape[2]
    G = N // K
    qt = q.transpose(0, 2, 1, 3)                      # [B,N,S,h]
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    mode = kernel_mode(interpret, force_kernel)
    if mode != "reference":
        out = flash_attention(
            qt.reshape(B * N, S, h), kt.reshape(B * N, S, h),
            vt.reshape(B * N, S, h), causal=causal, window=window,
            bq=bq, bk=bk, interpret=mode == "interpret")
        out = out.reshape(B, N, S, h)
    else:
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)
