"""Pure-jnp oracle for the fused SVRG control-variate update.

    u' = u − lr · (g − g0 + gf + wd·u)

This is Algorithm 1's inner update (Eq. 2 + the u-step) with optional decoupled
weight decay. The fused kernel must match this to float32 precision.
"""
from __future__ import annotations



def svrg_update_ref(u, g, g0, gf, lr, wd: float = 0.0):
    v = g - g0 + gf
    if wd:
        v = v + wd * u
    return (u - lr * v).astype(u.dtype)
