"""Fault-tolerance: checkpoint atomicity, retention, resume, corruption."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _state(x):
    return {"params": {"w": jnp.full((4, 3), x)},
            "step": jnp.asarray(int(x), jnp.int32)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(7.0), step=7)
    restored, step = ck.restore(_state(0.0))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 3), 7.0))


def test_restore_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last_k=2)
    for s in (1, 2, 3, 4):
        ck.save(_state(float(s)), step=s)
    assert ck.list_steps() == [3, 4]      # retention pruned 1, 2
    _, step = ck.restore(_state(0.0))
    assert step == 4


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(5.0), step=5, blocking=False)
    ck.wait()
    assert ck.list_steps() == [5]


def test_corrupt_manifest_skipped(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(1.0), step=1)
    ck.save(_state(2.0), step=2)
    # corrupt the newest manifest -> restore falls back to step 1
    with open(tmp_path / "step_0000000002" / "manifest.json", "w") as f:
        f.write("{not json")
    assert ck.list_steps() == [1]
    _, step = ck.restore(_state(0.0))
    assert step == 1


def test_tmp_dirs_ignored(tmp_path):
    """A crash mid-write leaves step_*.tmp — must be invisible to restore."""
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(1.0), step=1)
    os.makedirs(tmp_path / "step_0000000009.tmp")
    assert ck.list_steps() == [1]


def test_no_checkpoint_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state(0.0))


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto explicit (1-device) shardings — the elastic-restart
    path where the mesh changed between save and restore."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.utils.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(3.0), step=3)
    sh = {"params": {"w": NamedSharding(mesh, P("data", "model"))},
          "step": NamedSharding(mesh, P())}
    restored, step = ck.restore(_state(0.0), shardings=sh)
    assert restored["params"]["w"].sharding == sh["params"]["w"]
