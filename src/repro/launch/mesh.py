"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init,
and smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=(data,model) single pod (256 chips) or
    (2,16,16)=(pod,data,model) for 2 pods (512 chips).

    The same axis names scale to N pods — the `pod` axis composes with
    `data` in the sharding rules (see repro/sharding/rules.py), so a
    (8,16,16) 2048-chip mesh needs no model-code changes."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """1-device mesh with the production axis names (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
