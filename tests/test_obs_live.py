"""Live observability suite: progress streaming, watchdog, perf ledger.

The PR-10 contracts, bottom-up:

  * `ProgressBus` — bounded, cursor-resumable, watch_id-filtered, and a
    blocked ``watch`` wakes on publish (unit tests, no jax).
  * `Watchdog` — divergence detection on host-side numpy histories at
    slice/flush boundaries; ``cancel_row`` freezes the offender via the
    per-row epoch mask while every SURVIVOR stays bit-identical to a
    watchdog-off run (vmap and fused engines; the sharded variant lives
    in tests/test_sweep_sharded.py); ``cancel_job`` raises `JobDiverged`
    from ``run_job`` but degrades to ``cancel_row`` inside a coalesced
    multi-tenant flush.
  * Progress events — per-slice/-flush loss series equal the final
    `SweepResult` histories bit-for-bit, and watchdog truncations
    persist across checkpoint-resume.
  * `PerfLedger` — per-group compile/warm attribution with exact compile
    counting (AOT ``cost_analysis`` must not inflate the cache's compile
    counters) and roofline-based attained fraction.
  * End-to-end acceptance: a multi-slice job submitted over HTTP,
    streamed live via ``GET /watch`` while it runs.

``step_size=1e30`` is the forced-divergence vehicle throughout: on this
logistic objective it NaNs the loss at epoch 1, and step_size is not in
the group key, so the poisoned row shares a compiled group with healthy
rows.
"""
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.obs.progress import (ProgressBus, disable_progress,
                                enable_progress, progress_bus)
from repro.obs.watchdog import (JobDiverged, Watchdog, enforce_group,
                                first_bad_epoch)
from repro.service import SweepService, cache_stats

BAD_STEP = 1e30       # NaNs the logistic loss on epoch 1, reliably


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with the live-obs toggles off and the
    process-global bus/ledger empty (they are process singletons)."""
    from repro.obs.ledger import disable_ledger
    disable_progress(clear=True)
    disable_ledger(clear=True)
    yield
    disable_progress(clear=True)
    disable_ledger(clear=True)


def _specs(seeds, step_size=0.5, inner_steps=25):
    return [SweepSpec(scheme="inconsistent", step_size=step_size, tau=3,
                      num_threads=4, inner_steps=inner_steps, seed=s)
            for s in seeds]


# --------------------------------------------------------------- ProgressBus
def test_progress_bus_cursor_resume_and_filter():
    bus = ProgressBus()
    for i in range(5):
        bus.publish(kind="slice", watch_id=f"job-{i % 2}", slice_index=i)
    all_events, cursor = bus.watch(cursor=0)
    assert [e.slice_index for e in all_events] == [0, 1, 2, 3, 4]
    assert cursor == all_events[-1].seq == 5
    # resume: nothing new past the cursor, cursor stays put
    again, cursor2 = bus.watch(cursor=cursor)
    assert again == [] and cursor2 == cursor
    # filter: only job-1's events, cursor advances to ITS last seq so a
    # filtered consumer never re-reads interleaved foreign events
    ours, c1 = bus.watch(cursor=0, watch_id="job-1")
    assert [e.slice_index for e in ours] == [1, 3]
    assert c1 == ours[-1].seq
    bus.publish(kind="done", watch_id="job-1")
    more, _ = bus.watch(cursor=c1, watch_id="job-1")
    assert [e.kind for e in more] == ["done"]


def test_progress_bus_is_bounded():
    bus = ProgressBus(maxlen=4)
    for i in range(10):
        bus.publish(kind="slice", watch_id="j", slice_index=i)
    events, cursor = bus.watch(cursor=0)
    # only the newest maxlen retained; seq stays globally monotone
    assert [e.slice_index for e in events] == [6, 7, 8, 9]
    assert cursor == 10 and bus.latest_seq() == 10


def test_progress_bus_watch_blocks_until_publish():
    bus = ProgressBus()
    got = {}

    def consumer():
        got["events"], got["cursor"] = bus.watch(cursor=0, watch_id="j",
                                                 timeout=10.0)

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    bus.publish(kind="slice", watch_id="other")   # filtered out: keeps waiting
    bus.publish(kind="slice", watch_id="j")
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert [e.watch_id for e in got["events"]] == ["j"]
    # and an empty timeout-expiry returns immediately with cursor unchanged
    events, cursor = bus.watch(cursor=got["cursor"], timeout=0.0)
    assert events == [] and cursor == got["cursor"]


def test_progress_event_round_trips_json():
    import json
    bus = ProgressBus()
    ev = bus.publish(kind="slice", watch_id="job-1", tenant="t",
                     group="asysvrg-vmap-M100-opt2-buf4", slice_index=2,
                     slices_total=3, rows=(4, 5),
                     losses=((0.5, 0.25), (0.5, 0.125)),
                     loss_deltas=((-0.25,), (-0.375,)), diverged=(5,),
                     wall_s=0.125, trace_id="t01")
    back = json.loads(json.dumps(ev.to_dict()))
    assert back["kind"] == "slice" and back["rows"] == [4, 5]
    assert back["losses"][1] == [0.5, 0.125] and back["diverged"] == [5]


# ------------------------------------------------------------------ Watchdog
def test_first_bad_epoch_scan():
    nan_at_2 = np.asarray([1.0, 0.5, np.nan, 0.1], np.float32)
    assert first_bad_epoch(nan_at_2, epochs=3, explosion_ratio=1e3) == 2
    # entries past the row's own budget are frozen re-emits: not inspected
    assert first_bad_epoch(nan_at_2, epochs=1, explosion_ratio=1e3) is None
    assert first_bad_epoch(np.asarray([1.0, np.inf]), 1, 1e3) == 1
    # explosion without NaN: |loss| > ratio * |loss[0]|
    assert first_bad_epoch(np.asarray([1.0, 2.0, 5000.0]), 2, 1e3) == 2
    assert first_bad_epoch(np.asarray([1.0, 0.5, 0.25]), 2, 1e3) is None
    # epoch 0 (the initial loss) is trusted by construction
    assert first_bad_epoch(np.asarray([np.nan, 1.0]), 1, 1e3) is None
    assert first_bad_epoch(np.asarray([1.0]), 0, 1e3) is None


def test_watchdog_validation_and_tenant_policy():
    with pytest.raises(ValueError, match="unknown watchdog policy"):
        Watchdog(policy="explode")
    with pytest.raises(ValueError, match="unknown watchdog policy"):
        Watchdog(tenant_policies={"t": "bogus"})
    with pytest.raises(ValueError, match="explosion_ratio"):
        Watchdog(explosion_ratio=0.0)
    wd = Watchdog(policy="record", tenant_policies={"strict": "cancel_job"})
    assert wd.policy_for("strict") == "cancel_job"
    assert wd.policy_for("anyone-else") == "record"


@pytest.mark.parametrize("engine_mode", ["vmap", "fused"])
def test_flush_cancel_row_survivors_bit_identical(obj, engine_mode):
    """THE bit-identity contract: one poisoned row in a shared compiled
    group gets cancelled (frozen at w0 — its first bad epoch is 1), and
    every surviving row's history AND final iterate are bit-identical to
    a watchdog-off `run_sweep` of the same specs. The freeze re-dispatch
    rides the per-row epoch mask, so it must not compile anything."""
    import dataclasses
    good = [dataclasses.replace(s, engine_mode=engine_mode)
            for s in _specs([0, 1, 2])]
    bad = dataclasses.replace(_specs([99], step_size=BAD_STEP)[0],
                              engine_mode=engine_mode)
    specs = [good[0], bad, good[1], good[2]]

    svc = SweepService(obj, epochs=3, watchdog=Watchdog(policy="cancel_row"))
    rid = svc.submit(specs)
    svc.flush()                                   # compiles once
    base = cache_stats()
    rid2 = svc.submit(specs)
    svc.flush()                                   # warm flush + warm freeze
    assert cache_stats().since(base).compiles == 0, \
        "watchdog re-dispatch recompiled — epochs must stay a runtime array"
    got = svc.result(rid2)
    svc.result(rid)

    np.testing.assert_array_equal(got.diverged_rows, [-1, 0, -1, -1])
    assert got.epochs_per_row.tolist() == [3, 0, 3, 3]
    # cancelled row: frozen at w0 — every entry the initial loss, finite
    assert np.isfinite(got.histories[1]).all()
    assert np.all(got.histories[1] == got.histories[1, 0])

    ref = run_sweep(obj, 3, good)                 # watchdog-off reference
    for row, ref_row in zip((0, 2, 3), (0, 1, 2)):
        np.testing.assert_array_equal(got.histories[row],
                                      ref.histories[ref_row])
        np.testing.assert_array_equal(got.final_w[row],
                                      ref.final_w[ref_row])
    assert svc.stats().rows_diverged >= 1


def test_record_policy_marks_without_touching_outputs(obj):
    """``record`` flags the row in ``diverged_rows`` but keeps all
    outputs — the whole result stays bit-identical to watchdog-off."""
    specs = _specs([0, 1]) + _specs([99], step_size=BAD_STEP)
    svc = SweepService(obj, epochs=2, watchdog=Watchdog(policy="record"))
    rid = svc.submit(specs)
    svc.flush()
    got = svc.result(rid)
    ref = run_sweep(obj, 2, specs)
    np.testing.assert_array_equal(got.histories, ref.histories)
    np.testing.assert_array_equal(got.final_w, ref.final_w)
    assert got.epochs_per_row.tolist() == [2, 2, 2]   # nothing truncated
    np.testing.assert_array_equal(got.diverged_rows, [-1, -1, 0])


def test_cancel_job_raises_from_run_job_but_degrades_in_flush(obj, tmp_path):
    """``cancel_job`` is a job-scoped verdict: `run_job` raises
    `JobDiverged`, but a coalesced flush (multi-tenant by construction)
    degrades it to ``cancel_row`` so one tenant cannot cancel another."""
    specs = _specs([0]) + _specs([99], step_size=BAD_STEP)
    svc = SweepService(obj, epochs=2, watchdog=Watchdog(policy="cancel_job"))
    with pytest.raises(JobDiverged) as exc:
        svc.run_job(specs, 2, checkpointer=Checkpointer(str(tmp_path)))
    assert exc.value.rows == {1: 0}

    rid = svc.submit(specs)
    svc.flush()                                   # must NOT raise
    got = svc.result(rid)
    np.testing.assert_array_equal(got.diverged_rows, [-1, 0])
    np.testing.assert_array_equal(got.histories[0],
                                  run_sweep(obj, 2, _specs([0])).histories[0])


def test_enforce_group_respects_pad_duplicates():
    """Width-stabilizing pad rows past ``real`` re-run some real spec and
    may well diverge with it; they are demuxed away, so the watchdog must
    not inspect them (a pad row must never trigger a freeze)."""
    hist = np.asarray([[1.0, 0.5], [1.0, np.nan]], np.float32)
    w = np.zeros((2, 3), np.float32)

    class _Row:
        epochs = 1
    calls = []
    out = enforce_group(Watchdog(policy="cancel_row"), hist, w,
                        members=[0, 0], resolved=[_Row()], real=1,
                        tenant_of=lambda c: "t",
                        redispatch=lambda amended: calls.append(amended))
    assert out[2] == {} and out[3] == {} and calls == []


# ----------------------------------------------------------- progress events
def test_flush_events_match_result_histories(obj):
    specs = _specs([0, 1, 2])
    svc = SweepService(obj, epochs=2)
    enable_progress()
    bus = progress_bus()
    cursor = bus.latest_seq()                     # ignore prior traffic
    rid = svc.submit(specs, tenant="team-a")
    svc.flush()
    res = svc.result(rid)
    events, _ = bus.watch(cursor=cursor, watch_id=f"req-{rid}")
    assert [e.kind for e in events] == ["flush"]
    ev = events[0]
    assert ev.tenant == "team-a" and ev.rows == (0, 1, 2)
    for row in ev.rows:
        streamed = np.asarray(ev.losses[row], np.float32)
        np.testing.assert_array_equal(streamed, res.histories[row])
        np.testing.assert_array_equal(
            np.asarray(ev.loss_deltas[row], np.float32),
            np.diff(res.histories[row]).astype(np.float32))


def test_publishing_is_off_by_default(obj):
    svc = SweepService(obj, epochs=1)
    bus = progress_bus()
    before = bus.latest_seq()
    rid = svc.submit(_specs([5]))
    svc.flush()
    svc.result(rid)
    assert bus.latest_seq() == before


def test_run_job_slice_events_and_watchdog_resume(obj, tmp_path):
    """run_job publishes one ``slice`` event per dispatched group (losses
    == the checkpointed, watchdog-amended histories) plus ``done``; and a
    PREEMPTED job resumed by a fresh service keeps its frozen rows — the
    truncation is checkpoint state, not service memory."""
    specs = (_specs([0, 1]) + _specs([99], step_size=BAD_STEP)
             + _specs([7], inner_steps=50))      # 2 compiled groups
    ckpt = Checkpointer(str(tmp_path))
    enable_progress()
    bus = progress_bus()
    cursor = bus.latest_seq()

    svc = SweepService(obj, epochs=2, watchdog=Watchdog(policy="cancel_row"))
    res, done = svc.run_job(specs, 2, checkpointer=ckpt, max_groups=1,
                            progress_id="job-test")
    assert res is None and not done               # preempted after slice 1

    # a NEW service (fresh process stand-in) finishes from the checkpoint
    svc2 = SweepService(obj, epochs=2,
                        watchdog=Watchdog(policy="cancel_row"))
    res, done = svc2.run_job(specs, 2, checkpointer=ckpt,
                             progress_id="job-test")
    assert done
    np.testing.assert_array_equal(res.diverged_rows, [-1, -1, 0, -1])
    assert res.epochs_per_row.tolist() == [2, 2, 0, 2]

    events, _ = bus.watch(cursor=cursor, watch_id="job-test")
    kinds = [e.kind for e in events]
    assert kinds == ["slice", "slice", "done"]
    assert events[0].slices_total == events[1].slices_total == 2
    assert {events[0].slice_index, events[1].slice_index} == {0, 1}
    seen = {}
    for ev in events[:2]:
        for row, losses in zip(ev.rows, ev.losses):
            seen[row] = losses
    assert set(seen) == {0, 1, 2, 3}
    for row, losses in seen.items():
        budget = int(res.epochs_per_row[row])
        np.testing.assert_array_equal(np.asarray(losses, np.float32),
                                      res.histories[row, :budget + 1])
    assert events[0].diverged == (2,) or events[1].diverged == (2,)

    # the survivors match a watchdog-off run of the healthy specs
    ref = run_sweep(obj, 2, _specs([0, 1]) + _specs([7], inner_steps=50))
    for row, ref_row in ((0, 0), (1, 1), (3, 2)):
        np.testing.assert_array_equal(res.histories[row],
                                      ref.histories[ref_row])
        np.testing.assert_array_equal(res.final_w[row],
                                      ref.final_w[ref_row])


# -------------------------------------------------------------------- ledger
def test_ledger_per_group_attribution(obj):
    """One cold + one warm dispatch of a fresh group: dispatches=2,
    compiles=1 with compile_s attributed, a warm floor, FLOPs (XLA
    cost_analysis or the analytic fallback — named either way) and an
    attained-vs-roofline fraction. The AOT cost_analysis retrace must not
    inflate the runner cache's exact compile counters."""
    from repro.obs.ledger import disable_ledger, enable_ledger
    specs = _specs([0, 1], inner_steps=27)        # unique group: cold here
    led = enable_ledger()
    led.clear()
    svc = SweepService(obj, epochs=2)
    base = cache_stats()
    for _ in range(2):
        rid = svc.submit(specs)
        svc.flush()
        svc.result(rid)
    assert cache_stats().since(base).compiles == 1, \
        "cost_analysis retrace leaked into the counted compile path"

    snap = led.snapshot()
    assert len(snap) == 1
    label, entry = next(iter(snap.items()))
    assert label.startswith("asysvrg-vmap-") and "-rows2-E2" in label
    assert entry["dispatches"] == 2 and entry["compiles"] == 1
    assert entry["compile_s"] > 0.0
    assert 0.0 < entry["warm_wall_min_s"] < entry["compile_s"]
    assert entry["flops"] > 0.0 and entry["bytes"] > 0.0
    assert entry["flops_source"] in ("cost_analysis", "analytic")
    assert entry["roofline_s"] > 0.0 and entry["attained_frac"] > 0.0

    disable_ledger(clear=True)
    base = cache_stats()
    rid = svc.submit(specs)
    svc.flush()
    svc.result(rid)                               # off: nothing recorded
    assert len(led.snapshot()) == 0
    assert cache_stats().since(base).compiles == 0


# ------------------------------------------------------- end-to-end over HTTP
def test_live_watch_job_over_http_acceptance(obj):
    """The acceptance path: a multi-slice job submitted over HTTP with a
    poisoned row, streamed via ``GET /watch?id=job-N`` WHILE it runs.
    Asserts (a) a slice event arrives before the job completes, (b) every
    streamed loss equals the final result's histories bit-for-bit,
    (c) the watchdog cancels exactly the poisoned row while survivors
    stay bit-identical to a watchdog-off in-process run.

    Both groups use inner_steps no other test shares (21, 61) so each
    slice pays a cold compile: after slice 1 streams, slice 2 is still
    seconds away in XLA — a guaranteed window to observe the job live."""
    from repro.server import FlushPolicy, SweepClient, SweepServer

    good = _specs([0, 1], inner_steps=21) + _specs([7], inner_steps=61)
    specs = (good[:2] + _specs([99], step_size=BAD_STEP, inner_steps=21)
             + good[2:])
    svc = SweepService(obj, epochs=2, watchdog=Watchdog(policy="cancel_row"))
    enable_progress()
    with SweepServer(svc, policy=FlushPolicy(max_delay_ms=10)) as server:
        client = SweepClient(server.url, poll_s=5.0)
        job = client.submit_job(specs, 2, tenant="team-a")
        watch_id = job["watch_id"]
        assert watch_id == f"job-{job['job_id']}"

        events, cursor, pending_after_first_slice = [], 0, False
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline:
            got = client.watch(watch_id, cursor=cursor, timeout_s=0.25)
            assert got["enabled"] is True
            cursor = got["cursor"]
            events.extend(got["events"])
            done_seen = any(e["kind"] == "done" for e in events)
            if events and not done_seen and not pending_after_first_slice:
                # (a) live: the first slice streamed while the job still
                # had the second group to compile and dispatch
                with pytest.raises(TimeoutError):
                    client.job_result(job["job_id"], timeout=0.05)
                pending_after_first_slice = True
            if done_seen:
                break
        res = client.job_result(job["job_id"], timeout=300)

    kinds = [e["kind"] for e in events]
    assert pending_after_first_slice and kinds[-1] == "done"
    assert kinds.count("slice") == 2              # one per compiled group
    assert all(e["tenant"] == "team-a" for e in events)

    # (b) streamed losses == final histories, bit for bit
    seen = {}
    for e in events:
        for row, losses in zip(e["rows"], e["losses"]):
            seen[row] = losses
    assert set(seen) == {0, 1, 2, 3}
    for row, losses in seen.items():
        budget = int(res.epochs_per_row[row])
        np.testing.assert_array_equal(np.asarray(losses, np.float32),
                                      res.histories[row, :budget + 1])

    # (c) the poisoned row was cancelled; survivors bit-identical to the
    # watchdog-off in-process reference
    np.testing.assert_array_equal(res.diverged_rows, [-1, -1, 0, -1])
    assert res.epochs_per_row.tolist() == [2, 2, 0, 2]
    assert np.isfinite(res.histories[2]).all()
    ref = run_sweep(obj, 2, good)
    for row, ref_row in ((0, 0), (1, 1), (3, 2)):
        np.testing.assert_array_equal(res.histories[row],
                                      ref.histories[ref_row])
        np.testing.assert_array_equal(res.final_w[row],
                                      ref.final_w[ref_row])
