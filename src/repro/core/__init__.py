from repro.core.objective import (
    LogisticRegression,
    Objective,
    get_objective,
    params_from_flat,
    register_objective,
    registered_objectives,
)
from repro.core.objectives import (
    MLPObjective,
    NonconvexLogistic,
    mlp_lm_objective,
)
from repro.core.svrg import svrg_epoch, run_svrg, sweep_spec as svrg_sweep_spec
from repro.core.asysvrg import (
    AsyRunResult,
    asysvrg_epoch,
    run_asysvrg,
    make_delay_schedule,
)
from repro.core.sweep import (
    ALGOS,
    SweepSpec,
    SweepResult,
    SweepPlan,
    make_grid,
    plan_sweep,
    run_sweep,
)
from repro.core.hogwild import hogwild_epoch, run_hogwild
from repro.core.compression import (
    topk_compress,
    randk_compress,
    int8_compress,
    ErrorFeedbackState,
    compressed_update,
)

__all__ = [
    "LogisticRegression",
    "Objective",
    "register_objective",
    "get_objective",
    "registered_objectives",
    "params_from_flat",
    "MLPObjective",
    "NonconvexLogistic",
    "mlp_lm_objective",
    "svrg_epoch",
    "run_svrg",
    "svrg_sweep_spec",
    "ALGOS",
    "AsyRunResult",
    "asysvrg_epoch",
    "run_asysvrg",
    "make_delay_schedule",
    "SweepSpec",
    "SweepResult",
    "SweepPlan",
    "make_grid",
    "plan_sweep",
    "run_sweep",
    "hogwild_epoch",
    "run_hogwild",
    "topk_compress",
    "randk_compress",
    "int8_compress",
    "ErrorFeedbackState",
    "compressed_update",
]
