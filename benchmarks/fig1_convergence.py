"""Paper Figure 1 (right column): objective gap vs effective passes —
AsySVRG (lock/unlock, 10 threads) vs Hogwild! (lock/unlock, 10 threads).

All four curves come from ONE `run_sweep` call. The paired epoch budgets —
AsySVRG runs E epochs (~3 effective passes each: snapshot pass + 2n inner
visits), Hogwild! runs 3E epochs (1 pass each) so both families cover equal
effective passes — used to force two calls; the masked per-row ``epochs``
axis (`SweepSpec.epochs`, scan to max / freeze finished rows) folds them
into a single program: the AsySVRG rows freeze after E epochs while the
Hogwild! rows run on to 3E. ``--sharded`` additionally shards the config
rows across the host's devices (`make_sweep_mesh`).

Per-row semantics: `SweepResult.curve(c)` trims each row's history and
effective-pass axis to ITS OWN budget — read curves through it, not through
the raw max-width `histories` array, whose tail repeats a frozen row's
final loss.

Bit-exactness caveat: each curve is bit-identical to its sequential
`run_asysvrg`/`run_hogwild` driver — sharded or not — ON XLA:CPU, whose
reduction behaviour the contract is calibrated against (vmap-stable
row-reduces + fixed-order scan sums, device-local rows under shard_map).
On a new backend (TPU/GPU) re-validate with tests/test_sweep.py and
tests/test_sweep_sharded.py before trusting the single-program grid as a
drop-in for the per-run drivers.
"""
from __future__ import annotations

import sys

import jax

from benchmarks.artifacts import write_bench_json
from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.launch.mesh import make_sweep_mesh

P = 10


def run(dataset="rcv1", scale=0.03, epochs=8, quick=False, sharded=False):
    if quick:
        epochs = 4
    ds = make_synthetic_libsvm(dataset, scale=scale)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)

    # one call, paired budgets: AsySVRG E epochs vs Hogwild! 3E epochs
    specs = [SweepSpec(seed=0, scheme=scheme, step_size=2.0, num_threads=P,
                       tau=P - 1, epochs=epochs)
             for scheme in ("inconsistent", "unlock")]
    specs += [SweepSpec(algo="hogwild", seed=0, scheme=scheme, step_size=2.0,
                        num_threads=P, tau=P - 1, epochs=3 * epochs)
              for scheme in ("inconsistent", "unlock")]
    mesh = make_sweep_mesh() if sharded and jax.device_count() > 1 else None
    res = run_sweep(obj, epochs, specs, mesh=mesh)

    curves = {}
    for c, spec in enumerate(specs):
        name = ("asysvrg" if spec.algo == "asysvrg" else "hogwild")
        passes, hist = res.curve(c)
        curves[f"{name}-{spec.scheme}"] = (tuple(passes), tuple(hist))
    return {"f_star": f_star, "curves": curves,
            "devices": jax.device_count() if mesh is not None else 1}


def main(quick=True, sharded=False):
    out = run(quick=quick, sharded=sharded)
    write_bench_json("fig1_convergence", {
        "f_star": out["f_star"],
        "devices": out["devices"],
        "curves": {name: {"passes": list(passes), "loss": list(hist)}
                   for name, (passes, hist) in out["curves"].items()}})
    print("name,us_per_call,derived")
    for name, (passes, hist) in out["curves"].items():
        final_gap = hist[-1] - out["f_star"]
        print(f"fig1_convergence_{name},0,"
              f"final_gap={final_gap:.3e};passes={passes[-1]:.0f}")
    # full curves as CSV comment rows for plotting
    for name, (passes, hist) in out["curves"].items():
        pts = ";".join(f"{p:.0f}:{h - out['f_star']:.3e}"
                       for p, h in zip(passes, hist))
        print(f"# curve {name}: {pts}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv, sharded="--sharded" in sys.argv)
