"""chatglm3-6b [dense] — RoPE 2d (partial rotary), GQA kv=2, qkv bias.
[arXiv:2406.12793; hf]

28L, d_model=4096, 32 heads (kv=2), d_ff=13696, vocab=65024.
ChatGLM applies rotary to half the head dims ("2d RoPE") and uses bias on
the QKV projection only; SwiGLU MLP; RMSNorm.
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    rope_style="partial",
    rope_fraction=0.5,
    use_qkv_bias=True,
    norm="rmsnorm",
    activation="silu",
    glu=True,
))
