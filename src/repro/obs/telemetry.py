"""Algorithm telemetry: realized staleness + update-magnitude series.

The paper's convergence guarantee is parameterized by the delay bound τ,
but what convergence actually responds to is the REALIZED staleness of
each read (Lian et al., 1506.08272): a row configured at τ=7 whose
uniform schedule mostly drew d_m <= 2 behaves like a much smaller τ. An
opt-in ``SweepSpec.telemetry`` flag surfaces that per row, WITHOUT
touching the compiled program:

  * The engines draw every delay d_m inside the jitted scan from a key
    chain that is a pure function of the row's seed — per epoch
    ``key, sub = split(key)``, then ``k_idx, k_delay, k_scan =
    split(sub, 3)`` and ``delays = _delay_schedule_core(delay_id, total,
    τ, k_delay)`` (identical in `core/asysvrg.py` and `core/hogwild.py`).
    JAX PRNG is deterministic eager-vs-jit, so replaying that chain HERE,
    outside any jit, reproduces the exact delays the compiled scan used —
    recomputation, not instrumentation.
  * Update-norm and loss-delta series come from arrays the engine already
    returns (``final_w``, ``histories``).

Both make telemetry trace-safe and bit-safe by construction: nothing is
added to, reordered in, or read out of the jitted group fn, so results
with the flag on are bit-identical to the pinned engine outputs
(asserted in tests/test_obs.py against runs with the flag off, and the
pre-refactor pin stays green). repro-lint RL006 enforces the
construction: no obs/timing calls can enter a ``*_core`` scope.

Computed only for rows that set the flag (a host-side replay costs
O(epochs · M̃) numpy work per row); un-flagged rows carry zeros and
``rows[c] == False``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.asysvrg import _delay_schedule_core


class SweepTelemetry(NamedTuple):
    """Row-aligned telemetry series (all [C] or [C, max_epochs]).

    ``rows`` marks which rows were computed (``SweepSpec.telemetry``);
    every series is zero where ``rows`` is False. Staleness entries are
    the realized delays d_m the row's reads executed with; per-epoch
    entries past a row's own budget are zero (the row was frozen)."""
    rows: np.ndarray                 # [C] bool: telemetry computed?
    staleness_mean: np.ndarray       # [C] mean d_m over the row's run
    staleness_var: np.ndarray        # [C] variance of d_m
    staleness_max: np.ndarray        # [C] max realized d_m (<= τ always)
    staleness_per_epoch: np.ndarray  # [C, max_epochs] per-epoch mean d_m
    update_norm: np.ndarray          # [C] ||w_final - w0||_2
    loss_delta: np.ndarray           # [C, max_epochs] loss[e+1] - loss[e]
    loss_delta_var: np.ndarray       # [C] variance of live loss deltas


def realized_delays(seed: int, delay_id: int, tau: int, total: int,
                    epochs: int) -> np.ndarray:
    """[epochs, total] — the exact delay schedule the compiled scan drew.

    Replays the engines' key-split chain from ``PRNGKey(seed)`` (shared
    verbatim by the asysvrg and hogwild epoch cores, and by the fused
    Pallas megakernel, which runs the same ``*_core`` functions)."""
    key = jax.random.PRNGKey(seed)
    delay_id_ = np.int32(delay_id)
    tau_ = np.int32(tau)
    out = np.empty((epochs, total), np.int32)
    for e in range(epochs):
        key, sub = jax.random.split(key)
        _, k_delay, _ = jax.random.split(sub, 3)
        out[e] = np.asarray(
            _delay_schedule_core(delay_id_, total, tau_, k_delay))
    return out


def compute(specs: Sequence, resolved: Sequence, histories: np.ndarray,
            final_w: np.ndarray, w_init) -> Optional["SweepTelemetry"]:
    """Telemetry for every flagged row of one assembled result (None when
    no row set the flag). ``specs``/``resolved`` are the row-aligned
    normalized specs and `_Resolved` entries; ``histories`` has the
    result's [C, max_epochs+1] width; ``w_init`` is the flat start
    iterate every row shares."""
    flags = np.asarray([bool(getattr(s, "telemetry", False))
                        for s in specs])
    if not flags.any():
        return None
    C, width = histories.shape
    max_epochs = width - 1
    w0 = np.asarray(w_init, np.float64)

    stale_mean = np.zeros(C, np.float64)
    stale_var = np.zeros(C, np.float64)
    stale_max = np.zeros(C, np.int64)
    stale_epoch = np.zeros((C, max_epochs), np.float64)
    update_norm = np.zeros(C, np.float64)
    loss_delta = np.zeros((C, max_epochs), np.float64)
    loss_delta_var = np.zeros(C, np.float64)

    hist64 = np.asarray(histories, np.float64)
    for c in np.flatnonzero(flags):
        r = resolved[c]
        epochs = min(int(r.epochs), max_epochs)
        delays = realized_delays(specs[c].seed, r.delay_id, r.tau,
                                 r.total, epochs)
        flat = delays.reshape(-1).astype(np.float64)
        stale_mean[c] = flat.mean() if flat.size else 0.0
        stale_var[c] = flat.var() if flat.size else 0.0
        stale_max[c] = int(delays.max()) if delays.size else 0
        stale_epoch[c, :epochs] = delays.mean(axis=1)
        update_norm[c] = float(np.linalg.norm(
            np.asarray(final_w[c], np.float64) - w0))
        deltas = hist64[c, 1:epochs + 1] - hist64[c, :epochs]
        loss_delta[c, :epochs] = deltas
        loss_delta_var[c] = deltas.var() if deltas.size else 0.0

    return SweepTelemetry(rows=flags, staleness_mean=stale_mean,
                          staleness_var=stale_var, staleness_max=stale_max,
                          staleness_per_epoch=stale_epoch,
                          update_norm=update_norm, loss_delta=loss_delta,
                          loss_delta_var=loss_delta_var)


def to_dict(tel: "SweepTelemetry") -> dict:
    """JSON-safe wire form (nested lists of Python scalars — exact, like
    the rest of the result payload)."""
    return {name: np.asarray(getattr(tel, name)).tolist()
            for name in SweepTelemetry._fields}


_DTYPES = {"rows": np.bool_, "staleness_max": np.int64}


def from_dict(payload: dict) -> "SweepTelemetry":
    return SweepTelemetry(**{
        name: np.asarray(payload[name], _DTYPES.get(name, np.float64))
        for name in SweepTelemetry._fields})
