"""RL001 — the vmap-bitwise-stable contract, mechanically.

The sweep engine batches every objective's math with `jax.vmap` and
promises the batched bits equal the sequential driver's
(`repro.core.objective`, module docstring). On XLA:CPU that holds only for
elementwise ops, single-axis reduces with an EXPLICIT axis, and
fixed-order `lax.scan` accumulation — a full reduction to a scalar
(axis-less `jnp.sum`/`jnp.mean`) or a `dot_general` (``@``, `jnp.dot`,
`jnp.matmul`, `jnp.einsum`) may change its summation order under a leading
batch axis and silently break bit-parity.

This checker enforces the contract inside the functions that carry it:
any function named ``loss_fixed_order``, ending in ``_stable`` or starting
with ``_stable`` (the stable-math helpers), plus functions nested inside
them. Within that scope it flags

  * reductions called WITHOUT an explicit ``axis=`` (or with
    ``axis=None``): sum, mean, nansum, nanmean, std, var, prod, logsumexp;
  * always-unstable accumulation primitives: ``@`` (MatMult), dot, vdot,
    inner, matmul, tensordot, einsum, trace — rewrite as a
    broadcast-multiply + trailing-axis reduce (`_stable_matmul`) or a
    `_fixed_order_sum` scan.

An axis-less reduce over a known-1-D value is numerically fine, but the
AST cannot see ranks — write the axis out (``axis=-1``) so the reduce is
stable for every rank, or suppress with the 1-D justification.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.astutil import FUNC_NODES, call_name, keyword
from repro.analysis.diagnostics import Diagnostic

# reducers that are stable ONLY with an explicit single axis
_NEEDS_AXIS = {"sum", "mean", "nansum", "nanmean", "std", "var", "prod",
               "logsumexp"}
# accumulation primitives whose internal order XLA may rewrite under vmap
_FORBIDDEN = {"dot", "vdot", "inner", "matmul", "tensordot", "einsum",
              "trace", "norm"}
# module roots the reducers are looked up on (bare names are NOT flagged:
# python's builtin sum() is a fixed-order left fold)
_ARRAY_ROOTS = ("jnp", "np", "numpy", "jax.numpy", "jax.nn", "jsp",
                "jax.scipy.special", "jax.lax")


def _is_array_call(name: str) -> bool:
    root, _, attr = name.rpartition(".")
    return bool(root) and any(
        root == r or root.endswith("." + r) for r in _ARRAY_ROOTS)


def _in_scope(name: str) -> bool:
    return (name == "loss_fixed_order" or name.endswith("_stable")
            or name.startswith("_stable"))


def _check_scope(path: str, fn: ast.AST, scope: str,
                 out: List[Diagnostic]) -> None:
    for node in ast.walk(fn):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            out.append(Diagnostic(
                path, node.lineno, "RL001",
                f"`@` matmul inside vmap-bitwise-stable scope {scope!r} — "
                "dot_general may reorder its accumulation under a batch "
                "axis; use a broadcast-multiply + trailing-axis reduce "
                "(see _stable_matmul)"))
        elif isinstance(node, ast.Call):
            name = call_name(node)
            if name is None or not _is_array_call(name):
                continue
            attr = name.rpartition(".")[2]
            if attr in _FORBIDDEN:
                out.append(Diagnostic(
                    path, node.lineno, "RL001",
                    f"order-unstable `{name}` inside vmap-bitwise-stable "
                    f"scope {scope!r} — use a broadcast-reduce or a "
                    "fixed-order scan (_fixed_order_sum)"))
            elif attr in _NEEDS_AXIS:
                axis = keyword(node, "axis")
                # positional axis (arg 2 for np-style reducers) also counts
                has_positional_axis = len(node.args) >= 2
                if (axis is None and not has_positional_axis) or (
                        isinstance(axis, ast.Constant)
                        and axis.value is None):
                    out.append(Diagnostic(
                        path, node.lineno, "RL001",
                        f"axis-less `{name}` inside vmap-bitwise-stable "
                        f"scope {scope!r} reduces every axis — give an "
                        "explicit trailing `axis=` or accumulate via "
                        "_fixed_order_sum"))


def check(path: str, tree: ast.AST, source: str) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    # walk top-level scopes; once inside a stable-named function, the whole
    # subtree (nested defs included) carries the contract
    for node in ast.walk(tree):
        if isinstance(node, FUNC_NODES) and _in_scope(node.name):
            _check_scope(path, node, node.name, out)
    return out
