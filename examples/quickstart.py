"""Quickstart: AsySVRG on the paper's own workload (logistic regression).

Reproduces the core claim in ~30 seconds on CPU: AsySVRG (all three reading
schemes) converges linearly and beats Hogwild! per effective pass. EVERY
algorithm here runs on the multi-algorithm sweep engine (repro.core.sweep):
the three AsySVRG schemes plus the serial-SVRG baseline (``algo="svrg"``,
the τ=0 degenerate case on the same engine) execute as ONE jit-compiled
grid, and the Hogwild! baseline (``algo="hogwild"``, γ-decay inside the
compiled scan) as another. Adding a scenario is one more SweepSpec row —
no new compiles, no new driver code.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (LogisticRegression, SweepSpec, make_grid, run_sweep,
                        svrg_sweep_spec)
from repro.data.libsvm import make_synthetic_libsvm


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.05)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    print(f"dataset rcv1-like: n={obj.n} p={obj.p}  f*={f_star:.6f}\n")

    # AsySVRG × 3 schemes + serial SVRG, one sweep call
    specs = make_grid(schemes=("consistent", "inconsistent", "unlock"),
                      seeds=(0,), step_sizes=(2.0,), taus=(9,),
                      num_threads=10)
    specs += [svrg_sweep_spec(step_size=2.0)]
    res = run_sweep(obj, 6, specs)

    print(f"{'method':28s} {'passes':>7s} {'final gap':>12s}")
    for c, spec in enumerate(specs):
        name = ("SVRG-serial" if spec.algo == "svrg"
                else f"AsySVRG-{spec.scheme}")
        gap = res.histories[c][-1] - f_star
        print(f"{name:28s} {res.effective_passes[c][-1]:7.0f} "
              f"{gap:12.3e}")

    # Hogwild! baseline: same engine, algo axis flipped; 18 epochs = 18
    # effective passes, matching the AsySVRG rows' ~18 passes above
    hog_specs = [SweepSpec(algo="hogwild", scheme="unlock", step_size=2.0,
                           num_threads=10, tau=9)]
    hog = run_sweep(obj, 18, hog_specs)
    gap = hog.histories[0][-1] - f_star
    print(f"{'Hogwild!-unlock':28s} {hog.effective_passes[0][-1]:7.0f} "
          f"{gap:12.3e}")
    print("\nAsySVRG reaches a much smaller gap at EQUAL effective passes —")
    print("the paper's Figure 1 (right) in one table.")


if __name__ == "__main__":
    main()
