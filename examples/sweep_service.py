"""Sweep service demo: multi-tenant coalescing + checkpoint-resumable jobs.

Three scenes on one objective (the paper's logistic-regression workload):

  1. WARM CACHE — the same grid swept twice; the second call fetches its
     compiled runners from the persistent cache (repro.service.cache) and
     compiles nothing.
  2. COALESCING — three logical clients submit compatible grids; one
     `flush` merges their rows into shared compiled groups and each client
     gets back exactly what a standalone `run_sweep` of its own specs
     would return (bit-identical — asserted below).
  3. CHECKPOINT-RESUME — a long sweep job dispatched group by group
     through `repro.checkpoint.Checkpointer`, preempted after every group
     (``max_groups=1``) and resumed until done; the assembled result is
     again bit-identical to one uninterrupted `run_sweep`.

    PYTHONPATH=src python examples/sweep_service.py
"""
import tempfile

import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import (LogisticRegression, SweepSpec, make_grid, run_sweep,
                        svrg_sweep_spec)
from repro.data.libsvm import make_synthetic_libsvm
from repro.service import SweepService, cache_stats, clear_cache


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.03)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    print(f"dataset rcv1-like: n={obj.n} p={obj.p}\n")

    # ---- 1. warm cache: second same-shape sweep compiles nothing --------
    clear_cache()
    grid = make_grid(seeds=(0, 1), step_sizes=(1.0,), taus=(9,),
                     num_threads=10)
    run_sweep(obj, 3, grid)
    cold = cache_stats()
    run_sweep(obj, 3, grid)
    warm = cache_stats().since(cold)
    print(f"cold sweep: {cold.compiles} compiles; "
          f"repeat: {warm.compiles} compiles, {warm.hits} cache hits\n")

    # ---- 2. three tenants, one coalesced dispatch -----------------------
    svc = SweepService(obj, epochs=3)
    rid_a = svc.submit(make_grid(schemes=("inconsistent",), seeds=(3, 4),
                                 step_sizes=(1.0, 2.0), taus=(9,),
                                 num_threads=10))
    rid_b = svc.submit([SweepSpec(scheme="unlock", step_size=1.0, tau=9,
                                  num_threads=10, seed=5),
                        svrg_sweep_spec(step_size=1.0)])
    rid_c = svc.submit([SweepSpec(algo="hogwild", scheme="unlock",
                                  step_size=1.0, tau=9, num_threads=10,
                                  epochs=9)])
    svc.flush()
    stats = svc.stats()
    print(f"3 requests, {stats.rows_submitted} rows -> "
          f"{stats.groups_dispatched} compiled groups "
          f"({stats.rows_coalesced} rows coalesced across requests, "
          f"cache hit rate {stats.cache_hit_rate:.0%})")
    for rid, name in ((rid_a, "tenant A"), (rid_b, "tenant B"),
                      (rid_c, "tenant C")):
        res = svc.result(rid)
        gaps = ", ".join(f"{res.curve(c)[1][-1]:.4f}"
                         for c in range(len(res.specs)))
        print(f"  {name}: final losses [{gaps}]")

    # each tenant's demuxed result == its own standalone run_sweep
    res_b = svc.result(rid_b)
    base_b = run_sweep(obj, 3, [SweepSpec(scheme="unlock", step_size=1.0,
                                          tau=9, num_threads=10, seed=5),
                                svrg_sweep_spec(step_size=1.0)])
    np.testing.assert_array_equal(res_b.histories, base_b.histories)
    print("  demuxed results bit-identical to standalone run_sweep\n")

    # ---- 3. checkpoint-resumable job ------------------------------------
    job_specs = grid + [svrg_sweep_spec(step_size=1.0),
                        SweepSpec(algo="hogwild", scheme="inconsistent",
                                  step_size=1.0, tau=9, num_threads=10)]
    with tempfile.TemporaryDirectory() as ckpt_dir:
        calls, done, res = 0, False, None
        while not done:
            # a fresh Checkpointer each call simulates process restarts
            res, done = svc.run_job(job_specs, epochs=3,
                                    checkpointer=Checkpointer(ckpt_dir),
                                    max_groups=1)
            calls += 1
        base = run_sweep(obj, 3, job_specs)
        np.testing.assert_array_equal(res.histories, base.histories)
        print(f"job of {len(job_specs)} rows survived {calls - 1} "
              "preemptions; resumed result bit-identical to one "
              "uninterrupted run_sweep")


if __name__ == "__main__":
    main()
