"""Serving-tier metrics: one JSON-safe snapshot of everything operable.

`snapshot(service, daemon=None, fairness=None)` flattens the accounting
the lower layers already keep — `ServiceStats` (requests/rows/coalescing +
the per-lookup runner-cache counters), queue depth in requests AND rows,
per-tenant row accounting, the p50/p95/max of the recent flush-dispatch
durations and request submit→result latencies, the daemon's trigger
counters, the fair-share deficit state, and the process-global runner
cache — into one plain dict of JSON types. The HTTP ``/stats`` endpoint
returns it verbatim; a Prometheus exporter would walk the same dict.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.obs import ledger as _ledger
from repro.server.daemon import ServeDaemon
from repro.server.fairness import FairShare
from repro.service import cache as _cache
from repro.service.api import SweepService

PERCENTILES = (50.0, 95.0)


def percentile(values: Sequence[float], q: float) -> float:
    """np.percentile with an empty-series guard (0.0), so the snapshot is
    always JSON-complete."""
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


def _latency_summary(seconds: Sequence[float]) -> Dict[str, float]:
    out: Dict[str, float] = {"count": len(seconds)}
    for q in PERCENTILES:
        out[f"p{q:g}_ms"] = percentile(seconds, q) * 1000.0
    out["max_ms"] = max(seconds) * 1000.0 if seconds else 0.0
    return out


def snapshot(service: SweepService, daemon: Optional[ServeDaemon] = None,
             fairness: Optional[FairShare] = None) -> dict:
    """One consistent, JSON-safe view of the serving tier."""
    stats = service.stats()
    flush_lat, request_lat = service.latencies()
    out = {
        "service": {**dataclasses.asdict(stats),
                    "cache_hit_rate": stats.cache_hit_rate},
        "queue": {
            "depth_requests": service.pending(),
            "depth_rows": service.pending_rows(),
            "oldest_age_ms": (service.oldest_pending_age() or 0.0) * 1000.0,
        },
        "tenants": {t: {"rows_submitted": sub, "rows_completed": done}
                    for t, (sub, done) in service.tenant_rows().items()},
        "flush_latency": _latency_summary(flush_lat),
        "request_latency": _latency_summary(request_lat),
        "runner_cache": {**dataclasses.asdict(_cache.cache_stats()),
                         "size": _cache.cache_size()},
    }
    if daemon is not None:
        # locked copies — the live stats object is concurrently mutated by
        # the flush thread and flush_now() callers (RL003 guards it)
        err = daemon.last_error_snapshot()
        out["daemon"] = {**dataclasses.asdict(daemon.stats_snapshot()),
                         "jobs_pending": daemon.jobs_pending(),
                         "policy": dataclasses.asdict(daemon.policy),
                         "running": daemon.running(),
                         "heartbeat_age_s": daemon.heartbeat_age_s(),
                         "last_error": repr(err) if err else None}
    if fairness is not None:
        out["fairness"] = {
            "quantum_rows": fairness.quantum_rows,
            "max_rows_per_flush": fairness.max_rows_per_flush,
            "deficits": fairness.deficits(),
        }
    if _ledger.ledger_enabled():
        # opt-in section: tests pin the exact default section set, and an
        # empty ledger on every scrape would just be noise
        out["ledger"] = _ledger.ledger().snapshot()
    return out
