"""The paper's objective: L2-regularized logistic regression (paper §5).

    f(w) = (1/n) Σ_i log(1 + exp(-y_i x_i·w)) + (λ/2)||w||²

All pieces the algorithms need are exposed as pure jnp functions:
full objective, full gradient, per-sample gradient (the ∇f_i of Algorithm 1),
and minibatch gradient. Assumptions 1–2 hold: each f_i is convex and
L-smooth with L ≤ max_i ||x_i||²/4 + λ, and f is λ-strongly convex.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _log1pexp(z):
    """Numerically stable log(1 + e^z)."""
    return jnp.logaddexp(0.0, z)


class LogisticRegression:
    """Stateless objective bound to a dataset (X, y, λ)."""

    def __init__(self, X, y, l2_reg: float = 1e-4):
        self.X = jnp.asarray(X)
        self.y = jnp.asarray(y)
        self.l2 = float(l2_reg)
        self.n, self.p = self.X.shape

    # -- objective ---------------------------------------------------------
    def loss(self, w) -> jnp.ndarray:
        margins = self.y * (self.X @ w)
        return jnp.mean(_log1pexp(-margins)) + 0.5 * self.l2 * jnp.vdot(w, w)

    # -- gradients ---------------------------------------------------------
    def full_grad(self, w) -> jnp.ndarray:
        """∇f(w) — the snapshot full gradient of Algorithm 1."""
        margins = self.y * (self.X @ w)
        s = jax.nn.sigmoid(-margins)             # σ(-y x·w)
        return (-(self.y * s) @ self.X) / self.n + self.l2 * w

    def partial_full_grad(self, w, lo: int, size: int) -> jnp.ndarray:
        """Partitioned full-gradient contribution (one thread's φ_a).

        Returns an UN-normalized sum over rows [lo, lo+size); the caller sums
        the partitions and divides by n — exactly the paper's parallel
        snapshot pass.
        """
        Xs = jax.lax.dynamic_slice_in_dim(self.X, lo, size, 0)
        ys = jax.lax.dynamic_slice_in_dim(self.y, lo, size, 0)
        margins = ys * (Xs @ w)
        s = jax.nn.sigmoid(-margins)
        return -(ys * s) @ Xs

    def sample_grad(self, w, i) -> jnp.ndarray:
        """∇f_i(w) for one instance (the paper's inner-loop gradient)."""
        x = self.X[i]
        yi = self.y[i]
        s = jax.nn.sigmoid(-yi * jnp.dot(x, w))
        return -yi * s * x + self.l2 * w

    def minibatch_grad(self, w, idx) -> jnp.ndarray:
        """Mean gradient over a batch of indices (beyond-paper batching)."""
        Xb = self.X[idx]
        yb = self.y[idx]
        s = jax.nn.sigmoid(-yb * (Xb @ w))
        return (-(yb * s) @ Xb) / idx.shape[0] + self.l2 * w

    # -- constants for the theory-facing tests ------------------------------
    def smoothness(self) -> float:
        row_sq = jnp.sum(self.X * self.X, axis=1)
        return float(jnp.max(row_sq) / 4.0 + self.l2)

    def strong_convexity(self) -> float:
        return self.l2

    def optimum(self, tol: float = 1e-12, max_iter: int = 5000) -> Tuple[jnp.ndarray, float]:
        """High-accuracy reference optimum via deterministic gradient descent
        with backtracking-free fixed step 1/L (used to compute the paper's
        "gap < 1e-4" stopping metric)."""
        L = self.smoothness()
        step = 1.0 / L

        def body(carry, _):
            w, = carry
            g = self.full_grad(w)
            return (w - step * g,), None

        (w,), _ = jax.lax.scan(body, (jnp.zeros(self.p),), None, length=max_iter)
        return w, float(self.loss(w))
