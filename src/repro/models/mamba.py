"""Attention-free SSM family (falcon-mamba-7b, Mamba-1 architecture).

Per layer: in_proj → (x, z); x → causal depthwise conv(4) → SiLU → selective
SSM → ⊙ SiLU(z) → out_proj. The selective scan is computed CHUNKED: the
sequence is split into fixed chunks; within a chunk `lax.associative_scan`
produces both the prefix states and the chunk's transition product, and the
inter-chunk state is carried by a sequential `lax.scan` — this bounds the
materialized [B, chunk, d_inner, N] tensors (the TPU adaptation of the
CUDA selective-scan kernel's registers/SRAM blocking; see DESIGN.md §8).

Channels are independent ⇒ activations shard over `model` on d_inner
without any cross-device sequential dependency (sharding/context.py).
Decode is the O(1) recurrence on a [B, d_inner, N] state — why this arch
runs the long_500k cell.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as nn
from repro.models import transformer as tf
from repro.sharding.context import constrain
from repro.sharding.rules import ParamDef

CHUNK = 256
# channel sharding: "mlp" is the LOGICAL axis name that maps to the `model`
# mesh axis in the rule table (a raw mesh-axis name here silently resolves
# to replicated — cost 96 GiB/device before this was caught)
RESIDUAL_AXES = ("batch", None, "mlp")


def param_defs(cfg: ModelConfig) -> Dict:
    dt = cfg.param_dtype
    L, D, V = cfg.num_layers, cfg.d_model, cfg.vocab_size
    Di, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_actual
    blocks = {
        "norm": tf._norm_defs((L, D), cfg, dt),
        "in_proj": ParamDef((L, D, 2 * Di), ("layers", "embed", "mlp"), dtype=dt),
        "conv_w": ParamDef((L, 4, Di), ("layers", "conv", "mlp"), "scaled", scale=0.2, dtype=dt),
        "conv_b": ParamDef((L, Di), ("layers", "mlp"), "zeros", dtype=dt),
        "x_proj": ParamDef((L, Di, R + 2 * N), ("layers", "mlp", None), dtype=dt),
        "dt_proj": ParamDef((L, R, Di), ("layers", None, "mlp"), "scaled", scale=0.1, dtype=dt),
        "dt_bias": ParamDef((L, Di), ("layers", "mlp"), "ones", dtype=dt),
        "A_log": ParamDef((L, Di, N), ("layers", "mlp", "state"), "ones", dtype=dt),
        "D_skip": ParamDef((L, Di), ("layers", "mlp"), "ones", dtype=dt),
        "out_proj": ParamDef((L, Di, D), ("layers", "mlp", "embed"), dtype=dt),
    }
    p = {
        "tok_embed": ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt),
        "blocks": blocks,
        "final_norm": tf._norm_defs((D,), cfg, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt)
    return p


# ---------------------------------------------------------------------------
# Selective scan
# ---------------------------------------------------------------------------

def _ssm_params(x, lp, cfg):
    """x [B,S,Di] (post-conv) -> (dA [B,S,Di,N], dBx [B,S,Di,N], C [B,S,N])."""
    N, R = cfg.ssm_state, cfg.dt_rank_actual
    proj = jnp.einsum("bsd,dr->bsr", x, lp["x_proj"])
    dtr, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dtr, lp["dt_proj"]) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))              # [Di,N]
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)        # [B,S,Di,N]
    dBx = (dt * x).astype(jnp.float32)[..., None] * Bc.astype(jnp.float32)[:, :, None, :]
    return dA, dBx, Cc


def selective_scan(x, lp, cfg, h0=None):
    """Chunked selective scan. x [B,S,Di] -> (y [B,S,Di], h_last [B,Di,N]).

    The SSM parameters (dA, dBx, C) are computed PER CHUNK inside the scan
    body (and the body is rematerialized): materializing [B,S,Di,N] f32 for
    the full sequence costs 34 GiB/device on falcon-mamba train_4k.
    Channels shard over `model` (constrained here), so the per-chunk
    tensors are [B, chunk, Di/16, N]."""
    B, S, Di = x.shape
    N = cfg.ssm_state
    chunk = min(CHUNK, S)
    while S % chunk != 0:
        chunk //= 2
    nch = S // chunk
    if h0 is None:
        h0 = jnp.zeros((B, Di, N), jnp.float32)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    def chunk_body(h_prev, x_c):
        x_c = constrain(x_c, ("batch", None, "mlp"))
        dA_c, dBx_c, C_c = _ssm_params(x_c, lp, cfg)
        P, Ss = jax.lax.associative_scan(combine, (dA_c, dBx_c), axis=1)
        hs = Ss + P * h_prev[:, None, :, :]        # states at every position
        y = jnp.einsum("bsdn,bsn->bsd", hs, C_c.astype(jnp.float32))
        return hs[:, -1, :, :], y.astype(x_c.dtype)

    if nch > 1:
        chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    xs = x.reshape(B, nch, chunk, Di).transpose(1, 0, 2, 3)
    h_last, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, Di)
    return y.astype(x.dtype), h_last


def _mamba_block(cfg, lp, h, conv_state=None, ssm_state=None):
    x = nn.apply_norm(cfg, h, lp["norm"])
    xz = jnp.einsum("bsd,de->bse", x, lp["in_proj"])
    xb, z = jnp.split(xz, 2, axis=-1)
    from repro.models.rglru import _causal_conv
    xb, new_conv = _causal_conv(xb, lp["conv_w"], lp["conv_b"], conv_state)
    xb = jax.nn.silu(xb)
    y, h_last = selective_scan(xb, lp, cfg, h0=ssm_state)
    y = y + lp["D_skip"] * xb
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, lp["out_proj"])
    return h + out, (new_conv, h_last)


def hidden_states(cfg: ModelConfig, params, tokens, collect_state=False):
    h = tf.embed_tokens(cfg, params, tokens)

    def body(carry, lp):
        carry = constrain(carry, RESIDUAL_AXES)
        out, st = _mamba_block(cfg, lp, carry)
        # constrain the OUTPUT too: the scan saves/stacks body outputs, and
        # an unconstrained stack accumulates replicated on D (+96 GiB/device
        # observed on falcon-mamba train_4k)
        return constrain(out, RESIDUAL_AXES), st

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    h, states = jax.lax.scan(body, h, params["blocks"])
    h = nn.apply_norm(cfg, h, params["final_norm"])
    if collect_state:
        return h, states
    return h


def loss_fn(cfg: ModelConfig, params, batch):
    h = hidden_states(cfg, params, batch["tokens"])
    return nn.lm_loss(h, tf.unembed(cfg, params), batch["targets"],
                      batch["mask"])


# ---------------------------------------------------------------------------
# Serving — O(1) state decode
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    L, Di, N = cfg.num_layers, cfg.d_inner, cfg.ssm_state
    return {
        "conv": ParamDef((L, batch, 3, Di), ("layers", "batch", None, "mlp"), "zeros", dtype=cfg.dtype),
        "ssm": ParamDef((L, batch, Di, N), ("layers", "batch", "mlp", "state"), "zeros", dtype="float32"),
    }


def prefill(cfg: ModelConfig, params, tokens, cache_len: int):
    h, (convs, ssms) = hidden_states(cfg, params, tokens, collect_state=True)
    logits = jnp.einsum("bd,vd->bv", h[:, -1, :], tf.unembed(cfg, params))
    return logits.astype(jnp.float32), {
        "conv": convs.astype(jnp.dtype(cfg.dtype)),
        "ssm": ssms.astype(jnp.float32),
    }


def decode_step(cfg: ModelConfig, params, cache: Dict, tokens, pos_scalar):
    del pos_scalar   # SSM decode is position-free
    h = tf.embed_tokens(cfg, params, tokens[:, None])

    def body(carry, xs):
        lp, cs, ss = xs
        out, (nc, nh) = _mamba_block(cfg, lp, carry, conv_state=cs,
                                     ssm_state=ss)
        return out, (nc, nh)

    h, (ncs, nss) = jax.lax.scan(
        body, h, (params["blocks"], cache["conv"], cache["ssm"]))
    h = nn.apply_norm(cfg, h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h[:, 0, :], tf.unembed(cfg, params))
    return logits.astype(jnp.float32), {
        "conv": ncs.astype(cache["conv"].dtype),
        "ssm": nss.astype(jnp.float32),
    }
