"""Parsed-file records shared by the engine and project-level checkers.

Lives in its own module so ``rules/rl004_keys`` (which needs to resolve
sibling files) and ``engine`` (which drives the walk) can both import it
without a cycle.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import NamedTuple, Optional

from repro.analysis.suppress import Comments, scan_comments


class SourceFile(NamedTuple):
    path: str
    source: str
    tree: ast.Module
    comments: Comments


def load_file(path: Path) -> Optional[SourceFile]:
    """Parse one file; None when it does not parse (the engine turns that
    into its own diagnostic rather than crashing the whole run)."""
    source = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError:
        return None
    return SourceFile(str(path), source, tree, scan_comments(source))
