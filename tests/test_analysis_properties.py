"""Hypothesis property tests for repro-lint (own module so the skip, when
hypothesis is absent, doesn't take the deterministic fixtures in
test_analysis.py down with it)."""
import pytest

from repro.analysis import RULES, lint_source
from repro.analysis.suppress import scan_comments

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="analysis property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

_code = st.sampled_from(sorted(RULES))
_reason = st.text(
    st.characters(min_codepoint=32, max_codepoint=126,
                  exclude_characters="#\\"),
    min_size=1, max_size=40).map(str.strip).filter(bool)


@settings(max_examples=50, deadline=None)
@given(_code, _reason)
def test_suppression_comment_roundtrip(code, reason):
    """Any well-formed ignore-comment parses back to its code + reason."""
    src = f"x = 1  # repro-lint: ignore[{code}] {reason}\n"
    sup = scan_comments(src).suppressions[1]
    assert sup.codes == (code,)
    assert sup.reason == reason


@settings(max_examples=30, deadline=None)
@given(st.text(st.characters(min_codepoint=32, max_codepoint=126),
               max_size=200))
def test_lint_source_never_crashes_on_parseable_text(text):
    """lint_source on arbitrary parseable source returns diagnostics,
    never raises (unparseable input may raise SyntaxError upstream)."""
    try:
        compile(text, "<gen>", "exec")
    except (SyntaxError, ValueError):
        return
    lint_source(text)
