"""Quickstart: AsySVRG on the paper's own workload (logistic regression).

Reproduces the core claim in ~30 seconds on CPU: AsySVRG (all three reading
schemes) converges linearly and beats Hogwild! per effective pass. EVERY
scenario here runs in ONE `run_sweep` call on the multi-algorithm sweep
engine (repro.core.sweep): the three AsySVRG schemes, the serial-SVRG
baseline (``algo="svrg"``, the τ=0 degenerate case on the same engine), AND
the Hogwild! baseline (``algo="hogwild"``, γ-decay inside the compiled
scan) — the Hogwild! row carries its own 3× per-row ``epochs`` budget (1
pass/epoch vs AsySVRG's ~3) via the masked-epoch axis, so equal effective
passes no longer need a second call. Adding a scenario is one more
SweepSpec row — no new compiles, no new driver code. On a multi-device
host, pass ``mesh=make_sweep_mesh()`` to shard the rows across devices.

Serving sweeps: re-running grids is as cheap as running them — every
dispatch goes through the persistent compiled-runner cache
(`repro.service.cache`), so a second same-shape sweep compiles nothing —
and the serving tier (`repro.server`) makes the whole thing a deployable
HTTP service: clients submit over the wire and a background flush daemon
coalesces tenants' specs into shared compiled dispatches on a deadline
policy, nobody ever calling flush() (see the "serving sweeps" section
below; examples/serve_sweeps.py is the full multi-tenant demo with
priorities and a time-sliced giant job, examples/sweep_service.py the
in-process + checkpoint-resume one).

Fused kernel path: every group can also run as ONE Pallas megakernel
launch (`engine_mode="fused"` per spec, or ``REPRO_SWEEP_ENGINE=fused``
process-wide) with the config rows mapped onto the kernel grid — bit-exact
to the default vmap engine in interpret mode; see the "fused kernel path"
section below for when it profits and how to read the benchmark.

Bring your own objective: the engine is not married to logistic regression.
Subclass `repro.core.Objective` with three math methods (fixed-order loss,
stable full gradient, stable per-sample gradient — see the class docstring
for the bitwise-stability rules) and every layer above works unchanged:
sweeps, the runner cache, coalescing, checkpoint-resume and the HTTP tier.
The last section below onboards a ridge-regression objective in ~25 lines;
examples/nonconvex_sweep.py does the same for an MLP language model
(pytree params) and a nonconvex clipped-penalty logistic through the
sweep service.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (LogisticRegression, Objective, SweepSpec, make_grid,
                        run_sweep, svrg_sweep_spec)
from repro.core.objective import _fixed_order_sum
from repro.data.libsvm import make_synthetic_libsvm
from repro.server import FlushPolicy, SweepClient, SweepServer
from repro.service import SweepService, cache_stats


class Ridge(Objective):
    """Least squares + l2 — a complete bring-your-own objective.

    The whole protocol: hand the engine your data (`data_args`), an initial
    parameter pytree (`init_params`, here a bare vector), and the three math
    methods. Reduces stay elementwise/trailing-axis or fixed-order
    (`_fixed_order_sum`, lax.scan) so every engine bit-exactness guarantee
    — coalescing, sharding, wire round-trips — holds for free.
    """

    def __init__(self, X, y, l2: float = 1e-3):
        self.X, self.y, self.l2 = jnp.asarray(X), jnp.asarray(y), float(l2)
        self.n, self.p = self.X.shape

    def data_args(self):
        return (self.X, self.y, jnp.float32(self.l2))

    def init_params(self):
        return jnp.zeros(self.p)

    def static_key(self):
        return ()

    def loss_fixed_order(self, data, w):
        X, y, l2 = data
        r = jnp.sum(X * w, axis=-1) - y          # stable row-wise matvec
        return (_fixed_order_sum(0.5 * r * r) / X.shape[0]
                + 0.5 * l2 * _fixed_order_sum(w * w))

    def full_grad_stable(self, data, w):
        X, y, l2 = data
        r = jnp.sum(X * w, axis=-1) - y
        return jnp.sum(r[:, None] * X, axis=0) / X.shape[0] + l2 * w

    def sample_grad_stable(self, data, i, w):
        X, y, l2 = data
        return (jnp.sum(X[i] * w) - y[i]) * X[i] + l2 * w


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.05)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    print(f"dataset rcv1-like: n={obj.n} p={obj.p}  f*={f_star:.6f}\n")

    # AsySVRG × 3 schemes + serial SVRG + pass-matched Hogwild!, one call:
    # 6 epochs × ~3 passes for the SVRG family, 18 × 1 for Hogwild!
    specs = make_grid(schemes=("consistent", "inconsistent", "unlock"),
                      seeds=(0,), step_sizes=(2.0,), taus=(9,),
                      num_threads=10)
    specs += [svrg_sweep_spec(step_size=2.0)]
    specs += [SweepSpec(algo="hogwild", scheme="unlock", step_size=2.0,
                        num_threads=10, tau=9, epochs=18)]
    res = run_sweep(obj, 6, specs)

    print(f"{'method':28s} {'passes':>7s} {'final gap':>12s}")
    for c, spec in enumerate(res.specs):
        name = {"svrg": "SVRG-serial",
                "hogwild": f"Hogwild!-{spec.scheme}"}.get(
                    spec.algo, f"AsySVRG-{spec.scheme}")
        passes, hist = res.curve(c)
        gap = hist[-1] - f_star
        print(f"{name:28s} {passes[-1]:7.0f} {gap:12.3e}")

    print("\nAsySVRG reaches a much smaller gap at EQUAL effective passes —")
    print("the paper's Figure 1 (right) in one table, from one compile-set.")

    # ---- fused kernel path: the SAME grid as one Pallas megakernel
    # launch per group — rows on the kernel grid, the whole multi-epoch
    # scan inside one launch so the iterate/snapshot/anchor state stays
    # kernel-resident instead of streaming through memory every update.
    # Flip it per spec (engine_mode="fused") or process-wide with
    # REPRO_SWEEP_ENGINE=fused; off TPU it runs under the Pallas
    # interpreter, BIT-EXACT to the vmap engine (asserted here). It
    # profits when groups are wide or scans deep (the memory-bound
    # regime): `python -m benchmarks.kernel_sweep` records measured
    # vmap-vs-fused times next to the roofline-predicted intensity
    # headroom (repro.launch.roofline.sweep_epoch_roofline) per shape.
    import dataclasses

    import numpy as np
    fused = run_sweep(obj, 6, [dataclasses.replace(s, engine_mode="fused")
                               for s in specs])
    assert np.array_equal(fused.histories, res.histories)
    print("\nfused megakernel path: same grid, one launch per group, "
          "bit-exact to the vmap engine")

    # ---- serving sweeps: the same shapes again, served over HTTP. Two
    # tenants submit to a SweepServer and simply wait: the background
    # flush daemon's 25ms deadline fires once, their 2+1 rows coalesce
    # into ONE 3-row compiled group — the exact shape the 3-scheme grid
    # above already compiled — so the dispatch fetches the cached runner
    # and compiles NOTHING. Results come back over the wire bit-identical
    # to an in-process run_sweep.
    base = cache_stats()
    with SweepServer(SweepService(obj, epochs=6),
                     policy=FlushPolicy(max_rows=24,
                                        max_delay_ms=25)) as server:
        client = SweepClient(server.url)
        rid_a = client.submit(make_grid(schemes=("inconsistent",),
                                        seeds=(1, 2), step_sizes=(2.0,),
                                        taus=(9,), num_threads=10),
                              tenant="team-a")
        rid_b = client.submit(make_grid(schemes=("unlock",), seeds=(3,),
                                        step_sizes=(1.0,), taus=(9,),
                                        num_threads=10), tenant="team-b")

        def best_gap(res):
            return min(res.curve(c)[1][-1] - f_star
                       for c in range(len(res.specs)))

        gap_a = best_gap(client.result(rid_a, timeout=600))
        gap_b = best_gap(client.result(rid_b, timeout=600))
        stats = client.stats()

    s, q = stats["service"], stats["request_latency"]
    print(f"\nserving sweeps over HTTP: 2 tenants, {s['rows_submitted']} "
          f"rows -> {s['flushes']} deadline flush, "
          f"{s['rows_coalesced']} rows coalesced, "
          f"{cache_stats().since(base).compiles} new compile(s), "
          f"request p95 {q['p95_ms']:.0f} ms")
    print(f"  team-a best gap {gap_a:.3e}, team-b best gap {gap_b:.3e}"
          "  (each bit-identical to its own run_sweep)")

    # ---- bring your own objective: the Ridge class above through the
    # SAME engine — same specs, same compiled-path machinery, zero new
    # driver code. Pytree-param objectives work identically (see
    # examples/nonconvex_sweep.py for an MLP through the service tier).
    key = jax.random.PRNGKey(0)
    Xr = jax.random.normal(key, (512, 64)) / 8.0
    yr = jnp.sum(Xr[:, :4], axis=-1)             # planted linear signal
    ridge = Ridge(Xr, yr, l2=1e-3)
    rspecs = [SweepSpec(scheme="inconsistent", step_size=s, tau=3,
                        num_threads=4) for s in (0.5, 1.0)]
    rres = run_sweep(ridge, 4, rspecs)
    print("\nbring-your-own objective (ridge regression), same engine:")
    for c, spec in enumerate(rres.specs):
        print(f"  step={spec.step_size:3.1f}: loss "
              f"{rres.histories[c, 0]:.4f} -> {rres.histories[c, -1]:.4f}")


if __name__ == "__main__":
    main()
