"""Comment-driven controls: suppressions and lock annotations.

Two comment grammars ride in source files:

  * ``# repro-lint: ignore[RL001] reason text``  — suppress the named
    rule(s) ON THAT LINE. The reason is MANDATORY: a bare ignore is itself
    reported (RL000), as is an ignore that suppressed nothing — the tree
    can carry suppressions, never unexplained or stale ones.
  * ``# guarded-by: _lock`` / ``# holds: _lock`` — RL003's declarations:
    the first, on an attribute assignment in ``__init__``, declares the
    attribute guarded by that lock; the second, on a ``def`` line (or the
    first line of its body), declares the method is only called with the
    lock already held.

Comments are extracted with `tokenize` so strings containing ``#`` can
never be misread as comments (test fixtures embed violating snippets as
string literals).
"""
from __future__ import annotations

import io
import re
import tokenize
from typing import Dict, List, NamedTuple, Set, Tuple

from repro.analysis.diagnostics import RULES, Diagnostic

_IGNORE_RE = re.compile(
    r"#\s*repro-lint:\s*ignore\[([A-Z0-9,\s]+)\]\s*(.*)$")
_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w|]*)")
_HOLDS_RE = re.compile(r"#\s*holds:\s*([A-Za-z_][\w|]*)")


class Suppression(NamedTuple):
    line: int
    codes: Tuple[str, ...]
    reason: str


class Comments(NamedTuple):
    """Per-file comment facts, line-indexed."""
    suppressions: Dict[int, Suppression]
    guarded_by: Dict[int, Tuple[str, ...]]   # line -> lock names
    holds: Dict[int, Tuple[str, ...]]        # line -> lock names


def scan_comments(source: str) -> Comments:
    suppressions: Dict[int, Suppression] = {}
    guarded: Dict[int, Tuple[str, ...]] = {}
    holds: Dict[int, Tuple[str, ...]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        comments = []
    for line, text in comments:
        m = _IGNORE_RE.search(text)
        if m:
            codes = tuple(c.strip() for c in m.group(1).split(",")
                          if c.strip())
            suppressions[line] = Suppression(line, codes,
                                             m.group(2).strip())
        m = _GUARDED_RE.search(text)
        if m:
            guarded[line] = tuple(m.group(1).split("|"))
        m = _HOLDS_RE.search(text)
        if m:
            holds[line] = tuple(m.group(1).split("|"))
    return Comments(suppressions, guarded, holds)


def apply_suppressions(path: str, comments: Comments,
                       diags: List[Diagnostic],
                       check_unused: bool = True) -> List[Diagnostic]:
    """Drop suppressed findings; report suppression-hygiene violations.

    A diagnostic is suppressed when its line carries an ignore naming its
    code. RL000 findings are emitted for (a) ignores with no reason text,
    (b) ignores naming unknown codes, and (c) ignores that suppressed
    nothing (stale after a fix — delete them). RL000 itself cannot be
    suppressed. ``check_unused=False`` disables (c) — under ``--select``
    subsetting a suppression of an unselected rule is not stale.
    """
    used: Set[int] = set()
    kept: List[Diagnostic] = []
    for d in diags:
        sup = comments.suppressions.get(d.line)
        if sup is not None and d.code in sup.codes and d.code != "RL000":
            used.add(d.line)
        else:
            kept.append(d)
    for line, sup in sorted(comments.suppressions.items()):
        if not sup.reason:
            kept.append(Diagnostic(
                path, line, "RL000",
                f"suppression of {','.join(sup.codes)} has no reason — "
                "append why the finding is acceptable"))
        for code in sup.codes:
            if code not in RULES or code == "RL000":
                kept.append(Diagnostic(
                    path, line, "RL000",
                    f"unknown rule code {code!r} in suppression"))
        if check_unused and line not in used and all(
                c in RULES and c != "RL000" for c in sup.codes):
            kept.append(Diagnostic(
                path, line, "RL000",
                f"unused suppression of {','.join(sup.codes)} — nothing "
                "was diagnosed on this line; delete the stale ignore"))
    return sorted(kept)
