"""Public fused logreg-gradient op: padding + dispatch + λw term.

Mode selection (compiled / interpret / jnp reference) goes through
`repro.kernels.dispatch.kernel_mode` — the one policy all kernels share.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dispatch import kernel_mode
from repro.kernels.logreg_grad.kernel import (
    BLOCK_B, BLOCK_P, grad_accum, margins)
from repro.kernels.logreg_grad.ref import logreg_grad_ref


def logreg_grad(X, y, w, l2: float, interpret: bool = False,
                force_kernel: bool = False):
    mode = kernel_mode(interpret, force_kernel)
    if mode == "reference":
        return logreg_grad_ref(X, y, w, l2)
    interpret = mode == "interpret"
    B, P = X.shape
    padB = (-B) % BLOCK_B
    padP = (-P) % BLOCK_P
    Xp = jnp.pad(X, ((0, padB), (0, padP)))
    yp = jnp.pad(y, (0, padB))[:, None]
    wp = jnp.pad(w, (0, padP))[:, None]
    c = margins(Xp, yp, wp, interpret=interpret)
    # padded rows contribute c = −0·σ(...)  = 0 exactly (y padded with 0);
    # margins normalized by 1/(B+padB) — rescale to the true 1/B
    c = c * ((B + padB) / B)
    g = grad_accum(Xp, c, interpret=interpret)[:P, 0]
    return g + l2 * w
