"""VLM family (llama-3.2-vision-11b backbone).

40 total layers = 8 repeating groups of [self, self, self, CROSS, self] —
the hf cross-attention indices {3, 8, ..., 38}. The vision tower is a STUB
per the assignment: input_specs()/the batch supply precomputed patch
embeddings [B, num_image_tokens, image_embed_dim]; a learned projector maps
them into d_model. Cross-attention layers carry their own MLP and
tanh-gated residuals (gate init 0 → image path starts disabled), matching
the published architecture.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as nn
from repro.models import transformer as tf
from repro.sharding.context import constrain
from repro.sharding.rules import ParamDef

GROUP = 5          # 4 self + 1 cross per group
CROSS_POS = 3      # cross layer index within each group


def _num_groups(cfg: ModelConfig) -> int:
    assert cfg.num_layers % GROUP == 0
    return cfg.num_layers // GROUP


def param_defs(cfg: ModelConfig) -> Dict:
    dt = cfg.param_dtype
    D, V = cfg.d_model, cfg.vocab_size
    G = _num_groups(cfg)
    n_self = G * (GROUP - 1)

    # self blocks stacked [G*(GROUP-1)] — reshaped to [G, GROUP-1] at apply
    self_blocks = tf.block_param_defs(cfg, n_self, dt)

    # cross blocks stacked [G]
    Lx, N, K, h, F = G, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_ff
    cross = {
        "xattn_norm": tf._norm_defs((Lx, D), cfg, dt),
        "xattn": {
            "wq": ParamDef((Lx, D, N, h), ("layers", "embed", "heads", "head_dim"), dtype=dt),
            "wk": ParamDef((Lx, D, K, h), ("layers", "embed", "kv_heads", "head_dim"), dtype=dt),
            "wv": ParamDef((Lx, D, K, h), ("layers", "embed", "kv_heads", "head_dim"), dtype=dt),
            "wo": ParamDef((Lx, N, h, D), ("layers", "heads", "head_dim", "embed"), dtype=dt),
            "q_norm": ParamDef((Lx, h), ("layers", None), "zeros", dtype=dt),
            "k_norm": ParamDef((Lx, h), ("layers", None), "zeros", dtype=dt),
        },
        "mlp_norm": tf._norm_defs((Lx, D), cfg, dt),
        "mlp": {
            "w_gate": ParamDef((Lx, D, F), ("layers", "embed", "mlp"), dtype=dt),
            "w_up": ParamDef((Lx, D, F), ("layers", "embed", "mlp"), dtype=dt),
            "w_down": ParamDef((Lx, F, D), ("layers", "mlp", "embed"), dtype=dt),
        },
        "gate_attn": ParamDef((Lx,), ("layers",), "zeros", dtype=dt),
        "gate_mlp": ParamDef((Lx,), ("layers",), "zeros", dtype=dt),
    }
    return {
        "tok_embed": ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt),
        "img_proj": ParamDef((cfg.image_embed_dim, D), ("embed_no_fsdp", None), dtype=dt),
        "self_blocks": self_blocks,
        "cross_blocks": cross,
        "final_norm": tf._norm_defs((D,), cfg, dt),
        "lm_head": ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt),
    }


def _project_image(cfg, params, image_embeds):
    return jnp.einsum("bte,ed->btd", image_embeds.astype(jnp.dtype(cfg.dtype)),
                      params["img_proj"].astype(jnp.dtype(cfg.dtype)))


def _cross_block(cfg, xp, h, img, img_pos, pos, xkv=None):
    x = nn.apply_norm(cfg, h, xp["xattn_norm"])
    q = jnp.einsum("bsd,dnh->bsnh", x, xp["xattn"]["wq"])
    q = nn.rmsnorm(q, xp["xattn"]["q_norm"])
    if xkv is None:
        k = jnp.einsum("btd,dkh->btkh", img, xp["xattn"]["wk"])
        v = jnp.einsum("btd,dkh->btkh", img, xp["xattn"]["wv"])
        k = nn.rmsnorm(k, xp["xattn"]["k_norm"])
    else:
        k, v = xkv
    k_new, v_new = k, v
    out = nn.attention(q, k, v, pos, img_pos, causal=False, window=0,
                       chunk_q=2048)
    gate_a = jnp.tanh(xp["gate_attn"])
    h = h + gate_a * nn.attn_output(out, xp["xattn"], False)
    x = nn.apply_norm(cfg, h, xp["mlp_norm"])
    gate_m = jnp.tanh(xp["gate_mlp"])
    h = h + gate_m * nn.mlp(x, xp["mlp"], cfg)
    return h, (k_new, v_new)


def hidden_states(cfg: ModelConfig, params, tokens, image_embeds,
                  collect_cache: bool = False):
    B, S = tokens.shape
    G = _num_groups(cfg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    img = _project_image(cfg, params, image_embeds)
    T = img.shape[1]
    img_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :], (B, T))
    h = tf.embed_tokens(cfg, params, tokens)

    grouped = jax.tree.map(
        lambda x: x.reshape((G, GROUP - 1) + x.shape[1:]), params["self_blocks"])

    def body(carry, xs):
        sp, xp = xs
        carry = constrain(carry, tf.RESIDUAL_AXES)
        kvs = []
        for i in range(GROUP - 1):
            lp = jax.tree.map(lambda x: x[i], sp)
            if i == CROSS_POS:
                carry, xkv = _cross_block(cfg, xp, carry, img, img_pos, pos)
                kvs.append(xkv)
            carry, kv = tf.block_apply(cfg, lp, carry, pos, 0)
            kvs.append(kv)
        return constrain(carry, tf.RESIDUAL_AXES), tuple(kvs)

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    h, kvs = jax.lax.scan(body, h, (grouped, params["cross_blocks"]))
    h = nn.apply_norm(cfg, h, params["final_norm"])
    if collect_cache:
        return h, kvs
    return h


def loss_fn(cfg: ModelConfig, params, batch):
    h = hidden_states(cfg, params, batch["tokens"], batch["image_embeds"])
    return nn.lm_loss(h, params["lm_head"], batch["targets"], batch["mask"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    G = _num_groups(cfg)
    K, h = cfg.num_kv_heads, cfg.head_dim
    T = cfg.num_image_tokens
    ax = ("layers", "batch", "cache_kv", "seq_shard", "head_dim")
    return {
        "k": ParamDef((G * (GROUP - 1), batch, K, seq_len, h), ax, "zeros", dtype=cfg.dtype),
        "v": ParamDef((G * (GROUP - 1), batch, K, seq_len, h), ax, "zeros", dtype=cfg.dtype),
        "xk": ParamDef((G, batch, K, T, h), ("layers", "batch", "cache_kv", "seq", "head_dim"), "zeros", dtype=cfg.dtype),
        "xv": ParamDef((G, batch, K, T, h), ("layers", "batch", "cache_kv", "seq", "head_dim"), "zeros", dtype=cfg.dtype),
    }


def prefill(cfg: ModelConfig, params, tokens, image_embeds, cache_len: int):
    h, kvs = hidden_states(cfg, params, tokens, image_embeds,
                           collect_cache=True)
    logits = jnp.einsum("bd,vd->bv", h[:, -1, :], params["lm_head"])

    # kvs is a tuple of 5 stacked entries per group:
    # index 0..2 = self, 3 = cross, 4 = self  (see body() append order)
    self_ks, self_vs, xk, xv = [], [], None, None
    for i, kv in enumerate(kvs):
        if i == CROSS_POS:
            xk, xv = kv
        else:
            self_ks.append(kv[0])
            self_vs.append(kv[1])

    def stack_self(parts):  # list of [G,B,S,K,h] in group order -> [G*4,...]
        x = jnp.stack(parts, axis=1)          # [G, 4, B, S, K, h]
        return x.reshape((-1,) + x.shape[2:])

    def pad_cache(x):  # [L,B,S,K,h] -> [L,B,K,cache_len,h]
        x = x.transpose(0, 1, 3, 2, 4)
        pad = cache_len - x.shape[3]
        return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))).astype(jnp.dtype(cfg.dtype))

    ks = pad_cache(stack_self(self_ks))
    vs = pad_cache(stack_self(self_vs))
    return logits.astype(jnp.float32), {
        "k": ks, "v": vs,
        "xk": xk.transpose(0, 1, 3, 2, 4).astype(jnp.dtype(cfg.dtype)),
        "xv": xv.transpose(0, 1, 3, 2, 4).astype(jnp.dtype(cfg.dtype)),
    }


def decode_step(cfg: ModelConfig, params, cache: Dict, tokens, pos_scalar):
    B = tokens.shape[0]
    G = _num_groups(cfg)
    S = cache["k"].shape[3]
    T = cache["xk"].shape[3]
    pos_q = jnp.broadcast_to(pos_scalar[None, None], (B, 1)).astype(jnp.int32)
    pos_k = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    img_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :], (B, T))
    h = tf.embed_tokens(cfg, params, tokens[:, None])

    grouped = jax.tree.map(
        lambda x: x.reshape((G, GROUP - 1) + x.shape[1:]), params["self_blocks"])
    ck = cache["k"].reshape((G, GROUP - 1) + cache["k"].shape[1:])
    cv = cache["v"].reshape((G, GROUP - 1) + cache["v"].shape[1:])

    def self_attend(lp, hh, k_cache, v_cache):
        x = nn.apply_norm(cfg, hh, lp["attn_norm"])
        q, k, v = nn.gqa_project(x, lp["attn"], cfg, cfg.use_qkv_bias)
        q = nn.apply_rope(q, pos_q, cfg)
        k = nn.apply_rope(k, pos_q, cfg)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.transpose(0, 2, 1, 3).astype(k_cache.dtype), pos_scalar, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.transpose(0, 2, 1, 3).astype(v_cache.dtype), pos_scalar, axis=2)
        out = nn.attention(q, k_cache.transpose(0, 2, 1, 3),
                           v_cache.transpose(0, 2, 1, 3),
                           pos_q, pos_k, causal=True, window=0)
        return hh + nn.attn_output(out, lp["attn"], cfg.use_bias), k_cache, v_cache

    def body(carry, xs):
        sp, xp, kg, vg, xkg, xvg = xs
        nk, nv = [], []
        for i in range(GROUP - 1):
            lp = jax.tree.map(lambda x: x[i], sp)
            if i == CROSS_POS:
                carry, _ = _cross_block(
                    cfg, xp, carry, None, img_pos, pos_q,
                    xkv=(xkg.transpose(0, 2, 1, 3), xvg.transpose(0, 2, 1, 3)))
            carry, k2, v2 = self_attend(lp, carry, kg[i], vg[i])
            x = nn.apply_norm(cfg, carry, lp["mlp_norm"])
            carry = carry + nn.mlp(x, lp["mlp"], cfg)
            nk.append(k2)
            nv.append(v2)
        return carry, (jnp.stack(nk), jnp.stack(nv))

    h, (nk, nv) = jax.lax.scan(
        body, h, (grouped, params["cross_blocks"], ck, cv,
                  cache["xk"], cache["xv"]))
    h = nn.apply_norm(cfg, h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h[:, 0, :], params["lm_head"])
    new_cache = {
        "k": nk.reshape((-1,) + nk.shape[2:]),
        "v": nv.reshape((-1,) + nv.shape[2:]),
        "xk": cache["xk"], "xv": cache["xv"],
    }
    return logits.astype(jnp.float32), new_cache
