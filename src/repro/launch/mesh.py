"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init,
and smoke tests must keep seeing 1 CPU device.

Mesh construction goes through repro.utils.compat so the same code runs on
JAX 0.4.x (no AxisType) and newer releases (Auto axis types requested).
"""
from __future__ import annotations

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=(data,model) single pod (256 chips) or
    (2,16,16)=(pod,data,model) for 2 pods (512 chips).

    The same axis names scale to N pods — the `pod` axis composes with
    `data` in the sharding rules (see repro/sharding/rules.py), so a
    (8,16,16) 2048-chip mesh needs no model-code changes."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names (CPU smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))


_SWEEP_MESHES: dict = {}


def make_sweep_mesh(num_devices: int | None = None):
    """1-D (`data`,) mesh over the host's devices, for config-row sharding.

    `repro.core.sweep.run_sweep` shards each group's config-batch axis over
    the `data` axis of whatever mesh it is given (or the ambient
    `mesh_context` mesh); this factory builds the simplest such mesh — all
    local devices on one axis. CI's forced-8-device CPU job and the sharded
    bench smoke both use it; on real hardware pass `make_production_mesh()`
    instead (same axis name, pod-scale device set).

    Memoized per device count: repeated calls (one per service flush, say)
    return the SAME Mesh object, and `sharding.context.mesh_fingerprint`
    additionally makes distinct-but-equal meshes share compiled-runner
    cache entries.
    """
    import jax
    n = num_devices or len(jax.devices())
    mesh = _SWEEP_MESHES.get(n)
    if mesh is None:
        mesh = _SWEEP_MESHES[n] = make_mesh((n,), ("data",))
    return mesh
