"""Encoder-decoder family (whisper-large-v3 backbone).

The audio frontend (mel + 2x conv) is a STUB per the assignment: the input
pipeline / input_specs() supply precomputed frame embeddings
[B, encoder_seq, encoder_feature_dim]; a learned input projection maps them
to d_model. Sinusoidal positions are used for BOTH encoder and decoder
(whisper uses a 448-entry learned table for the decoder — swapped for
sinusoids so the 32k-decode dry-run cells are well-defined; recorded as a
deviation in configs/whisper_large_v3.py).

Whisper details kept: pre-LN layernorm, GELU (non-GLU) MLP, biases on,
MHA (num_kv_heads == num_heads), tied embeddings, no RoPE.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as nn
from repro.models import transformer as tf
from repro.sharding.context import constrain
from repro.sharding.rules import ParamDef


def _sinusoid(positions, dim: int):
    """[B,S] -> [B,S,dim] f32 sinusoidal embeddings."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(1, half - 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_defs(cfg: ModelConfig, L: int, dtype: str) -> Dict:
    D, N, K, h = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": ParamDef((L, D, N, h), ("layers", "embed", "heads", "head_dim"), dtype=dtype),
        "wk": ParamDef((L, D, K, h), ("layers", "embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": ParamDef((L, D, K, h), ("layers", "embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": ParamDef((L, N, h, D), ("layers", "heads", "head_dim", "embed"), dtype=dtype),
    }
    if cfg.use_qkv_bias:
        p["bq"] = ParamDef((L, N, h), ("layers", "heads", "head_dim"), "zeros", dtype=dtype)
        p["bk"] = ParamDef((L, K, h), ("layers", "kv_heads", "head_dim"), "zeros", dtype=dtype)
        p["bv"] = ParamDef((L, K, h), ("layers", "kv_heads", "head_dim"), "zeros", dtype=dtype)
    if cfg.use_bias:
        p["bo"] = ParamDef((L, D), ("layers", "embed"), "zeros", dtype=dtype)
    return p


def param_defs(cfg: ModelConfig) -> Dict:
    dt = cfg.param_dtype
    D, V, F = cfg.d_model, cfg.vocab_size, cfg.encoder_feature_dim
    Le, Ld = cfg.encoder_layers, cfg.num_layers
    dec_blocks = tf.block_param_defs(cfg, Ld, dt)
    dec_blocks["xattn_norm"] = tf._norm_defs((Ld, D), cfg, dt)
    dec_blocks["xattn"] = _xattn_defs(cfg, Ld, dt)
    return {
        "tok_embed": ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt),
        "enc_in_proj": ParamDef((F, D), ("embed_no_fsdp", None), dtype=dt),
        "enc_blocks": tf.block_param_defs(cfg, Le, dt),
        "enc_final_norm": tf._norm_defs((D,), cfg, dt),
        "dec_blocks": dec_blocks,
        "final_norm": tf._norm_defs((D,), cfg, dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def enc_seq_padded(cfg: ModelConfig, pad_to: int = 16) -> int:
    """Encoder frames padded up to a TP-shardable length (1500 -> 1504):
    1500 does not divide a 16-way axis, which replicated every encoder
    score tensor (+8 GiB/device on whisper train_4k). Padded keys carry
    position -BIG and are masked in _mask_bias."""
    return -(-cfg.encoder_seq // pad_to) * pad_to


def encode(cfg: ModelConfig, params, enc_feats):
    """enc_feats [B, S_enc, F] (stub frontend output) -> [B, S_pad, D]."""
    B, S, _ = enc_feats.shape
    Sp = enc_seq_padded(cfg)
    pad = Sp - S
    if pad:
        enc_feats = jnp.pad(enc_feats, ((0, 0), (0, pad), (0, 0)))
    pos = jnp.where(jnp.arange(Sp) < S, jnp.arange(Sp), -(1 << 30))
    pos = jnp.broadcast_to(pos.astype(jnp.int32)[None, :], (B, Sp))
    h = jnp.einsum("bsf,fd->bsd", enc_feats.astype(jnp.dtype(cfg.dtype)),
                   params["enc_in_proj"].astype(jnp.dtype(cfg.dtype)))
    h = h + _sinusoid(jnp.maximum(pos, 0), cfg.d_model).astype(h.dtype)

    def body(carry, lp):
        carry = constrain(carry, tf.RESIDUAL_AXES)
        x = nn.apply_norm(cfg, carry, lp["attn_norm"])
        q, k, v = nn.gqa_project(x, lp["attn"], cfg, cfg.use_qkv_bias)
        out = nn.attention(q, k, v, pos, pos, causal=False, window=0)
        carry = carry + nn.attn_output(out, lp["attn"], cfg.use_bias)
        x = nn.apply_norm(cfg, carry, lp["mlp_norm"])
        return constrain(carry + nn.mlp(x, lp["mlp"], cfg),
                         tf.RESIDUAL_AXES), None

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return nn.apply_norm(cfg, h, params["enc_final_norm"])


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _dec_block(cfg, lp, h, pos, enc_out, enc_pos, self_kv=None, pos_k=None):
    # causal self-attention
    x = nn.apply_norm(cfg, h, lp["attn_norm"])
    q, k, v = nn.gqa_project(x, lp["attn"], cfg, cfg.use_qkv_bias)
    k_new, v_new = k, v
    if self_kv is not None:
        k, v = self_kv
        pk = pos_k
    else:
        pk = pos
    out = nn.attention(q, k, v, pos, pk, causal=True, window=0, chunk_q=2048)
    h = h + nn.attn_output(out, lp["attn"], cfg.use_bias)
    # cross-attention to encoder states
    x = nn.apply_norm(cfg, h, lp["xattn_norm"])
    q = jnp.einsum("bsd,dnh->bsnh", x, lp["xattn"]["wq"])
    if cfg.use_qkv_bias:
        q = q + lp["xattn"]["bq"]
    ek = jnp.einsum("bsd,dkh->bskh", enc_out, lp["xattn"]["wk"])
    ev = jnp.einsum("bsd,dkh->bskh", enc_out, lp["xattn"]["wv"])
    if cfg.use_qkv_bias:
        ek = ek + lp["xattn"]["bk"]
        ev = ev + lp["xattn"]["bv"]
    out = nn.attention(q, ek, ev, pos, enc_pos, causal=False, window=0,
                       chunk_q=2048)
    h = h + nn.attn_output(out, lp["xattn"], cfg.use_bias)
    # MLP
    x = nn.apply_norm(cfg, h, lp["mlp_norm"])
    return h + nn.mlp(x, lp["mlp"], cfg), (k_new, v_new)


def _enc_positions(cfg, B, Sp):
    p = jnp.where(jnp.arange(Sp) < cfg.encoder_seq, jnp.arange(Sp), -(1 << 30))
    return jnp.broadcast_to(p.astype(jnp.int32)[None, :], (B, Sp))


def _decoder_hidden(cfg, params, tokens, enc_out, collect_cache=False):
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    enc_pos = _enc_positions(cfg, B, enc_out.shape[1])
    h = jnp.take(params["tok_embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    h = h + _sinusoid(pos, cfg.d_model).astype(h.dtype)

    def body(carry, lp):
        carry = constrain(carry, tf.RESIDUAL_AXES)
        out, kv = _dec_block(cfg, lp, carry, pos, enc_out, enc_pos)
        return constrain(out, tf.RESIDUAL_AXES), kv

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    h, kvs = jax.lax.scan(body, h, params["dec_blocks"])
    h = nn.apply_norm(cfg, h, params["final_norm"])
    if collect_cache:
        return h, kvs
    return h


def loss_fn(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["enc_feats"])
    h = _decoder_hidden(cfg, params, batch["tokens"], enc_out)
    return nn.lm_loss(h, params["tok_embed"], batch["targets"], batch["mask"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    L, K, h = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    Se = enc_seq_padded(cfg)
    ax = ("layers", "batch", "cache_kv", "seq_shard", "head_dim")
    return {
        "k": ParamDef((L, batch, K, seq_len, h), ax, "zeros", dtype=cfg.dtype),
        "v": ParamDef((L, batch, K, seq_len, h), ax, "zeros", dtype=cfg.dtype),
        "xk": ParamDef((L, batch, K, Se, h), ax, "zeros", dtype=cfg.dtype),
        "xv": ParamDef((L, batch, K, Se, h), ax, "zeros", dtype=cfg.dtype),
    }


def prefill(cfg: ModelConfig, params, enc_feats, tokens, cache_len: int):
    """Encode audio + run decoder prompt; returns logits + all caches."""
    enc_out = encode(cfg, params, enc_feats)
    h, kvs = _decoder_hidden(cfg, params, tokens, enc_out, collect_cache=True)
    logits = jnp.einsum("bd,vd->bv", h[:, -1, :], params["tok_embed"])

    def pad_cache(x):  # [L,B,S,K,h] -> [L,B,K,cache_len,h]
        x = x.transpose(0, 1, 3, 2, 4)
        pad = cache_len - x.shape[3]
        return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))).astype(jnp.dtype(cfg.dtype))

    # cross K/V computed once from encoder output, per decoder layer
    def xkv(wk, wv, bk=None, bv=None):
        ek = jnp.einsum("bsd,ldkh->lbksh", enc_out, wk)
        ev = jnp.einsum("bsd,ldkh->lbksh", enc_out, wv)
        if bk is not None:
            ek = ek + bk[:, None, :, None, :]
            ev = ev + bv[:, None, :, None, :]
        return ek.astype(jnp.dtype(cfg.dtype)), ev.astype(jnp.dtype(cfg.dtype))

    xa = params["dec_blocks"]["xattn"]
    ek, ev = xkv(xa["wk"], xa["wv"], xa.get("bk"), xa.get("bv"))
    return logits.astype(jnp.float32), {
        "k": pad_cache(kvs[0]), "v": pad_cache(kvs[1]), "xk": ek, "xv": ev}


def decode_step(cfg: ModelConfig, params, cache: Dict, tokens, pos_scalar):
    B = tokens.shape[0]
    S = cache["k"].shape[3]
    Se = cache["xk"].shape[3]
    pos_q = jnp.broadcast_to(pos_scalar[None, None], (B, 1)).astype(jnp.int32)
    pos_k = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    enc_pos = _enc_positions(cfg, B, Se)
    h = jnp.take(params["tok_embed"], tokens[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    h = h + _sinusoid(pos_q, cfg.d_model).astype(h.dtype)

    def body(carry, xs):
        hh, ck_all, cv_all = carry
        lp, xk, xv, i = xs
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        x = nn.apply_norm(cfg, hh, lp["attn_norm"])
        q, k, v = nn.gqa_project(x, lp["attn"], cfg, cfg.use_qkv_bias)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.transpose(0, 2, 1, 3).astype(ck.dtype), pos_scalar, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.transpose(0, 2, 1, 3).astype(cv.dtype), pos_scalar, axis=2)
        out = nn.attention(q, ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
                           pos_q, pos_k, causal=True, window=0)
        hh = hh + nn.attn_output(out, lp["attn"], cfg.use_bias)
        x = nn.apply_norm(cfg, hh, lp["xattn_norm"])
        q = jnp.einsum("bsd,dnh->bsnh", x, lp["xattn"]["wq"])
        if cfg.use_qkv_bias:
            q = q + lp["xattn"]["bq"]
        out = nn.attention(q, xk.transpose(0, 2, 1, 3), xv.transpose(0, 2, 1, 3),
                           pos_q, enc_pos, causal=False, window=0)
        hh = hh + nn.attn_output(out, lp["xattn"], cfg.use_bias)
        x = nn.apply_norm(cfg, hh, lp["mlp_norm"])
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
        return (hh + nn.mlp(x, lp["mlp"], cfg), ck_all, cv_all), None

    (h, nk, nv), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"]),
        (params["dec_blocks"], cache["xk"], cache["xv"],
         jnp.arange(cfg.num_layers)))
    h = nn.apply_norm(cfg, h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h[:, 0, :], params["tok_embed"])
    return logits.astype(jnp.float32), {"k": nk, "v": nv,
                                        "xk": cache["xk"], "xv": cache["xv"]}
