"""Background flush daemon suite: policy triggers, stable widths, job
time-slicing, drain semantics, and the concurrent-tenancy stress test.

Everything here runs WITHOUT a client ever calling ``flush()`` — the point
of the serving tier is that coalesced dispatch happens asynchronously on
size/deadline policy, and every result is still bit-identical to a
standalone ``run_sweep`` of that tenant's specs.
"""
import sys
import threading

import numpy as np
import pytest

from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.server import FairShare, FlushPolicy, ServeDaemon, WidthRegistry
from repro.service import SweepService, cache_stats, clear_cache


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def _specs(seeds, tau=3, threads=4, steps=25):
    return [SweepSpec(scheme="inconsistent", step_size=0.5, tau=tau,
                      num_threads=threads, inner_steps=steps, seed=s)
            for s in seeds]


def _assert_same(got, want):
    np.testing.assert_array_equal(got.histories, want.histories)
    np.testing.assert_array_equal(got.final_w, want.final_w)
    np.testing.assert_array_equal(got.effective_passes,
                                  want.effective_passes)
    assert got.specs == want.specs


# ------------------------------------------------------------- policy knobs
def test_flush_policy_validation():
    with pytest.raises(ValueError):
        FlushPolicy(max_rows=0)
    with pytest.raises(ValueError):
        FlushPolicy(max_delay_ms=-1)
    with pytest.raises(ValueError):
        FlushPolicy(max_pad_factor=0.5)
    with pytest.raises(ValueError):
        FlushPolicy(job_groups_per_slice=0)


def test_deadline_triggered_flush(obj):
    """A lone small request on a quiet server is dispatched by the DEADLINE
    trigger — no client flush, no size threshold reached."""
    svc = SweepService(obj, epochs=1)
    daemon = ServeDaemon(svc, FlushPolicy(max_rows=1000, max_delay_ms=30))
    with daemon:
        rid = svc.submit(_specs([0, 1]))
        res = svc.wait_result(rid, timeout=120)
    _assert_same(res, run_sweep(obj, 1, _specs([0, 1])))
    assert daemon.stats.deadline_flushes >= 1
    assert daemon.stats.size_flushes == 0


def test_size_triggered_flush_coalesces_tenants(obj):
    """Enough rows queued fires the SIZE trigger before the (long)
    deadline, and the flush coalesces the tenants' compatible rows."""
    svc = SweepService(obj, epochs=1)
    daemon = ServeDaemon(svc, FlushPolicy(max_rows=4, max_delay_ms=60_000))
    with daemon:
        rid_a = svc.submit(_specs([2, 3]), tenant="a")
        rid_b = svc.submit(_specs([4, 5]), tenant="b")
        res_a = svc.wait_result(rid_a, timeout=120)
        res_b = svc.wait_result(rid_b, timeout=120)
    _assert_same(res_a, run_sweep(obj, 1, _specs([2, 3])))
    _assert_same(res_b, run_sweep(obj, 1, _specs([4, 5])))
    assert daemon.stats.size_flushes >= 1
    stats = svc.stats()
    assert stats.flushes == 1 and stats.rows_coalesced == 4


def test_stable_widths_keep_warm_path_at_zero_compiles(obj):
    """The width registry pads a smaller same-shape batch up to the width
    already compiled, so the warm path performs 0 new traces; without it
    the narrower batch would retrace (control asserted too)."""
    clear_cache()
    svc = SweepService(obj, epochs=1, width_policy=WidthRegistry())
    svc.submit(_specs([0, 1, 2]))
    svc.flush()                               # natural width 3: compiles
    base = cache_stats()
    rid = svc.submit(_specs([7, 8]))          # width 2 -> padded to 3
    svc.flush()
    assert cache_stats().since(base).compiles == 0
    assert svc.stats().rows_padded == 1
    _assert_same(svc.result(rid), run_sweep(obj, 1, _specs([7, 8])))

    # control: the same drift WITHOUT the registry retraces once
    clear_cache()
    svc2 = SweepService(obj, epochs=1)
    svc2.sweep(_specs([0, 1, 2]))
    base = cache_stats()
    svc2.sweep(_specs([7, 8]))
    assert cache_stats().since(base).compiles >= 1


def test_width_registry_bounds_padding_waste():
    reg = WidthRegistry(max_pad_factor=2.0)
    key = ("asysvrg", 100, 2, 4)
    assert reg((*key,), 1, 8) == 8            # new width: recorded
    assert reg((*key,), 1, 5) == 8            # pad 5 -> 8: within 2x
    assert reg((*key,), 1, 3) == 3            # 8 > 2*3: record 3 instead
    assert reg((*key,), 1, 4) == 8            # 8 == 2*4: exactly at bound
    assert reg((*key,), 1, 2) == 3            # smallest admissible wins
    assert sorted(reg.known_widths((*key,), 1)) == [3, 8]
    assert reg(("other",), 5, 8) == 8         # keys don't bleed


def test_job_time_slicing_interleaves_with_queue(obj):
    """A giant multi-group job runs a slice at a time via
    run_job(max_groups=1) while small requests keep flushing in between —
    the queue is never starved, and the job result is bit-identical to one
    uninterrupted run_sweep."""
    svc = SweepService(obj, epochs=1)
    job_specs = (_specs([1]) +                # three distinct group shapes
                 _specs([2], tau=2, threads=3, steps=20) +
                 [SweepSpec(algo="hogwild", scheme="consistent",
                            step_size=0.5, tau=2, num_threads=3, seed=3)])
    daemon = ServeDaemon(svc, FlushPolicy(max_rows=1000, max_delay_ms=10,
                                          job_groups_per_slice=1))
    with daemon:
        handle = daemon.submit_job(job_specs)
        rid = svc.submit(_specs([9, 10]))     # rides between job slices
        res_req = svc.wait_result(rid, timeout=120)
        res_job = handle.result(timeout=240)
    assert handle.slices == 3                 # one slice per compiled group
    assert daemon.stats.job_slices == 3
    assert daemon.stats.jobs_completed == 1
    _assert_same(res_job, run_sweep(obj, 1, job_specs))
    _assert_same(res_req, run_sweep(obj, 1, _specs([9, 10])))


def test_stop_drains_queue_and_jobs(obj):
    """stop(drain=True) flushes what is still queued and finishes every
    job, so shutdown loses nothing."""
    svc = SweepService(obj, epochs=1)
    daemon = ServeDaemon(svc, FlushPolicy(max_rows=1000,
                                          max_delay_ms=60_000))
    daemon.start()
    rid = svc.submit(_specs([11]))
    handle = daemon.submit_job(_specs([12]))
    daemon.stop(drain=True)
    _assert_same(svc.result(rid), run_sweep(obj, 1, _specs([11])))
    _assert_same(handle.result(timeout=0), run_sweep(obj, 1, _specs([12])))
    assert svc.pending() == 0 and daemon.jobs_pending() == 0


def test_fair_share_slices_successive_flushes(obj):
    """With a FairShare selector, one deadline tick drains the queue in
    successive bounded slices (the daemon loops until the selector leaves
    nothing), and every request still completes bit-identically."""
    svc = SweepService(obj, epochs=1)
    fair = FairShare(quantum_rows=2, max_rows_per_flush=2)
    daemon = ServeDaemon(svc, FlushPolicy(max_rows=1000, max_delay_ms=20),
                         fairness=fair)
    with daemon:
        rids = {svc.submit(_specs([20 + i]), tenant=f"t{i}"): [20 + i]
                for i in range(5)}
        for rid, seeds in rids.items():
            _assert_same(svc.wait_result(rid, timeout=120),
                         run_sweep(obj, 1, _specs(seeds)))
    assert svc.stats().flushes >= 3           # 5 rows through 2-row slices


# --------------------------------------------------- concurrent tenancy
def test_concurrent_tenancy_stress(obj):
    """N tenant threads submit + await against ONE service under the
    background daemon: no lost requests, no duplicate ids, every result
    bit-identical to a standalone run_sweep of that tenant's specs."""
    svc = SweepService(obj, epochs=1)
    daemon = ServeDaemon(svc, FlushPolicy(max_rows=6, max_delay_ms=25))
    n_threads, rounds = 8, 2
    results, errors = {}, []
    ids = []
    id_lock = threading.Lock()

    def tenant(t):
        try:
            for r in range(rounds):
                seeds = [1000 * t + 10 * r, 1000 * t + 10 * r + 1]
                rid = svc.submit(_specs(seeds), tenant=f"tenant-{t}")
                with id_lock:
                    ids.append(rid)
                res = svc.wait_result(rid, timeout=180)
                results[(t, r)] = (seeds, res)
        except Exception as e:                 # pragma: no cover
            errors.append(e)

    with daemon:
        threads = [threading.Thread(target=tenant, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert not errors
    assert len(ids) == len(set(ids)) == n_threads * rounds
    assert len(results) == n_threads * rounds          # nothing lost
    for (t, r), (seeds, res) in results.items():
        _assert_same(res, run_sweep(obj, 1, _specs(seeds)))
    per_tenant = svc.tenant_rows()
    assert len(per_tenant) == n_threads
    assert all(v == (2 * rounds, 2 * rounds) for v in per_tenant.values())


# --------------------------------------------- stats locking (RL003 fixes)
def test_flush_now_counter_survives_thread_races(obj):
    """forced_flushes is bumped under _lock: N threads hammering
    flush_now() concurrently must account every call exactly (the
    pre-lock ``self.stats.forced_flushes += 1`` was a lost-update race
    between HTTP handler threads and the drain path)."""
    svc = SweepService(obj, epochs=1)
    daemon = ServeDaemon(svc)          # not started: queue stays empty,
    n_threads, calls = 8, 50           # flush_now() exercises only the
    old = sys.getswitchinterval()      # counter + the (empty) dispatch
    sys.setswitchinterval(1e-6)        # force frequent preemption
    try:
        threads = [threading.Thread(
            target=lambda: [daemon.flush_now() for _ in range(calls)])
            for _ in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    finally:
        sys.setswitchinterval(old)
    assert daemon.stats_snapshot().forced_flushes == n_threads * calls


def test_stats_snapshot_is_a_decoupled_copy(obj):
    """stats_snapshot() hands out a frozen-in-time COPY — later daemon
    activity must not mutate an exporter's already-taken snapshot."""
    svc = SweepService(obj, epochs=1)
    daemon = ServeDaemon(svc)
    before = daemon.stats_snapshot()
    daemon.flush_now()
    assert before.forced_flushes == 0
    assert before is not daemon.stats
    assert daemon.stats_snapshot().forced_flushes == 1


def test_flush_error_surfaces_through_locked_snapshots(obj, monkeypatch):
    """A poisoned dispatch lands in flush_errors/last_error under _lock,
    is visible through the snapshot accessors (what metrics.snapshot now
    reads), and a later healthy flush clears it."""
    svc = SweepService(obj, epochs=1)
    daemon = ServeDaemon(svc)
    boom = RuntimeError("poisoned dispatch")

    def failing_flush(selector=None):
        raise boom

    monkeypatch.setattr(svc, "flush", failing_flush)
    assert daemon.flush_now() == []
    assert daemon.last_error_snapshot() is boom
    snap = daemon.stats_snapshot()
    assert snap.flush_errors == 1 and snap.forced_flushes == 1

    from repro.server.metrics import snapshot
    exported = snapshot(svc, daemon=daemon)
    assert "poisoned dispatch" in exported["daemon"]["last_error"]
    assert exported["daemon"]["flush_errors"] == 1

    monkeypatch.undo()                 # healthy flush clears the error
    daemon.flush_now()
    assert daemon.last_error_snapshot() is None
    assert snapshot(svc, daemon=daemon)["daemon"]["last_error"] is None
