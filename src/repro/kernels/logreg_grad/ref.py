"""Pure-jnp oracle: minibatch logistic-regression gradient (paper §5).

    g = −(1/B) Xᵀ (y · σ(−y · Xw)) + λ w
"""
from __future__ import annotations

import jax


def logreg_grad_ref(X, y, w, l2: float):
    z = X @ w
    s = jax.nn.sigmoid(-y * z)
    return -(X.T @ (y * s)) / X.shape[0] + l2 * w
