"""Small logging / formatting / timing helpers (no external deps)."""
from __future__ import annotations

import sys
import time


def log(msg: str) -> None:
    print(f"[repro] {msg}", file=sys.stderr, flush=True)


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def fmt_flops(n: float) -> str:
    for unit in ("F", "KF", "MF", "GF", "TF"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}PF"


class Timer:
    """Wall-clock timer context manager."""

    def __init__(self, name: str = ""):
        self.name = name
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self._t0
        return False
