# Model zoo: one module per family; repro.models.factory dispatches on
# ModelConfig.family.
