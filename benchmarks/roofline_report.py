"""Roofline report: render EXPERIMENTS.md §Roofline from the dry-run JSONs."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.roofline import roofline_terms

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(dryrun_dir=DRYRUN_DIR):
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def render_table(recs, mesh="single"):
    lines = ["| arch | shape | peak GiB/dev | t_compute | t_memory | "
             "t_collective | dominant | useful | MFU-UB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["shape"], r["arch"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skipped: {r['reason'][:40]} | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | |")
            continue
        t = roofline_terms(r)
        peak = r["memory"]["peak_per_device_bytes"] / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {peak:.2f} | "
            f"{t['t_compute_s']:.2e} | {t['t_memory_s']:.2e} | "
            f"{t['t_collective_s']:.2e} | {t['dominant']} | "
            f"{min(t['useful_ratio'], 9.99):.3f} | "
            f"{t['mfu_upper_bound']:.3f} |")
    return "\n".join(lines)


def main(quick=True):
    recs = load_records()
    print("name,us_per_call,derived")
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    print(f"roofline_cells,0,ok={n_ok};skipped={n_skip};total={len(recs)}")
    for r in recs:
        if r["status"] != "ok":
            continue
        t = roofline_terms(r)
        print(f"roofline_{r['mesh']}_{r['arch']}_{r['shape']},"
              f"{t['step_lower_bound_s'] * 1e6:.1f},"
              f"dominant={t['dominant']};mfu_ub={t['mfu_upper_bound']:.3f}")


if __name__ == "__main__":
    main()
