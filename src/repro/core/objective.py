"""The paper's objective: L2-regularized logistic regression (paper §5).

    f(w) = (1/n) Σ_i log(1 + exp(-y_i x_i·w)) + (λ/2)||w||²

All pieces the algorithms need are exposed as pure jnp functions:
full objective, full gradient, per-sample gradient (the ∇f_i of Algorithm 1),
and minibatch gradient. Assumptions 1–2 hold: each f_i is convex and
L-smooth with L ≤ max_i ||x_i||²/4 + λ, and f is λ-strongly convex.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _log1pexp(z):
    """Numerically stable log(1 + e^z)."""
    return jnp.logaddexp(0.0, z)


# ---------------------------------------------------------------------------
# vmap-bitwise-stable formulations (used by the AsySVRG engine + sweep)
#
# The sweep engine (repro.core.sweep) runs a batch of configurations through
# jax.vmap and must reproduce the sequential driver BIT-identically. XLA:CPU
# keeps row-reduces over a trailing axis and elementwise ops bitwise-stable
# under an added leading batch axis, but changes the summation order of
# full reductions to a scalar (jnp.mean, jnp.vdot, X @ w). The functions
# below therefore use only row-reduces plus a fixed-order lax.scan for
# scalar accumulation.
# ---------------------------------------------------------------------------

def _fixed_order_sum(v):
    """Σ v_i accumulated strictly in index order (vmap-bitwise-stable)."""
    acc, _ = jax.lax.scan(lambda a, x: (a + x, None),
                          jnp.zeros((), v.dtype), v)
    return acc


def _margins_stable(X, y, w):
    """y ⊙ (X w) as a row-reduce (stable under a leading batch axis on w)."""
    return y * jnp.sum(X * w[None, :], axis=1)


def loss_fixed_order(X, y, l2: float, w):
    """f(w) with fixed-order reductions; equals LogisticRegression.loss up to
    summation order (differences are O(n·eps))."""
    t = _log1pexp(-_margins_stable(X, y, w))
    n = X.shape[0]
    return _fixed_order_sum(t) / n + 0.5 * l2 * _fixed_order_sum(w * w)


def full_grad_stable(X, y, l2: float, w):
    """∇f(w) via row-reduces only (vmap-bitwise-stable)."""
    n = X.shape[0]
    s = jax.nn.sigmoid(-_margins_stable(X, y, w))
    return jnp.sum((-(y * s))[:, None] * X, axis=0) / n + l2 * w


def sample_grad_stable(X, y, l2: float, w, i):
    """∇f_i(w) (vmap-bitwise-stable)."""
    x = X[i]
    yi = y[i]
    s = jax.nn.sigmoid(-yi * jnp.sum(x * w))
    return -yi * s * x + l2 * w


class LogisticRegression:
    """Stateless objective bound to a dataset (X, y, λ)."""

    def __init__(self, X, y, l2_reg: float = 1e-4):
        self.X = jnp.asarray(X)
        self.y = jnp.asarray(y)
        self.l2 = float(l2_reg)
        self.n, self.p = self.X.shape

    # -- objective ---------------------------------------------------------
    def loss(self, w) -> jnp.ndarray:
        margins = self.y * (self.X @ w)
        return jnp.mean(_log1pexp(-margins)) + 0.5 * self.l2 * jnp.vdot(w, w)

    # -- gradients ---------------------------------------------------------
    def full_grad(self, w) -> jnp.ndarray:
        """∇f(w) — the snapshot full gradient of Algorithm 1."""
        margins = self.y * (self.X @ w)
        s = jax.nn.sigmoid(-margins)             # σ(-y x·w)
        return (-(self.y * s) @ self.X) / self.n + self.l2 * w

    def partial_full_grad(self, w, lo: int, size: int) -> jnp.ndarray:
        """Partitioned full-gradient contribution (one thread's φ_a).

        Returns an UN-normalized sum over rows [lo, lo+size); the caller sums
        the partitions and divides by n — exactly the paper's parallel
        snapshot pass.
        """
        Xs = jax.lax.dynamic_slice_in_dim(self.X, lo, size, 0)
        ys = jax.lax.dynamic_slice_in_dim(self.y, lo, size, 0)
        margins = ys * (Xs @ w)
        s = jax.nn.sigmoid(-margins)
        return -(ys * s) @ Xs

    def sample_grad(self, w, i) -> jnp.ndarray:
        """∇f_i(w) for one instance (the paper's inner-loop gradient)."""
        x = self.X[i]
        yi = self.y[i]
        s = jax.nn.sigmoid(-yi * jnp.dot(x, w))
        return -yi * s * x + self.l2 * w

    def minibatch_grad(self, w, idx) -> jnp.ndarray:
        """Mean gradient over a batch of indices (beyond-paper batching)."""
        Xb = self.X[idx]
        yb = self.y[idx]
        s = jax.nn.sigmoid(-yb * (Xb @ w))
        return (-(yb * s) @ Xb) / idx.shape[0] + self.l2 * w

    # -- constants for the theory-facing tests ------------------------------
    def smoothness(self) -> float:
        row_sq = jnp.sum(self.X * self.X, axis=1)
        return float(jnp.max(row_sq) / 4.0 + self.l2)

    def strong_convexity(self) -> float:
        return self.l2

    def optimum(self, tol: float = 1e-12, max_iter: int = 5000) -> Tuple[jnp.ndarray, float]:
        """High-accuracy reference optimum via deterministic gradient descent
        with backtracking-free fixed step 1/L (used to compute the paper's
        "gap < 1e-4" stopping metric)."""
        L = self.smoothness()
        step = 1.0 / L

        def body(carry, _):
            w, = carry
            g = self.full_grad(w)
            return (w - step * g,), None

        (w,), _ = jax.lax.scan(body, (jnp.zeros(self.p),), None, length=max_iter)
        return w, float(self.loss(w))
