"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.compression import topk_compress
from repro.core.distributed import svrg_direction
from repro.kernels.svrg_update.ref import svrg_update_ref
from repro.utils.tree import tree_axpy, tree_l2norm

floats = st.floats(-10, 10, allow_nan=False, allow_subnormal=False, width=32)
arrays = st.lists(floats, min_size=1, max_size=32).map(
    lambda xs: jnp.asarray(xs, jnp.float32))


@settings(max_examples=25, deadline=None)
@given(arrays, arrays.map(lambda x: x), st.floats(-3, 3, width=32))
def test_tree_axpy_linearity(a, b, alpha):
    n = min(a.shape[0], b.shape[0])
    a, b = a[:n], b[:n]
    out = tree_axpy(alpha, {"x": a}, {"x": b})
    np.testing.assert_allclose(np.asarray(out["x"]),
                               alpha * np.asarray(a) + np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(arrays)
def test_tree_norm_matches_numpy(a):
    got = float(tree_l2norm({"x": a, "y": 2.0 * a}))
    want = float(np.sqrt((np.asarray(a) ** 2).sum() * 5.0))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(arrays, st.floats(0.01, 0.99))
def test_topk_decomposition_lossless(a, frac):
    """compress(x) + residual(x) == x — error feedback's soundness."""
    comp, res = topk_compress({"x": a}, frac)
    np.testing.assert_allclose(np.asarray(comp["x"] + res["x"]),
                               np.asarray(a), rtol=1e-6, atol=1e-6)
    # top-k keeps the largest |.| coordinates
    k = max(1, int(a.shape[0] * frac))
    kept = np.nonzero(np.asarray(comp["x"]))[0]
    assert len(kept) <= k


@settings(max_examples=20, deadline=None)
@given(arrays)
def test_svrg_direction_identities(g):
    """v(g, g, gs) == gs and v(g, 0, 0) == g — Eq. 2 edge cases."""
    zeros = {"x": jnp.zeros_like(g)}
    gs = {"x": g * 0.5}
    v1 = svrg_direction({"x": g}, {"x": g}, gs)
    np.testing.assert_allclose(np.asarray(v1["x"]), np.asarray(gs["x"]))
    v2 = svrg_direction({"x": g}, zeros, zeros)
    np.testing.assert_allclose(np.asarray(v2["x"]), np.asarray(g))


@settings(max_examples=20, deadline=None)
@given(arrays, st.floats(0.001, 1.0), st.floats(0.0, 0.1))
def test_svrg_update_fixed_point(u, lr, wd):
    """u is a fixed point of the update iff v + wd·u == 0."""
    zero = jnp.zeros_like(u)
    out = svrg_update_ref(u, zero, zero, zero, lr, wd=0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(u))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_synthetic_data_deterministic(seed):
    from repro.data.synthetic_lm import SyntheticLMDataset
    ds1 = SyntheticLMDataset(256, 16, 4, seed=seed)
    ds2 = SyntheticLMDataset(256, 16, 4, seed=seed)
    b1, b2 = ds1.batch_at(3), ds2.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


# ------------------------------------------------ pytree<->flat bridge
# (objective protocol: the engine runs on ONE flat vector; pytree params
# cross through repro.utils.tree — bit-exact data movement, by property)
@st.composite
def _nested_trees(draw):
    n_leaves = draw(st.integers(1, 4))
    tree = {}
    for i in range(n_leaves):
        rank = draw(st.integers(0, 2))
        shape = tuple(draw(st.integers(1, 3)) for _ in range(rank))
        size = int(np.prod(shape)) if shape else 1
        vals = draw(st.lists(floats, min_size=size, max_size=size))
        leaf = jnp.asarray(vals, jnp.float32).reshape(shape)
        if draw(st.booleans()):
            tree.setdefault("nest", {})[f"k{i}"] = leaf
        else:
            tree[f"k{i}"] = leaf
    return tree


@settings(max_examples=25, deadline=None)
@given(_nested_trees())
def test_tree_ravel_unravel_roundtrip_bit_exact(tree):
    """unravel(ravel(tree)) == tree and ravel(unravel(flat)) == flat, to
    the BIT — the soundness of running pytree objectives on the flat-vector
    engine."""
    from repro.utils.tree import tree_ravel, tree_unravel_fn
    import jax

    flat = tree_ravel(tree)
    assert flat.ndim == 1
    back = tree_unravel_fn(tree)(flat)
    assert (jax.tree.structure(back) == jax.tree.structure(tree))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(tree_ravel(back)),
                                  np.asarray(flat))


@pytest.fixture(scope="module")
def _tiny_mlp():
    from repro.core import mlp_lm_objective
    return mlp_lm_objective(n=4, vocab_size=8, seq_len=2, d_model=4,
                            d_hidden=4)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16), st.floats(0.01, 0.3, width=32))
def test_epoch_core_flat_pytree_equivalence(_tiny_mlp, seed, step):
    """flatten -> epoch core -> unflatten round-trips nested params
    bit-exactly: the engine epoch launched from PYTREE params equals the
    launch from the pre-flattened vector, and the flat result survives
    unravel/ravel unchanged."""
    import jax
    from repro.core import mlp_lm_objective
    from repro.core.asysvrg import SVRGConfig, asysvrg_epoch

    obj = _tiny_mlp
    params = jax.tree.map(
        lambda l, k: 0.1 * jax.random.normal(k, l.shape, l.dtype),
        obj.init_params(),
        dict(zip(obj.init_params(),
                 jax.random.split(jax.random.PRNGKey(seed),
                                  len(obj.init_params())))))
    flat = obj.as_flat(params)
    cfg = SVRGConfig(scheme="inconsistent", step_size=float(step),
                     num_threads=2, tau=1, inner_steps=4)
    key = jax.random.PRNGKey(seed)
    out_tree_launch = asysvrg_epoch(obj, params, key, cfg)
    out_flat_launch = asysvrg_epoch(obj, flat, key, cfg)
    np.testing.assert_array_equal(np.asarray(out_tree_launch),
                                  np.asarray(out_flat_launch))
    rebuilt = obj.as_flat(obj.unravel_params(out_flat_launch))
    np.testing.assert_array_equal(np.asarray(rebuilt),
                                  np.asarray(out_flat_launch))
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 256


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8))
def test_synthetic_data_sharding_partition(num_shards):
    """Shards partition the global batch exactly."""
    from repro.data.synthetic_lm import SyntheticLMDataset
    gb = 8 * num_shards
    full = SyntheticLMDataset(128, 8, gb, seed=1).batch_at(2)["tokens"]
    parts = [SyntheticLMDataset(128, 8, gb, seed=1, shard_index=i,
                                num_shards=num_shards).batch_at(2)["tokens"]
             for i in range(num_shards)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)
