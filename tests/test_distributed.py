"""SPMD AsySVRG pieces: bounded-staleness local updates, compression with
error feedback, wire-size accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SVRGConfig
from repro.core.compression import (
    compressed_bytes,
    compressed_update,
    init_error_feedback,
    int8_compress,
    randk_compress,
    topk_compress,
)
from repro.core.distributed import (
    bounded_staleness_epoch,
    init_svrg_state,
    init_worker_error_feedback,
    reshape_for_workers,
    snapshot_accumulate,
    snapshot_begin,
    snapshot_finalize,
    svrg_direction,
)
from repro.utils.tree import tree_sub
from repro.launch.mesh import make_host_mesh


def _quad_loss(params, batch):
    # strongly convex quadratic: 0.5||w - target||^2 over batch rows
    diff = params["w"][None, :] - batch
    return 0.5 * jnp.mean(jnp.sum(diff * diff, axis=-1))


def test_bounded_staleness_epoch_single_worker_equals_local_steps():
    """On a 1-device mesh, the shard_map path must equal plain sequential
    local SVRG steps (the degenerate W=1 case)."""
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    dim, H = 8, 4
    params = {"w": jnp.zeros(dim)}
    target = jax.random.normal(key, (H, 2, dim))      # H batches of 2 rows
    svrg = init_svrg_state(params)
    svrg = snapshot_begin(svrg)
    svrg = snapshot_accumulate(_quad_loss, params, svrg,
                               target.reshape(-1, dim))
    svrg = snapshot_finalize(params, svrg, 0)

    cfg = SVRGConfig(local_steps=H)
    batches = reshape_for_workers(target, 1, H)       # [1, H, 2, dim]
    out, _ = bounded_staleness_epoch(mesh, _quad_loss, params, svrg, batches,
                                     step_size=0.1, cfg=cfg)

    # sequential reference
    w = params
    for hstep in range(H):
        b = target[hstep]
        g = jax.grad(_quad_loss)(w, b)
        g0 = jax.grad(_quad_loss)(svrg.w_snap, b)
        v = svrg_direction(g, g0, svrg.g_snap)
        w = jax.tree.map(lambda wi, vi: wi - 0.1 * vi, w, v)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(w["w"]),
                               atol=1e-6)


def test_bounded_staleness_converges_on_quadratic():
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(1)
    dim, H, epochs = 16, 8, 10
    target = jax.random.normal(key, (64, dim)) + 3.0
    params = {"w": jnp.zeros(dim)}
    cfg = SVRGConfig(local_steps=H)
    for e in range(epochs):
        svrg = snapshot_finalize(
            params,
            snapshot_accumulate(_quad_loss, params,
                                snapshot_begin(init_svrg_state(params)),
                                target),
            e)
        batches = reshape_for_workers(
            target.reshape(H, 8, dim), 1, H)
        params, _ = bounded_staleness_epoch(mesh, _quad_loss, params, svrg,
                                            batches, step_size=0.3, cfg=cfg)
    w_star = target.mean(0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(w_star),
                               atol=1e-2)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_topk_keeps_largest_and_residual_exact():
    x = {"a": jnp.asarray([1.0, -5.0, 0.1, 3.0])}
    comp, res = topk_compress(x, frac=0.5)
    np.testing.assert_allclose(np.asarray(comp["a"]), [0.0, -5.0, 0.0, 3.0])
    # compressed + residual == original exactly (lossless decomposition)
    np.testing.assert_allclose(np.asarray(comp["a"] + res["a"]),
                               np.asarray(x["a"]))


def test_randk_unbiased():
    # 800 trials: per-coord std = 4*sqrt(.25*.75/800) ~= 0.061, so the max
    # deviation over 64 coords (~2.9 sigma ~= 0.18) sits well inside atol.
    key = jax.random.PRNGKey(2)
    x = {"a": jnp.ones(64)}
    outs = []
    for i in range(800):
        comp, _ = randk_compress(x, 0.25, jax.random.fold_in(key, i))
        outs.append(np.asarray(comp["a"]))
    mean = np.stack(outs).mean(0)
    np.testing.assert_allclose(mean, np.ones(64), atol=0.25)


def test_int8_bounded_error():
    key = jax.random.PRNGKey(3)
    x = {"a": jax.random.normal(key, (256,))}
    comp, res = int8_compress(x, key)
    scale = float(jnp.max(jnp.abs(x["a"]))) / 127.0
    assert float(jnp.max(jnp.abs(res["a"]))) <= scale * 1.01


def test_error_feedback_accumulates():
    """EF: what is not transmitted now is carried and re-injected later —
    over many rounds the mean transmitted equals the mean gradient."""
    key = jax.random.PRNGKey(4)
    g = {"a": jnp.asarray([1.0, 0.01, 0.02, 0.005])}
    ef = init_error_feedback(g)
    sent_total = jnp.zeros(4)
    rounds = 50
    for i in range(rounds):
        sent, ef = compressed_update(g, ef, "topk", 0.25,
                                     jax.random.fold_in(key, i))
        sent_total = sent_total + sent["a"]
    np.testing.assert_allclose(np.asarray(sent_total / rounds),
                               np.asarray(g["a"]), atol=0.05)


def test_compressed_bytes_accounting():
    tree = {"a": jnp.zeros((100,)), "b": jnp.zeros((10, 10))}
    assert compressed_bytes(tree, "none", 0.0) == 4 * 200
    assert compressed_bytes(tree, "topk", 0.01) == 2 * (1 * 8)
    assert compressed_bytes(tree, "int8", 0.0) == 200 + 8


def test_compressed_reconcile_still_converges():
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(5)
    dim, H = 16, 4
    target = jax.random.normal(key, (32, dim)) + 1.0
    params = {"w": jnp.zeros(dim)}
    cfg = SVRGConfig(local_steps=H, compression="topk", compression_k=0.5)
    ef = None
    for e in range(12):
        svrg = snapshot_finalize(
            params, snapshot_accumulate(
                _quad_loss, params,
                snapshot_begin(init_svrg_state(params)), target), e)
        batches = reshape_for_workers(target.reshape(H, 8, dim), 1, H)
        params, ef = bounded_staleness_epoch(mesh, _quad_loss, params, svrg,
                                             batches, step_size=0.3, cfg=cfg,
                                             rng=jax.random.fold_in(key, e),
                                             ef=ef)
    err = float(jnp.linalg.norm(params["w"] - target.mean(0)))
    assert err < 0.25, err


def test_error_feedback_residual_carried_across_epochs():
    """Regression: the compression residual must PERSIST across epochs.

    A fresh `init_error_feedback` inside every call silently discarded the
    updated state, so nothing untransmitted was ever re-injected — error
    feedback (the point of the Stich-style compressor) never accumulated.
    Now the [W]-leading EF state threads in/out: epoch 1's residual equals
    the manual compress-of-delta remainder, and epoch 2's reconcile with
    the carried residual differs from one with a zeroed residual.
    """
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    dim, H = 8, 4
    params = {"w": jnp.zeros(dim)}
    target = jax.random.normal(key, (H, 2, dim))
    svrg = snapshot_finalize(
        params, snapshot_accumulate(
            _quad_loss, params,
            snapshot_begin(init_svrg_state(params)),
            target.reshape(-1, dim)), 0)
    cfg = SVRGConfig(local_steps=H, compression="topk", compression_k=0.25)
    batches = reshape_for_workers(target, 1, H)
    rng = jax.random.PRNGKey(9)

    params1, ef1 = bounded_staleness_epoch(mesh, _quad_loss, params, svrg,
                                           batches, step_size=0.1, cfg=cfg,
                                           rng=rng)
    res1 = np.asarray(ef1.residual["w"])
    assert res1.shape == (1, dim)             # [W=1]-leading, per-worker
    assert np.abs(res1).sum() > 0             # top-k at 25% left a remainder

    # manual reference: delta from W=1 sequential local steps; the worker's
    # key is split exactly as bounded_staleness_epoch does
    w = params
    for h in range(H):
        b = target[h]
        g = jax.grad(_quad_loss)(w, b)
        g0 = jax.grad(_quad_loss)(svrg.w_snap, b)
        v = svrg_direction(g, g0, svrg.g_snap)
        w = jax.tree.map(lambda wi, vi: wi - 0.1 * vi, w, v)
    delta = tree_sub(w, params)
    wkey = jax.random.split(rng, 2)[0]
    sent, ef_ref = compressed_update(
        delta, init_error_feedback(delta), "topk", 0.25, wkey)
    np.testing.assert_allclose(res1[0], np.asarray(ef_ref.residual["w"]),
                               rtol=1e-6)

    # epoch 2: carried residual is re-injected -> different reconcile than
    # a (buggy) zeroed one
    rng2 = jax.random.fold_in(rng, 1)
    with_ef, ef2 = bounded_staleness_epoch(mesh, _quad_loss, params1, svrg,
                                           batches, step_size=0.1, cfg=cfg,
                                           rng=rng2, ef=ef1)
    without_ef, _ = bounded_staleness_epoch(mesh, _quad_loss, params1, svrg,
                                            batches, step_size=0.1, cfg=cfg,
                                            rng=rng2)
    assert not np.allclose(np.asarray(with_ef["w"]),
                           np.asarray(without_ef["w"]))
    assert ef2.residual["w"].shape == (1, dim)


def test_init_worker_error_feedback_shapes():
    params = {"w": jnp.zeros(6), "b": jnp.zeros((2, 3))}
    ef = init_worker_error_feedback(params, 4)
    assert ef.residual["w"].shape == (4, 6)
    assert ef.residual["b"].shape == (4, 2, 3)
    assert float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(ef.residual))) == 0.0
