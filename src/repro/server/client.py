"""Python client for the sweep server's HTTP API (stdlib urllib only).

    client = SweepClient("http://127.0.0.1:8742")
    rid = client.submit(specs, tenant="team-a")     # returns immediately
    res = client.result(rid, timeout=60)            # long-polls the server
    # res is a SweepResult, bit-identical to run_sweep(obj, epochs, specs)

``result`` long-polls: each round the SERVER blocks up to its per-request
wait bound and answers 504/"pending" if the flush daemon hasn't run the
request yet; the client re-polls until its own ``timeout``. Submitting
never triggers execution — batching is entirely the server's policy —
except through :meth:`flush`, the explicit escape hatch.

``submit`` returns a `SubmitTicket` — an ``int`` (so existing callers
keep working) that also carries the server-minted ``.trace_id`` echoed in
the response's ``X-Trace-Id`` header. Pass it (or an explicit
``trace_id=``) back into :meth:`result`/:meth:`watch` and the client
sends ``X-Trace-Id`` on the outgoing request, correlating client-side
polls with the server's flight recorder.

Live progress: :meth:`submit_job` starts a time-sliced background job and
:meth:`watch` long-polls ``GET /watch`` for its per-slice loss events
while :meth:`job_result` waits for the final `SweepResult`.

Error mapping mirrors the service's in-process exceptions: 404 raises
KeyError, 410 raises `repro.service.ResultEvictedError`, 400 raises
ValueError, anything else `ServerError`.
"""
from __future__ import annotations

import json
import time
import urllib.error
import urllib.request
from typing import Dict, List, Optional, Sequence, Tuple
from urllib.parse import quote

from repro.core.sweep import SweepResult, SweepSpec
from repro.server.http import result_from_dict, spec_to_dict
from repro.service.api import ResultEvictedError


class ServerError(RuntimeError):
    """A non-2xx response that doesn't map to a standard exception."""

    def __init__(self, status: int, payload: dict):
        super().__init__(f"HTTP {status}: {payload.get('error', payload)}")
        self.status = status
        self.payload = payload


class SubmitTicket(int):
    """The request id from ``POST /submit``, plus the echoed trace id.

    Subclassing ``int`` keeps every pre-existing call site working
    (``client.result(rid)``, dict keys, formatting) while new code reads
    ``rid.trace_id`` to correlate with ``GET /trace?id=...``."""

    trace_id: Optional[str]

    def __new__(cls, request_id: int,
                trace_id: Optional[str] = None) -> "SubmitTicket":
        obj = super().__new__(cls, request_id)
        obj.trace_id = trace_id
        return obj


class SweepClient:
    def __init__(self, base_url: str, *, timeout: float = 30.0,
                 poll_s: float = 10.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout           # per-HTTP-call socket timeout
        self.poll_s = poll_s             # server-side wait per result poll

    # ------------------------------------------------------------ plumbing
    def _call_full(self, method: str, path: str,
                   body: Optional[dict] = None,
                   headers: Optional[Dict[str, str]] = None
                   ) -> Tuple[dict, Dict[str, str]]:
        """One HTTP round trip -> (json payload, response headers)."""
        data = None if body is None else json.dumps(body).encode()
        send = {"Content-Type": "application/json"}
        if headers:
            send.update(headers)
        req = urllib.request.Request(
            self.base_url + path, data=data, method=method, headers=send)
        try:
            # socket timeout must outlast the server-side result wait
            with urllib.request.urlopen(
                    req, timeout=self.timeout + self.poll_s) as resp:
                return json.loads(resp.read().decode()), dict(resp.headers)
        except urllib.error.HTTPError as e:
            try:
                payload = json.loads(e.read().decode())
            except (ValueError, OSError):
                payload = {"error": str(e)}
            raise self._map_error(e.code, payload) from None

    def _call(self, method: str, path: str,
              body: Optional[dict] = None,
              headers: Optional[Dict[str, str]] = None) -> dict:
        return self._call_full(method, path, body, headers)[0]

    @staticmethod
    def _trace_headers(trace_id: Optional[str]) -> Optional[Dict[str, str]]:
        return {"X-Trace-Id": trace_id} if trace_id else None

    @staticmethod
    def _map_error(status: int, payload: dict) -> Exception:
        message = payload.get("error", f"HTTP {status}")
        if status == 404 and payload.get("status") == "unknown":
            return KeyError(message)
        if status == 410:
            return ResultEvictedError(message)
        if status == 504:
            return TimeoutError(message)
        if status == 400:
            return ValueError(message)
        return ServerError(status, payload)

    # ------------------------------------------------------------- the API
    def healthz(self) -> dict:
        return self._call("GET", "/healthz")

    def stats(self) -> dict:
        return self._call("GET", "/stats")

    def metrics(self) -> str:
        """The raw Prometheus text exposition from ``GET /metrics``."""
        req = urllib.request.Request(self.base_url + "/metrics")
        with urllib.request.urlopen(req, timeout=self.timeout) as resp:
            return resp.read().decode()

    def trace(self, trace_id: Optional[str] = None) -> dict:
        """Flight-recorder state: recent traces + last-error dump, or one
        request's full span tree when ``trace_id`` is given (KeyError once
        it has been evicted from the ring buffer)."""
        path = "/trace" if trace_id is None else f"/trace?id={trace_id}"
        return self._call("GET", path)

    def submit(self, specs: Sequence[SweepSpec],
               epochs: Optional[int] = None, *, tenant: str = "default",
               priority: int = 0) -> SubmitTicket:
        body = {"specs": [spec_to_dict(s) for s in specs],
                "tenant": tenant, "priority": priority}
        if epochs is not None:
            body["epochs"] = epochs
        payload, hdrs = self._call_full("POST", "/submit", body)
        return SubmitTicket(
            int(payload["request_id"]),
            payload.get("trace_id") or hdrs.get("X-Trace-Id"))

    def flush(self) -> List[int]:
        """Force a flush now (the eager path; normally the server's flush
        daemon decides when to dispatch)."""
        return [int(i) for i in self._call("POST", "/flush")["completed"]]

    def result(self, request_id: int,
               timeout: Optional[float] = 60.0, *,
               trace_id: Optional[str] = None) -> SweepResult:
        """Long-poll until the request's result is served (TimeoutError
        after ``timeout`` seconds; None polls forever). ``trace_id``
        (defaulting to a `SubmitTicket`'s own) is sent as ``X-Trace-Id``
        so the poll correlates with the server-side trace."""
        if trace_id is None:
            trace_id = getattr(request_id, "trace_id", None)
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            remaining = (self.poll_s if deadline is None
                         else deadline - time.monotonic())
            if remaining <= 0:
                raise TimeoutError(
                    f"request {request_id} not served within {timeout}s")
            try:
                payload = self._call(
                    "GET", f"/result/{request_id}"
                    f"?timeout_s={min(self.poll_s, remaining):.3f}",
                    headers=self._trace_headers(trace_id))
            except TimeoutError:
                continue                 # server said "pending": poll again
            return result_from_dict(payload)

    # ------------------------------------------------------- live progress
    def watch(self, watch_id: Optional[str] = None, *, cursor: int = 0,
              timeout_s: Optional[float] = None,
              trace_id: Optional[str] = None) -> dict:
        """One long-poll round on the live-progress bus. Returns
        ``{"events": [...], "cursor": N, "enabled": bool}``; feed the
        returned ``cursor`` into the next call to resume past events
        already seen. ``watch_id=None`` streams the firehose (every
        channel); jobs publish on ``"job-<id>"`` and flushed requests on
        ``"req-<id>"``. Empty ``events`` just means nothing new within
        ``timeout_s`` — keep polling while the job runs."""
        wait = self.poll_s if timeout_s is None else timeout_s
        params = [f"cursor={int(cursor)}", f"timeout_s={float(wait):.3f}"]
        if watch_id is not None:
            params.insert(0, f"id={quote(watch_id)}")
        return self._call("GET", "/watch?" + "&".join(params),
                          headers=self._trace_headers(trace_id))

    def submit_job(self, specs: Sequence[SweepSpec],
                   epochs: Optional[int] = None, *,
                   tenant: str = "default") -> dict:
        """Start a time-sliced background job on the server's flush
        daemon. Returns ``{"job_id": N, "watch_id": "job-N"}`` — stream
        :meth:`watch` with that id while it runs, then
        :meth:`job_result`."""
        body = {"specs": [spec_to_dict(s) for s in specs],
                "tenant": tenant}
        if epochs is not None:
            body["epochs"] = epochs
        return self._call("POST", "/job", body)

    def job_result(self, job_id: int,
                   timeout: Optional[float] = 60.0) -> SweepResult:
        """Long-poll ``GET /job/<id>`` until the sliced job finishes."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            remaining = (self.poll_s if deadline is None
                         else deadline - time.monotonic())
            if remaining <= 0:
                raise TimeoutError(
                    f"job {job_id} not finished within {timeout}s")
            try:
                payload = self._call(
                    "GET", f"/job/{int(job_id)}"
                    f"?timeout_s={min(self.poll_s, remaining):.3f}")
            except TimeoutError:
                continue                 # still slicing: poll again
            return result_from_dict(payload)

    def ledger(self) -> dict:
        """The per-group performance ledger (``GET /ledger``):
        ``{"enabled": bool, "groups": {label: entry-dict}}``."""
        return self._call("GET", "/ledger")

    def sweep(self, specs: Sequence[SweepSpec],
              epochs: Optional[int] = None, *, tenant: str = "default",
              priority: int = 0,
              timeout: Optional[float] = 60.0) -> SweepResult:
        """submit + result in one call (still batched by server policy)."""
        return self.result(
            self.submit(specs, epochs, tenant=tenant, priority=priority),
            timeout=timeout)
