"""Train-loop integration: SVRG on a tiny LM decreases loss; checkpoint
resume continues mid-run (simulated failure)."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import SVRGConfig, TrainConfig
from repro.configs import reduced_config
from repro.data.synthetic_lm import SyntheticLMDataset
from repro.models.factory import build_model
from repro.train.loop import train
from repro.train.state import init_train_state, make_snapshot_fns


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("chatglm3-6b").with_overrides(
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=128)
    bundle = build_model(cfg)
    ds = SyntheticLMDataset(cfg.vocab_size, seq_len=32, global_batch=8)
    return bundle, ds


def _tcfg(steps, ckdir="", opt="svrg"):
    return TrainConfig(
        steps=steps, optimizer=opt, learning_rate=1.0, warmup_steps=2,
        schedule="constant", checkpoint_dir=ckdir, checkpoint_every=5,
        log_every=50,
        svrg=SVRGConfig(snapshot_every=10, snapshot_batches=2))


def test_svrg_training_decreases_loss(setup):
    bundle, ds = setup
    losses = []
    train(bundle, _tcfg(50), ds.batch_at,
          hooks=lambda s, m: losses.append(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_sgd_baseline_trains(setup):
    bundle, ds = setup
    losses = []
    train(bundle, _tcfg(30, opt="sgd"), ds.batch_at,
          hooks=lambda s, m: losses.append(m["loss"]))
    assert losses[-1] < losses[0]


def test_checkpoint_resume_after_failure(setup, tmp_path):
    """Run 12 steps (checkpoints at 5, 10); 'crash'; resume completes to 20
    starting from step 10, and matches a no-crash run's final loss."""
    bundle, ds = setup
    ckdir = str(tmp_path / "ck")

    train(bundle, _tcfg(12, ckdir), ds.batch_at)          # crashes after 12
    from repro.checkpoint import Checkpointer
    steps_available = Checkpointer(ckdir).list_steps()
    assert 10 in steps_available

    seen = []
    train(bundle, _tcfg(20, ckdir), ds.batch_at,
          hooks=lambda s, m: seen.append(s))
    assert seen, "resume ran no steps"
    assert min(seen) >= 10, f"resume restarted from scratch: {seen}"


def test_snapshot_fns_roundtrip(setup):
    bundle, ds = setup
    tcfg = _tcfg(1)
    state = init_train_state(jax.random.PRNGKey(0), bundle, tcfg)
    begin, accum, fin = make_snapshot_fns(bundle, tcfg)
    state = begin(state)
    state = accum(state, ds.batch_at(0))
    state = accum(state, ds.batch_at(1))
    state = fin(state)
    assert int(state.svrg.accum_count) == 0
    # w_snap == params after finalize
    for a, b in zip(jax.tree.leaves(state.svrg.w_snap),
                    jax.tree.leaves(state.params)):
        assert jnp.array_equal(a, b)
    # g_snap nonzero
    norms = [float(jnp.sum(jnp.abs(g)))
             for g in jax.tree.leaves(state.svrg.g_snap)]
    assert sum(norms) > 0


def test_svrg_direction_reduces_to_full_grad_at_snapshot(setup):
    """With w == w_snap and the same batch, v == g_snap exactly — the
    control variate nulls the stochastic part (Algorithm 1, m=0)."""
    bundle, ds = setup
    tcfg = _tcfg(1)
    state = init_train_state(jax.random.PRNGKey(0), bundle, tcfg)
    begin, accum, fin = make_snapshot_fns(bundle, tcfg)
    state = fin(accum(begin(state), ds.batch_at(0)))
    from repro.core.distributed import svrg_direction
    g = jax.grad(bundle.loss_fn)(state.params, ds.batch_at(5))
    g0 = jax.grad(bundle.loss_fn)(state.svrg.w_snap, ds.batch_at(5))
    v = svrg_direction(g, g0, state.svrg.g_snap)
    for vl, gl in zip(jax.tree.leaves(v), jax.tree.leaves(state.svrg.g_snap)):
        assert jnp.allclose(vl, gl, atol=1e-6)
