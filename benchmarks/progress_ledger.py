"""Live-observability smoke: progress streaming + performance ledger.

Runs one multi-group job through ``SweepService.run_job`` with the whole
PR-10 observability stack on — live progress bus, divergence watchdog,
per-group performance ledger — and writes the schema-gated
``BENCH_progress_ledger.json`` the perf-trajectory tooling keys on:

  * ``groups`` — the ledger snapshot, one entry per compiled group
    runner. The gate (`benchmarks.check_artifacts`) requires >= 2 group
    entries each carrying ``compile_s``, ``flops`` and ``attained_frac``
    (XLA's own ``cost_analysis`` FLOPs when the backend provides them,
    the analytic epoch model otherwise — ``flops_source`` says which).
  * ``progress`` — what the live stream delivered: slice events BEFORE
    the job finished, and per-row event losses that match the final
    `SweepResult` histories bit-for-bit (checked here, hard failure).
  * ``watchdog`` — one deliberately diverging row (``step_size=1e30``
    NaNs on epoch 1) cancelled by ``cancel_row`` while every survivor
    stays bit-identical; the artifact records the cancelled count.

Two groups come from two ``inner_steps`` values (the group key includes
the per-epoch update count), so both a cold compile and the ledger's
roofline attribution are exercised per group.
"""
from __future__ import annotations

import sys
import tempfile

import numpy as np

from benchmarks.artifacts import write_bench_json
from repro.checkpoint import Checkpointer
from repro.core import LogisticRegression, SweepSpec
from repro.data.libsvm import make_synthetic_libsvm
from repro.obs.ledger import disable_ledger, enable_ledger
from repro.obs.progress import disable_progress, enable_progress, \
    progress_bus
from repro.obs.watchdog import Watchdog
from repro.service import SweepService

WATCH_ID = "bench-progress-ledger"


def _specs(rows_per_group: int):
    """Two compiled groups (inner_steps 23 vs 46 — values no other
    benchmark uses, so the cold-compile attribution holds even when this
    runs after others in one process) plus one row that diverges
    immediately — same group as the first, so the watchdog's re-dispatch
    is a cache hit, not a new compile."""
    good = [SweepSpec(scheme="inconsistent", step_size=0.5, tau=3,
                      num_threads=4, inner_steps=steps, seed=7 * c + steps)
            for steps in (23, 46) for c in range(rows_per_group)]
    bad = [SweepSpec(scheme="inconsistent", step_size=1e30, tau=3,
                     num_threads=4, inner_steps=23, seed=999)]
    return good + bad


def run(quick: bool = False) -> dict:
    ds = make_synthetic_libsvm("real-sim", seed=11,
                               scale=0.002 if quick else 0.01)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    epochs = 2 if quick else 3
    specs = _specs(rows_per_group=2 if quick else 4)

    svc = SweepService(obj, epochs=epochs,
                       watchdog=Watchdog(policy="cancel_row"))
    enable_progress()
    enable_ledger().clear()
    bus = progress_bus()
    bus.clear()
    try:
        events = []
        cursor = 0
        with tempfile.TemporaryDirectory() as spool:
            ckpt = Checkpointer(spool)
            done = False
            while not done:
                # one group per slice: every boundary publishes an event
                res, done = svc.run_job(specs, epochs, checkpointer=ckpt,
                                        max_groups=1,
                                        progress_id=WATCH_ID)
                got, cursor = bus.watch(cursor=cursor, watch_id=WATCH_ID,
                                        timeout=0.0)
                events.extend(got)
                if not done and not any(e.kind == "slice" for e in events):
                    raise AssertionError(
                        "no slice event arrived before job completion — "
                        "the live stream is not live")

        kinds = [e.kind for e in events]
        if kinds.count("done") != 1 or "slice" not in kinds:
            raise AssertionError(f"unexpected event stream {kinds}")

        # the stream must be exact, not approximate: per-row losses in the
        # final slice events == the result histories, bit for bit
        last_loss = {}
        for e in events:
            for row, losses in zip(e.rows, e.losses):
                last_loss[row] = losses
        for row, losses in last_loss.items():
            budget = int(res.epochs_per_row[row])
            want = res.histories[row, :budget + 1]
            got = np.asarray(losses, np.float32)
            if not np.array_equal(got, want):
                raise AssertionError(
                    f"row {row}: streamed losses diverge from the final "
                    f"histories ({got} vs {want})")

        diverged = np.flatnonzero(res.diverged_rows >= 0)
        if diverged.tolist() != [len(specs) - 1]:
            raise AssertionError(
                f"watchdog should cancel exactly the step_size=1e30 row, "
                f"got diverged rows {diverged.tolist()}")

        groups = enable_ledger().snapshot()
        if len(groups) < 2:
            raise AssertionError(
                f"expected >= 2 ledger group entries, got {sorted(groups)}")
        for label, entry in groups.items():
            for k in ("compile_s", "flops", "attained_frac"):
                if not entry.get(k, 0.0) > 0.0:
                    raise AssertionError(
                        f"ledger entry {label}: {k} not populated "
                        f"({entry.get(k)!r})")

        return {
            "dataset": "real-sim", "epochs": epochs, "rows": len(specs),
            "groups": groups,
            "progress": {
                "watch_id": WATCH_ID,
                "events": len(events),
                "slice_events": kinds.count("slice"),
                "losses_bit_exact": True,
            },
            "watchdog": {
                "policy": "cancel_row",
                "diverged_rows": diverged.tolist(),
                "survivors": int(len(specs) - len(diverged)),
            },
        }
    finally:
        disable_progress(clear=True)
        disable_ledger(clear=True)


def main(quick: bool = True):
    out = run(quick=quick)
    write_bench_json("progress_ledger", out)
    print("name,us_per_call,derived")
    for label, entry in sorted(out["groups"].items()):
        print(f"ledger_{label},{entry['warm_wall_min_s'] * 1e6:.0f},"
              f"compile_s={entry['compile_s']:.3f};"
              f"flops={entry['flops']:.3e};"
              f"attained_frac={entry['attained_frac']:.4f};"
              f"src={entry.get('flops_source', '')}")
    print(f"progress_events,0,slices={out['progress']['slice_events']};"
          f"diverged={out['watchdog']['diverged_rows']}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
