"""Diagnostic records and the rule registry for repro-lint.

Every checker reports `Diagnostic`s with a STABLE rule code (RL001…) so
suppressions (`# repro-lint: ignore[RL001] reason`), CI greps and the docs
(docs/INVARIANTS.md) can all key on the same identifier forever. Codes are
never reused; retired rules keep their number.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# code -> one-line summary (the CLI's --explain output and the docs anchor)
RULES: Dict[str, str] = {
    "RL000": "suppression hygiene: every `# repro-lint: ignore[...]` needs a "
             "reason and must actually suppress something",
    "RL001": "bitwise-stability: vmap-bitwise-stable scopes (*_stable / "
             "loss_fixed_order) may only use elementwise ops, explicit-axis "
             "reduces, and fixed-order scans",
    "RL002": "trace-safety: jit/pallas bodies must not close over arrays, "
             "branch on tracer arguments, or return unhashable statics",
    "RL003": "lock-discipline: attributes declared guarded-by a lock may "
             "only be touched while holding it",
    "RL004": "key-completeness: every static that shapes a compiled program "
             "must reach the group/runner cache keys",
    "RL005": "kernel purity: Pallas kernel bodies are effect-free (no "
             "print/env/callbacks; mode decisions live in kernels/dispatch)",
    "RL006": "obs-boundary: no timing/tracing/metrics calls inside *_core "
             "jitted scopes or kernel modules — observability brackets "
             "compiled programs, it never runs inside them",
}


@dataclasses.dataclass(frozen=True, order=True)
class Diagnostic:
    """One finding: ``path:line: code message`` (sortable in file order)."""
    path: str
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"
