"""Histograms for the serving tier's hot-path distributions.

`repro.server.metrics.snapshot` already exports p50/p95 over a bounded
window of recent latencies; Prometheus wants the complementary view — a
CUMULATIVE bucket histogram over the service lifetime, scrape-rate
independent and aggregable across replicas. `Histogram` is the minimal
stdlib implementation of the text-exposition contract: fixed upper
bounds, cumulative counts at render time, `_sum`/`_count` series.

`ServiceHistograms` is the fixed set every `SweepService` carries
(observed inside `flush()`, on by default — four integer increments per
flush is noise next to an XLA dispatch, but the ``enabled`` flag lets
`benchmarks/obs_overhead.py` attribute per-feature overhead deltas):

  * ``flush_latency_seconds``   — one coalesced dispatch, wall clock
  * ``request_latency_seconds`` — submit -> result-available, per request
  * ``rows_per_flush``          — coalesced batch size (did batching work?)
  * ``pad_factor``              — dispatched/natural rows (what the
    stable-width policy's 0-compile warm path costs in padded FLOPs)

Thread-safety: each histogram owns a lock; observers never touch the
service lock, so recording can't extend any critical section.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Sequence, Tuple

# Latency buckets: 1 ms .. 30 s, roughly x2.5 per step — flushes on this
# stack span ~5 ms warm CPU dispatches to multi-second cold compiles.
LATENCY_BUCKETS_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                     0.5, 1.0, 2.5, 5.0, 10.0, 30.0)
ROWS_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0,
                1024.0)
PAD_FACTOR_BUCKETS = (1.0, 1.1, 1.25, 1.5, 2.0, 3.0, 5.0)


class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus classic semantics:
    bucket ``le=x`` counts observations <= x; ``+Inf`` == ``_count``)."""

    def __init__(self, buckets: Sequence[float]):
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds = bounds
        self._lock = threading.Lock()
        self._counts = [0] * (len(bounds) + 1)   # guarded-by: _lock
        self._sum = 0.0                          # guarded-by: _lock
        self._count = 0                          # guarded-by: _lock

    def observe(self, value: float) -> None:
        value = float(value)
        # linear scan: bucket lists here are ~10 entries and observe runs
        # once per flush/request, not per row
        i = 0
        for i, bound in enumerate(self.bounds):
            if value <= bound:
                break
        else:
            i = len(self.bounds)
        with self._lock:
            self._counts[i] += 1
            self._sum += value
            self._count += 1

    def snapshot(self) -> Tuple[List[Tuple[float, int]], float, int]:
        """(cumulative (le, count) pairs, sum, count) — render-ready."""
        with self._lock:
            counts = list(self._counts)
            total = self._sum
            n = self._count
        cumulative: List[Tuple[float, int]] = []
        running = 0
        for bound, c in zip(self.bounds, counts):
            running += c
            cumulative.append((bound, running))
        return cumulative, total, n


class ServiceHistograms:
    """The serving tier's fixed histogram set, rendered by
    `repro.obs.prometheus.render` under ``repro_<name>``."""

    def __init__(self):
        # observe-site gate (one bool read, checked by the service before
        # recording). Default on; obs_overhead flips it per measurement
        # round to price the histogram feature in isolation.
        self.enabled = True
        self.flush_latency_seconds = Histogram(LATENCY_BUCKETS_S)
        self.request_latency_seconds = Histogram(LATENCY_BUCKETS_S)
        self.rows_per_flush = Histogram(ROWS_BUCKETS)
        self.pad_factor = Histogram(PAD_FACTOR_BUCKETS)

    def as_dict(self) -> Dict[str, Histogram]:
        return {
            "flush_latency_seconds": self.flush_latency_seconds,
            "request_latency_seconds": self.request_latency_seconds,
            "rows_per_flush": self.rows_per_flush,
            "pad_factor": self.pad_factor,
        }
