"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, pattern 2 rec : 1 attn.
[arXiv:2402.19427; hf]

26L (8 x (rec,rec,attn) + 2 rec), d_model=2560, 10 MQA heads (kv=1),
head_dim=256, d_ff=7680 (GeGLU), vocab=256000, lru_width=2560,
local window 2048. Runs the long_500k cell: constant-memory ring-buffer
attention cache + O(1) recurrent state.
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,            # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    lru_width=2560,
    attn_pattern="local",
    local_window=2048,
    tie_embeddings=True,
    norm="rmsnorm",
    activation="gelu",
    glu=True,
))
