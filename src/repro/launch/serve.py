"""Serving CLI: prefill a batch of synthetic prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_configs, reduced_config
from repro.models.factory import build_model
from repro.serve.loop import generate
from repro.sharding.rules import init_from_defs
from repro.utils.misc import log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    bundle = build_model(cfg)
    if bundle.prefill_fn is None:
        raise SystemExit(f"{cfg.name} has no serve path")
    key = jax.random.PRNGKey(args.seed)
    params = init_from_defs(key, bundle.param_defs)

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_feats"] = np.ones(
            (args.batch, cfg.encoder_seq, cfg.encoder_feature_dim), np.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = np.ones(
            (args.batch, cfg.num_image_tokens, cfg.image_embed_dim), np.float32)

    cache_len = args.prompt_len + args.new_tokens
    t0 = time.perf_counter()
    out = generate(bundle, params, batch, args.new_tokens, cache_len,
                   temperature=args.temperature, seed=args.seed)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    log(f"generated {out.shape} tokens in {dt:.2f}s ({tps:.1f} tok/s)")
    print(np.asarray(out)[:, :12])


if __name__ == "__main__":
    main()
