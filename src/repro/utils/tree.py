"""Pytree arithmetic helpers used throughout the optimizer stack.

All helpers are jit-safe (pure jnp) and operate leaf-wise on arbitrary
pytrees of arrays — the SVRG/AsySVRG core treats parameters, gradients and
control variates uniformly as trees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leaf-wise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a, b):
    """Global inner product <a, b> across all leaves.

    Uses sum(a*b) rather than vdot: vdot RESHAPES to 1-D, and flattening a
    2D-sharded tensor forces XLA to all-gather it (observed +24 GiB/device
    in the grad-clip of the 104B configs — EXPERIMENTS.md §Perf)."""
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.zeros((), jnp.float32))


def tree_l2norm(a):
    return jnp.sqrt(tree_dot(a, a))


def global_norm(tree):
    return tree_l2norm(tree)


def tree_size(tree) -> int:
    """Total number of elements (python int; works on ShapeDtypeStructs)."""
    return sum(int(jnp.prod(jnp.array(x.shape))) if x.shape else 1
               for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    total = 0
    for x in jax.tree.leaves(tree):
        n = 1
        for d in x.shape:
            n *= int(d)
        total += n * jnp.dtype(x.dtype).itemsize
    return total


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


# ---------------------------------------------------------------------------
# Bit-exact flatten / unflatten (the sweep engine's pytree<->flat bridge)
#
# The AsySVRG/Hogwild! epoch cores do their delay-buffer and update math on
# ONE flat vector per config row (that is what keeps the ring-buffer reads,
# the unlock coordinate masks and the fused `kernels/svrg_update` routing
# objective-agnostic). Pytree objectives cross that boundary through the
# helpers below, which are pure data movement — concatenate of raveled
# leaves one way, split+reshape the other — so the round-trip is BIT-EXACT
# by construction (tests/test_properties.py pins it for arbitrary nested
# trees). Leaves must share one dtype: a mixed-dtype tree would force a cast
# (jnp.concatenate promotes), which silently breaks bit-exactness, so we
# raise instead.
# ---------------------------------------------------------------------------

def _leaf_meta(tree):
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot ravel an empty pytree")
    dtypes = {jnp.dtype(x.dtype) for x in leaves}
    if len(dtypes) > 1:
        raise ValueError(
            f"tree_ravel requires one leaf dtype, got {sorted(map(str, dtypes))}"
            " — cast the tree first (mixed dtypes would not round-trip "
            "bit-exactly through concatenate)")
    shapes = [tuple(x.shape) for x in leaves]
    return leaves, treedef, shapes


def tree_ravel(tree):
    """Flatten a pytree of same-dtype arrays to one 1-D vector.

    A single 1-D leaf passes through UNTOUCHED (no reshape/concat node in
    the graph) — the flat-vector objectives (logistic regression and
    friends) therefore compile to exactly the graphs they had before the
    pytree generalization.
    """
    leaves, _, _ = _leaf_meta(tree)
    if len(leaves) == 1 and getattr(leaves[0], "ndim", None) == 1:
        return leaves[0]
    return jnp.concatenate([jnp.ravel(x) for x in leaves])


def tree_unravel_fn(template):
    """``unravel(flat) -> tree`` for trees shaped like ``template``.

    Built once per objective from its param template (shapes/treedef are
    static), so the returned closure is jit-stable. Inverse of `tree_ravel`
    bit-exactly."""
    leaves, treedef, shapes = _leaf_meta(template)
    if len(leaves) == 1 and len(shapes[0]) == 1:
        return lambda flat: jax.tree.unflatten(treedef, [flat])
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    bounds = list(np.cumsum(sizes)[:-1])

    def unravel(flat):
        parts = jnp.split(flat, bounds)
        return jax.tree.unflatten(
            treedef, [p.reshape(s) for p, s in zip(parts, shapes)])

    return unravel
