# Pallas TPU kernels for the compute hot spots. Each subpackage:
#   kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
#   ops.py    — jit'd public wrapper (pytree handling, padding, dispatch)
#   ref.py    — pure-jnp oracle used by the allclose test sweeps
#
# Kernels are validated in interpret=True mode on CPU (this container);
# compiled mode targets TPU v5e.
