"""Fault-tolerant checkpointing.

Design (scaled-down but structurally faithful to a multi-host deployment):

  * step-atomic: arrays are written to ``step_<N>.tmp/`` then the directory
    is os.rename()d — a crash mid-write never corrupts the latest checkpoint.
  * manifest.json records step, flattened key paths, dtypes/shapes and the
    mesh shape used — restore works onto a DIFFERENT mesh (elastic restart:
    arrays are saved unsharded and re-placed under the new sharding).
  * async: `save(..., blocking=False)` hands the host copy to a writer
    thread so the train loop overlaps checkpoint IO with compute.
  * retention: keep_last_k with atomic cleanup.
  * restore picks the newest VALID manifest (partial/corrupt dirs skipped).

At real pod scale the np.savez writer would be swapped for a per-host
sharded writer (each host dumps its addressable shards); the manifest/atomic
rename/retention logic is the part that carries over unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep_last_k: int = 3):
        self.dir = directory
        self.keep = keep_last_k
        self._thread: Optional[threading.Thread] = None
        if directory:
            os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, state, step: int, blocking: bool = True,
             extra: Optional[Dict[str, Any]] = None) -> None:
        if not self.dir:
            return
        flat = _flatten(state)           # host copy happens on the main thread
        manifest = {
            "step": int(step),
            "keys": sorted(flat),
            "extra": extra or {},
            "format": 1,
        }
        if blocking:
            self._write(flat, manifest, step)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(flat, manifest, step), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def delete(self) -> None:
        """Remove the whole checkpoint directory (after any in-flight async
        save). For spooled jobs — e.g. the serving tier time-slicing a
        giant sweep through per-job scratch checkpoints — whose state is
        worthless once the final result has been delivered."""
        self.wait()
        if self.dir and os.path.isdir(self.dir):
            shutil.rmtree(self.dir, ignore_errors=True)

    def _write(self, flat, manifest, step: int) -> None:
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomicity boundary
        self._cleanup()

    def _cleanup(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self):
        if not self.dir or not os.path.isdir(self.dir):
            return []
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                man = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(man):
                    try:
                        with open(man) as f:
                            steps.append(int(json.load(f)["step"]))
                    except (ValueError, KeyError, json.JSONDecodeError):
                        continue          # corrupt manifest -> skip
        return sorted(steps)

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into the structure of `template`. Returns (state, step).
        With `shardings` (a matching pytree of NamedSharding), arrays are
        device_put under the new mesh — the elastic-restart path."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step = step if step is not None else steps[-1]
        path = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            flat = {k: npz[k] for k in npz.files}

        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        keys = ["/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                         for q in p) for p, _ in paths]
        missing = [k for k in keys if k not in flat]
        if missing:
            raise KeyError(
                f"checkpoint step {step} in {self.dir} does not match the "
                f"restore template: missing keys {missing} "
                f"(checkpoint holds {sorted(flat)})")
        def leaf_spec(leaf):
            # shape/dtype without materializing device arrays on the host
            return (tuple(np.shape(leaf)),
                    np.dtype(getattr(leaf, "dtype", None)
                             or np.result_type(leaf)))

        mismatched = [
            f"{k}: checkpoint {flat[k].shape}/{flat[k].dtype} != template "
            f"{leaf_spec(leaf)[0]}/{leaf_spec(leaf)[1]}"
            for k, (_, leaf) in zip(keys, paths)
            if (flat[k].shape, flat[k].dtype) != leaf_spec(leaf)]
        if mismatched:
            raise ValueError(
                f"checkpoint step {step} in {self.dir} does not match the "
                f"restore template: {'; '.join(mismatched)}")
        leaves = [flat[key] for key in keys]
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, step
