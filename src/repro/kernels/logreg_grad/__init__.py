from repro.kernels.logreg_grad import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
