"""Validate every BENCH_*.json artifact before CI uploads it.

The bench jobs are self-gating two ways: benchmarks with a correctness
component (kernel_sweep parity, service_throughput warm-compile count)
raise inside ``main()``, and THIS checker catches the quieter failure mode
— a benchmark that "succeeded" but wrote an artifact downstream tooling
cannot consume. Every ``BENCH_*.json`` in the scanned directory must

  * parse as strict JSON (the writer turns inf/nan into strings; a raw
    ``Infinity`` literal here means someone bypassed
    `benchmarks.artifacts.write_bench_json`),
  * be a non-empty JSON object, and
  * carry the required keys registered below for its benchmark name —
    the stable schema downstream perf-trajectory tooling keys on.

Exit status is the gate: 0 all valid, 1 any violation (listed on stderr),
2 when no artifacts were found but some were expected (``--expect``).

Usage:  python -m benchmarks.check_artifacts [DIR] [--expect name ...]
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple

# BENCH name -> top-level keys every artifact of that name must carry.
# Names absent here get only the parse/object checks (new benchmarks work
# out of the box; add their schema once a consumer depends on it).
REQUIRED_KEYS: Dict[str, Tuple[str, ...]] = {
    "kernel_sweep": ("backend", "fused_mode", "shapes"),
    "service_throughput": ("cold_s", "warm_s", "warm_cold_ratio",
                           "coalesced_speedup"),
    "server_latency": (),
    "table2_schemes": (),
    "table3_vs_hogwild": (),
    "frontier_stability": (),
    "nonconvex_frontier": (),
    "fig1_convergence": (),
    # obs-smoke lane: warm tracer-on vs tracer-off serving rounds plus the
    # traced HTTP smoke (span chain + Prometheus scrape) and per-feature
    # warm deltas (tracer/histograms/progress/telemetry)
    "obs_overhead": ("tracer_off_s", "tracer_on_s", "overhead_frac",
                     "http_smoke", "features"),
    # obs-smoke lane: live-progress stream + per-group performance ledger
    # over one multi-group run_job (see _check_progress_ledger)
    "progress_ledger": ("groups", "progress", "watchdog"),
    # written by `python -m repro.analysis --json-out` in the repro-lint
    # CI lane; diagnostics must be [] for the lane to pass, but the
    # artifact records suppression counts for trend tooling either way
    "repro_lint": ("files", "diagnostics", "suppressions", "rules"),
}

# kernel_sweep is additionally checked per shape: these are the keys the
# roofline-vs-measured comparison needs (acceptance criterion: timings AND
# predicted intensity for >= 2 group shapes).
_KERNEL_SHAPE_KEYS = ("label", "rows", "inner_steps", "epochs", "vmap_s",
                      "fused_s", "measured_speedup", "parity", "roofline")


def _check_kernel_sweep(payload: dict) -> List[str]:
    errs = []
    shapes = payload.get("shapes")
    if not isinstance(shapes, list) or len(shapes) < 2:
        return [f"shapes: expected a list of >= 2 group shapes, "
                f"got {shapes!r:.80}"]
    for i, s in enumerate(shapes):
        missing = [k for k in _KERNEL_SHAPE_KEYS
                   if not isinstance(s, dict) or k not in s]
        if missing:
            errs.append(f"shapes[{i}]: missing keys {missing}")
        elif "intensity_headroom" not in s["roofline"]:
            errs.append(f"shapes[{i}].roofline: missing intensity_headroom")
    return errs


# every ledger group entry the perf-trajectory tooling reads: compile
# attribution, FLOPs (cost_analysis or analytic) and the attained-vs-
# roofline fraction (acceptance criterion: >= 2 compiled groups).
_LEDGER_GROUP_KEYS = ("compile_s", "flops", "attained_frac",
                      "warm_wall_min_s", "dispatches", "compiles")


def _check_progress_ledger(payload: dict) -> List[str]:
    errs = []
    groups = payload.get("groups")
    if not isinstance(groups, dict) or len(groups) < 2:
        return [f"groups: expected a dict of >= 2 ledger entries, "
                f"got {groups!r:.80}"]
    for label, entry in groups.items():
        missing = [k for k in _LEDGER_GROUP_KEYS
                   if not isinstance(entry, dict) or k not in entry]
        if missing:
            errs.append(f"groups[{label!r}]: missing keys {missing}")
    return errs


def check_file(path: str) -> List[str]:
    """All schema violations for one artifact (empty list = valid)."""
    name = os.path.basename(path)[len("BENCH_"):-len(".json")]
    try:
        with open(path) as fh:
            payload = json.load(fh, parse_constant=lambda c: (_ for _ in ())
                                .throw(ValueError(f"non-strict JSON: {c}")))
    except (ValueError, OSError) as e:
        return [f"unparseable: {e}"]
    if not isinstance(payload, dict) or not payload:
        return ["top level must be a non-empty JSON object"]
    errs = [f"missing required key {k!r}"
            for k in REQUIRED_KEYS.get(name, ()) if k not in payload]
    if name == "kernel_sweep" and not errs:
        errs += _check_kernel_sweep(payload)
    if name == "progress_ledger" and not errs:
        errs += _check_progress_ledger(payload)
    return errs


def main(argv: List[str]) -> int:
    # everything after --expect is a benchmark NAME, not the scan dir
    # (the old `not a.startswith("--")` filter misread the first expected
    # name as the positional directory)
    args = list(argv)
    expected: List[str] = []
    if "--expect" in args:
        i = args.index("--expect")
        expected = args[i + 1:]
        args = args[:i]
    directory = args[0] if args else os.environ.get("BENCH_DIR", ".")
    try:
        entries = os.listdir(directory)
    except OSError as e:
        print(f"FAIL cannot scan {directory}: {e}", file=sys.stderr)
        entries = []
    paths = sorted(p for p in entries
                   if p.startswith("BENCH_") and p.endswith(".json"))
    failures = 0
    for p in paths:
        errs = check_file(os.path.join(directory, p))
        if errs:
            failures += 1
            for e in errs:
                print(f"FAIL {p}: {e}", file=sys.stderr)
        else:
            print(f"ok   {p}")
    missing = [n for n in expected if f"BENCH_{n}.json" not in paths]
    for n in missing:
        print(f"FAIL expected artifact BENCH_{n}.json not found in "
              f"{directory}", file=sys.stderr)
    if not paths and expected:
        return 2
    return 1 if failures or missing else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
