"""Async serving tier over the sweep service — the ROADMAP "RPC/HTTP
wrapper + background flush policy + per-tenant fairness" follow-up.

Four layers, all on the existing scheduler/cache stack (`repro.service`):

  * `repro.server.daemon` — `ServeDaemon` + `FlushPolicy`: a background
    thread triggers the coalesced flush on size/deadline policy (clients
    never block on a barrier) and keeps dispatched batch widths at
    previously-compiled values (`WidthRegistry`) so the warm path stays at
    0 compiles; giant sweeps time-slice through the checkpointed
    ``run_job(max_groups=…)`` between flushes.
  * `repro.server.fairness` — `FairShare` + `TenantPolicy`: deficit-round-
    robin admission with weighted quotas and priority classes; one
    tenant's huge grid cannot starve the queue.
  * `repro.server.http` / `repro.server.client` — stdlib-only HTTP
    front-end (`SweepServer`) and client (`SweepClient`): submit / result
    (long-poll) / flush / stats / healthz (503 once the daemon heartbeat
    stalls) / metrics (Prometheus 0.0.4) / trace (the `repro.obs` flight
    recorder's span trees, ids echoed in ``X-Trace-Id``), results
    bit-identical to in-process ``run_sweep``.
  * `repro.server.metrics` — one JSON snapshot: ServiceStats, queue depth,
    per-tenant rows, p50/p95 flush + request latency, daemon counters +
    heartbeat liveness.
"""
from repro.server.client import ServerError, SweepClient
from repro.server.daemon import (
    DaemonStats,
    FlushPolicy,
    JobHandle,
    ServeDaemon,
    WidthRegistry,
)
from repro.server.fairness import FairShare, TenantPolicy
from repro.server.http import SweepServer
from repro.server.metrics import snapshot

__all__ = [
    "FlushPolicy",
    "ServeDaemon",
    "WidthRegistry",
    "JobHandle",
    "DaemonStats",
    "FairShare",
    "TenantPolicy",
    "SweepServer",
    "SweepClient",
    "ServerError",
    "snapshot",
]
