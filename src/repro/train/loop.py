"""Host-side training loop: SVRG snapshot scheduling, checkpoint/restart,
metrics. Works identically on 1 CPU device (examples/tests) and on a pod
mesh (shardings come from the ParamDef rules; the loop never branches on
device count).

Fault tolerance:
  * auto-resume: if checkpoint_dir holds a valid step, training continues
    from it (the data pipeline is counter-based, so the step number IS the
    cursor).
  * step-atomic async checkpoints every checkpoint_every steps.
  * SVRG epoch barrier: snapshot passes are separate jit fns; a failure
    between them re-runs the snapshot from the restored step (idempotent).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.config import TrainConfig
from repro.models.factory import ModelBundle
from repro.train.state import (
    TrainState, init_train_state, make_snapshot_fns, make_train_step)
from repro.utils.misc import log


def train(bundle: ModelBundle, tcfg: TrainConfig,
          batch_at: Callable[[int], Any],
          snapshot_batch_at: Optional[Callable[[int], Any]] = None,
          hooks: Optional[Callable[[int, Dict], None]] = None) -> TrainState:
    """Run tcfg.steps training steps. `batch_at(step)` supplies data
    (counter-based — restart-safe)."""
    is_svrg = tcfg.optimizer == "svrg"
    snapshot_batch_at = snapshot_batch_at or batch_at

    step_fn = jax.jit(make_train_step(bundle, tcfg), donate_argnums=(0,))
    if is_svrg:
        begin_fn, accum_fn, finalize_fn = make_snapshot_fns(bundle, tcfg)
        begin_fn = jax.jit(begin_fn, donate_argnums=(0,))
        accum_fn = jax.jit(accum_fn, donate_argnums=(0,))
        finalize_fn = jax.jit(finalize_fn, donate_argnums=(0,))

    ckpt = Checkpointer(tcfg.checkpoint_dir, tcfg.keep_checkpoints)
    state = init_train_state(jax.random.PRNGKey(tcfg.seed), bundle, tcfg)
    start_step = 0
    if tcfg.checkpoint_dir and ckpt.list_steps():
        state, start_step = ckpt.restore(state)
        log(f"resumed from checkpoint step {start_step}")

    def refresh_snapshot(state: TrainState, step: int) -> TrainState:
        state = begin_fn(state)
        for j in range(tcfg.svrg.snapshot_batches):
            state = accum_fn(state, snapshot_batch_at(step * 131 + j))
        state = finalize_fn(state)
        # finalize sets w_snap = params: force a REAL copy, or the next
        # donating step_fn sees the same buffer twice ("donate(a), donate(a)")
        w_snap = jax.tree.map(lambda x: jnp.array(x), state.svrg.w_snap)
        return state._replace(svrg=state.svrg._replace(w_snap=w_snap))

    t0 = time.perf_counter()
    for step in range(start_step, tcfg.steps):
        if is_svrg and step % tcfg.svrg.snapshot_every == 0:
            state = refresh_snapshot(state, step)
        state, metrics = step_fn(state, batch_at(step))
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            log(f"step {step}: loss={m['loss']:.4f} |v|={m['v_norm']:.3f} "
                f"lr={m['lr']:.2e} ({dt:.1f}s)")
            if hooks:
                hooks(step, m)
        if tcfg.checkpoint_dir and (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save(state, step + 1, blocking=False)
    ckpt.wait()
    if tcfg.checkpoint_dir:
        ckpt.save(state, tcfg.steps, blocking=True)
    return state
