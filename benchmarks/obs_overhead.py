"""Observability tax: the flight recorder must be free when off and
near-free when on.

Two phases, both on a warm runner cache (the regime servers live in):

  * HTTP SMOKE — tracing enabled, a real `SweepServer` with the flush
    daemon, two tenants submit over the wire. Asserts the full span chain
    (submit → plan → coalesce → pad → dispatch → execute → demux) is
    retrievable from ``/trace`` by the ``trace_id`` the submit response
    echoes, and that ``/metrics`` scrapes as Prometheus 0.0.4 text with
    the four service histograms populated.
  * OVERHEAD — alternating tracer-off / tracer-on rounds through the
    in-process `SweepService` (same specs, same widths, zero compiles),
    min-of-rounds wall time per mode. Acceptance: warm tracer-on overhead
    ``(on - off) / off <= 5%``. The disabled path is a single bool check,
    and the enabled path only brackets host-side stages — neither may show
    up against the compiled program's runtime.
  * FEATURES — per-feature attribution on one all-off baseline service:
    each round flips exactly one of tracer / histograms / progress /
    telemetry on and prices its warm delta against the all-off round.
    Acceptance: the live-progress bus (the PR-10 feature that recomputes
    per-row losses and publishes slice events) stays ``<= 5%`` over the
    all-off baseline with zero recompiles — enabling it must never reach
    a group key.

Writes ``BENCH_obs_overhead.json`` (keys: ``tracer_off_s``,
``tracer_on_s``, ``overhead_frac``, ``http_smoke``, ``features``);
``--quick`` is the CI `obs-smoke` configuration.
"""
from __future__ import annotations

import json
import re
import sys
import time
import urllib.request

from benchmarks.artifacts import write_bench_json
from repro.core import LogisticRegression, SweepSpec
from repro.data.libsvm import make_synthetic_libsvm
from repro.obs.progress import disable_progress, enable_progress
from repro.obs.trace import disable_tracing, enable_tracing
from repro.server import FlushPolicy, SweepClient, SweepServer
from repro.service import SweepService, cache_stats

ACCEPT_OVERHEAD_FRAC = 0.05
ROWS_PER_REQUEST = 4
# the switchable obs features, each priced in isolation against all-off
# ("telemetry" rides the SweepSpec flag, the others are process/service
# toggles — see _set_features)
FEATURES = ("tracer", "histograms", "progress", "telemetry")

# every line of a 0.0.4 text exposition: comment, blank, or sample
_PROM_LINE = re.compile(
    r"^(#.*|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})?\s[^\s]+)$")

# span names every traced HTTP request must produce (pad appears because
# the daemon installs a WidthRegistry; execute carries the engine tags)
_EXPECTED_SPANS = {"submit", "plan", "coalesce", "pad", "dispatch",
                   "execute", "demux"}


def _specs(base_seed: int, rows: int = ROWS_PER_REQUEST,
           telemetry: bool = False):
    return [SweepSpec(scheme="inconsistent", step_size=0.5, tau=3,
                      num_threads=4, inner_steps=25, seed=base_seed + c,
                      telemetry=telemetry)
            for c in range(rows)]


def _submit_raw(url: str, specs, tenant: str) -> dict:
    """POST /submit and keep the whole response body — the stock client
    returns only request_id, but the smoke needs the echoed trace_id."""
    from repro.server.http import spec_to_dict
    body = {"specs": [spec_to_dict(s) for s in specs], "tenant": tenant}
    req = urllib.request.Request(
        url + "/submit", data=json.dumps(body).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=30) as resp:
        payload = json.loads(resp.read().decode())
        payload["x_trace_id"] = resp.headers.get("X-Trace-Id", "")
    return payload


def http_smoke(obj, epochs: int) -> dict:
    """Traced end-to-end pass over the wire; returns what it verified."""
    enable_tracing()
    try:
        svc = SweepService(obj, epochs=epochs)
        policy = FlushPolicy(max_rows=2 * ROWS_PER_REQUEST, max_delay_ms=20)
        with SweepServer(svc, policy=policy) as server:
            client = SweepClient(server.url, poll_s=5.0)
            subs = [_submit_raw(server.url, _specs(100 * (t + 1)), f"t{t}")
                    for t in range(2)]
            for sub in subs:
                client.result(sub["request_id"], timeout=600)

            span_names = set()
            for sub in subs:
                tid = sub["trace_id"]
                if sub["x_trace_id"] != tid:
                    raise AssertionError(
                        f"X-Trace-Id header {sub['x_trace_id']!r} != body "
                        f"trace_id {tid!r}")
                tree = client.trace(tid)
                names = {s["name"] for s in tree["spans"]}
                missing = _EXPECTED_SPANS - names
                if missing:
                    raise AssertionError(
                        f"trace {tid} missing spans {sorted(missing)} "
                        f"(got {sorted(names)})")
                span_names |= names

            text = client.metrics()
            bad = [ln for ln in text.splitlines()
                   if ln and not _PROM_LINE.match(ln)]
            if bad:
                raise AssertionError(f"non-Prometheus lines: {bad[:3]}")
            for hist in ("repro_flush_latency_seconds",
                         "repro_request_latency_seconds",
                         "repro_rows_per_flush", "repro_pad_factor"):
                if f"{hist}_count" not in text:
                    raise AssertionError(f"histogram {hist} not exposed")
        return {"requests": len(subs), "spans": sorted(span_names),
                "metrics_lines": len(text.splitlines()), "ok": True}
    finally:
        disable_tracing(clear=True)


def _round(svc, base_seed: int, submits: int,
           telemetry: bool = False) -> float:
    """One warm closed-loop round: N submits, one flush, all results."""
    t0 = time.perf_counter()
    rids = [svc.submit(_specs(base_seed + 1000 * i, telemetry=telemetry))
            for i in range(submits)]
    svc.flush()
    for rid in rids:
        svc.result(rid)
    return time.perf_counter() - t0


def measure_overhead(obj, epochs: int, rounds: int, submits: int) -> dict:
    """Alternate tracer-off / tracer-on rounds on one warm service; the
    interleave cancels drift (thermal, GC) that back-to-back blocks bake
    into whichever mode runs second."""
    svc = SweepService(obj, epochs=epochs, max_results=4 * submits)
    _round(svc, base_seed=1, submits=submits)            # compile + warm
    base = cache_stats()

    off, on = [], []
    for r in range(rounds):
        disable_tracing(clear=True)
        off.append(_round(svc, 10_000 + 97 * r, submits))
        enable_tracing()
        try:
            on.append(_round(svc, 20_000 + 97 * r, submits))
        finally:
            disable_tracing(clear=True)

    compiles = cache_stats().since(base).compiles
    if compiles:
        raise AssertionError(
            f"measured rounds recompiled ({compiles} traces) — the "
            "telemetry/tracing flags must never reach the group key")
    tracer_off_s, tracer_on_s = min(off), min(on)
    return {
        "rounds": rounds, "submits_per_round": submits,
        "rows_per_round": submits * ROWS_PER_REQUEST,
        "tracer_off_s": tracer_off_s,
        "tracer_on_s": tracer_on_s,
        "off_rounds_s": off, "on_rounds_s": on,
        "overhead_frac": (tracer_on_s - tracer_off_s) / tracer_off_s,
        "compiles_measured": compiles,
    }


def _set_features(svc, enabled: frozenset) -> None:
    """Flip the process/service obs toggles to exactly ``enabled``
    ("telemetry" is per-spec, handled by the round itself)."""
    if "tracer" in enabled:
        enable_tracing()
    else:
        disable_tracing(clear=True)
    if "progress" in enabled:
        enable_progress()
    else:
        disable_progress(clear=True)
    svc.histograms.enabled = "histograms" in enabled


def measure_features(obj, epochs: int, rounds: int, submits: int) -> dict:
    """Per-feature warm deltas: one all-off baseline round per iteration,
    then one round per feature with exactly that feature on, interleaved
    so drift hits every mode equally. Min-of-rounds throughout."""
    svc = SweepService(obj, epochs=epochs, max_results=4 * submits)
    _set_features(svc, frozenset())
    _round(svc, base_seed=1, submits=submits)            # compile + warm
    _round(svc, base_seed=1, submits=submits, telemetry=True)  # warm too
    base = cache_stats()

    baseline = []
    rounds_by_feature = {f: [] for f in FEATURES}
    try:
        for r in range(rounds):
            _set_features(svc, frozenset())
            baseline.append(_round(svc, 30_000 + 971 * r, submits))
            for i, feat in enumerate(FEATURES):
                _set_features(svc, frozenset((feat,)))
                rounds_by_feature[feat].append(_round(
                    svc, 40_000 + 971 * r + 7 * i, submits,
                    telemetry=(feat == "telemetry")))
    finally:
        _set_features(svc, frozenset())
        svc.histograms.enabled = True        # restore the service default

    compiles = cache_stats().since(base).compiles
    if compiles:
        raise AssertionError(
            f"feature rounds recompiled ({compiles} traces) — obs toggles "
            "must never reach a group key")
    base_s = min(baseline)
    features = {
        feat: {
            "round_s": min(series),
            "delta_frac": (min(series) - base_s) / base_s,
        }
        for feat, series in rounds_by_feature.items()
    }
    progress_frac = features["progress"]["delta_frac"]
    if progress_frac > ACCEPT_OVERHEAD_FRAC:
        raise AssertionError(
            f"progress-bus warm rounds {progress_frac * 100:.1f}% slower "
            f"than all-off (acceptance: <= "
            f"{ACCEPT_OVERHEAD_FRAC * 100:.0f}%)")
    return {"baseline_s": base_s, "baseline_rounds_s": baseline,
            "compiles_measured": compiles, **features}


def run(quick: bool = False):
    ds = make_synthetic_libsvm("real-sim", seed=11,
                               scale=0.002 if quick else 0.01)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    epochs = 1 if quick else 2
    rounds = 3 if quick else 6
    submits = 2 if quick else 4

    smoke = http_smoke(obj, epochs)
    bench = measure_overhead(obj, epochs, rounds, submits)
    features = measure_features(obj, epochs, rounds, submits)

    out = {"dataset": "real-sim", "epochs": epochs, "http_smoke": smoke,
           "features": features}
    out.update(bench)
    # acceptance: the flight recorder may not tax the warm serving path
    # by more than 5% — its spans bracket host-side stages only
    if out["overhead_frac"] > ACCEPT_OVERHEAD_FRAC:
        raise AssertionError(
            f"tracer-on warm rounds {out['overhead_frac'] * 100:.1f}% "
            f"slower than tracer-off (acceptance: <= "
            f"{ACCEPT_OVERHEAD_FRAC * 100:.0f}%)")
    return out


def main(quick: bool = True):
    out = run(quick=quick)
    write_bench_json("obs_overhead", out)
    print("name,us_per_call,derived")
    print(f"obs_tracer_off,{out['tracer_off_s'] * 1e6:.0f},"
          f"min_of_{out['rounds']}_rounds")
    print(f"obs_tracer_on,{out['tracer_on_s'] * 1e6:.0f},"
          f"overhead_frac={out['overhead_frac']:.4f};"
          f"compiles={out['compiles_measured']}")
    print(f"obs_http_smoke,0,spans={'+'.join(out['http_smoke']['spans'])};"
          f"metrics_lines={out['http_smoke']['metrics_lines']}")
    for feat in FEATURES:
        entry = out["features"][feat]
        print(f"obs_feature_{feat},{entry['round_s'] * 1e6:.0f},"
              f"delta_frac={entry['delta_frac']:.4f}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
