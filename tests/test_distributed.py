"""SPMD AsySVRG pieces: bounded-staleness local updates, compression with
error feedback, wire-size accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SVRGConfig
from repro.core.compression import (
    compressed_bytes,
    compressed_update,
    init_error_feedback,
    int8_compress,
    randk_compress,
    topk_compress,
)
from repro.core.distributed import (
    bounded_staleness_epoch,
    init_svrg_state,
    reshape_for_workers,
    snapshot_accumulate,
    snapshot_begin,
    snapshot_finalize,
    svrg_direction,
)
from repro.launch.mesh import make_host_mesh


def _quad_loss(params, batch):
    # strongly convex quadratic: 0.5||w - target||^2 over batch rows
    diff = params["w"][None, :] - batch
    return 0.5 * jnp.mean(jnp.sum(diff * diff, axis=-1))


def test_bounded_staleness_epoch_single_worker_equals_local_steps():
    """On a 1-device mesh, the shard_map path must equal plain sequential
    local SVRG steps (the degenerate W=1 case)."""
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    dim, H = 8, 4
    params = {"w": jnp.zeros(dim)}
    target = jax.random.normal(key, (H, 2, dim))      # H batches of 2 rows
    svrg = init_svrg_state(params)
    svrg = snapshot_begin(svrg)
    svrg = snapshot_accumulate(_quad_loss, params, svrg,
                               target.reshape(-1, dim))
    svrg = snapshot_finalize(params, svrg, 0)

    cfg = SVRGConfig(local_steps=H)
    batches = reshape_for_workers(target, 1, H)       # [1, H, 2, dim]
    out = bounded_staleness_epoch(mesh, _quad_loss, params, svrg, batches,
                                  step_size=0.1, cfg=cfg)

    # sequential reference
    w = params
    for hstep in range(H):
        b = target[hstep]
        g = jax.grad(_quad_loss)(w, b)
        g0 = jax.grad(_quad_loss)(svrg.w_snap, b)
        v = svrg_direction(g, g0, svrg.g_snap)
        w = jax.tree.map(lambda wi, vi: wi - 0.1 * vi, w, v)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(w["w"]),
                               atol=1e-6)


def test_bounded_staleness_converges_on_quadratic():
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(1)
    dim, H, epochs = 16, 8, 10
    target = jax.random.normal(key, (64, dim)) + 3.0
    params = {"w": jnp.zeros(dim)}
    cfg = SVRGConfig(local_steps=H)
    for e in range(epochs):
        svrg = snapshot_finalize(
            params,
            snapshot_accumulate(_quad_loss, params,
                                snapshot_begin(init_svrg_state(params)),
                                target),
            e)
        batches = reshape_for_workers(
            target.reshape(H, 8, dim), 1, H)
        params = bounded_staleness_epoch(mesh, _quad_loss, params, svrg,
                                         batches, step_size=0.3, cfg=cfg)
    w_star = target.mean(0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(w_star),
                               atol=1e-2)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_topk_keeps_largest_and_residual_exact():
    x = {"a": jnp.asarray([1.0, -5.0, 0.1, 3.0])}
    comp, res = topk_compress(x, frac=0.5)
    np.testing.assert_allclose(np.asarray(comp["a"]), [0.0, -5.0, 0.0, 3.0])
    # compressed + residual == original exactly (lossless decomposition)
    np.testing.assert_allclose(np.asarray(comp["a"] + res["a"]),
                               np.asarray(x["a"]))


def test_randk_unbiased():
    # 800 trials: per-coord std = 4*sqrt(.25*.75/800) ~= 0.061, so the max
    # deviation over 64 coords (~2.9 sigma ~= 0.18) sits well inside atol.
    key = jax.random.PRNGKey(2)
    x = {"a": jnp.ones(64)}
    outs = []
    for i in range(800):
        comp, _ = randk_compress(x, 0.25, jax.random.fold_in(key, i))
        outs.append(np.asarray(comp["a"]))
    mean = np.stack(outs).mean(0)
    np.testing.assert_allclose(mean, np.ones(64), atol=0.25)


def test_int8_bounded_error():
    key = jax.random.PRNGKey(3)
    x = {"a": jax.random.normal(key, (256,))}
    comp, res = int8_compress(x, key)
    scale = float(jnp.max(jnp.abs(x["a"]))) / 127.0
    assert float(jnp.max(jnp.abs(res["a"]))) <= scale * 1.01


def test_error_feedback_accumulates():
    """EF: what is not transmitted now is carried and re-injected later —
    over many rounds the mean transmitted equals the mean gradient."""
    key = jax.random.PRNGKey(4)
    g = {"a": jnp.asarray([1.0, 0.01, 0.02, 0.005])}
    ef = init_error_feedback(g)
    sent_total = jnp.zeros(4)
    rounds = 50
    for i in range(rounds):
        sent, ef = compressed_update(g, ef, "topk", 0.25,
                                     jax.random.fold_in(key, i))
        sent_total = sent_total + sent["a"]
    np.testing.assert_allclose(np.asarray(sent_total / rounds),
                               np.asarray(g["a"]), atol=0.05)


def test_compressed_bytes_accounting():
    tree = {"a": jnp.zeros((100,)), "b": jnp.zeros((10, 10))}
    assert compressed_bytes(tree, "none", 0.0) == 4 * 200
    assert compressed_bytes(tree, "topk", 0.01) == 2 * (1 * 8)
    assert compressed_bytes(tree, "int8", 0.0) == 200 + 8


def test_compressed_reconcile_still_converges():
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(5)
    dim, H = 16, 4
    target = jax.random.normal(key, (32, dim)) + 1.0
    params = {"w": jnp.zeros(dim)}
    cfg = SVRGConfig(local_steps=H, compression="topk", compression_k=0.5)
    for e in range(12):
        svrg = snapshot_finalize(
            params, snapshot_accumulate(
                _quad_loss, params,
                snapshot_begin(init_svrg_state(params)), target), e)
        batches = reshape_for_workers(target.reshape(H, 8, dim), 1, H)
        params = bounded_staleness_epoch(mesh, _quad_loss, params, svrg,
                                         batches, step_size=0.3, cfg=cfg,
                                         rng=jax.random.fold_in(key, e))
    err = float(jnp.linalg.norm(params["w"] - target.mean(0)))
    assert err < 0.25, err
