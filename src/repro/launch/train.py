"""Training CLI.

Examples:
  # AsySVRG on a reduced gemma3 (CPU-runnable end-to-end driver):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --reduced \
      --steps 100 --optimizer svrg --lr 0.05 --checkpoint-dir /tmp/ckpt

  # plain-SGD baseline (the Hogwild!-equivalent compute):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --reduced \
      --steps 100 --optimizer sgd
"""
from __future__ import annotations

import argparse

import jax

from repro.config import SVRGConfig, TrainConfig
from repro.configs import get_config, list_configs, reduced_config
from repro.data.synthetic_lm import SyntheticLMDataset
from repro.models.factory import build_model
from repro.train.loop import train
from repro.utils.misc import log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--optimizer", default="svrg",
                    choices=["svrg", "sgd", "momentum", "adamw"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--snapshot-every", type=int, default=25)
    ap.add_argument("--snapshot-batches", type=int, default=4)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    bundle = build_model(cfg)
    tcfg = TrainConfig(
        steps=args.steps, optimizer=args.optimizer, learning_rate=args.lr,
        seed=args.seed, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        svrg=SVRGConfig(snapshot_every=args.snapshot_every,
                        snapshot_batches=args.snapshot_batches),
    )
    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch,
                            seed=args.seed)
    extra = {}
    if cfg.family == "encdec":
        import numpy as np
        extra = {"enc_feats": np.ones(
            (args.batch, cfg.encoder_seq, cfg.encoder_feature_dim), np.float32)}
    if cfg.family == "vlm":
        import numpy as np
        extra = {"image_embeds": np.ones(
            (args.batch, cfg.num_image_tokens, cfg.image_embed_dim), np.float32)}

    def batch_at(step: int):
        return {**ds.batch_at(step), **extra}

    log(f"training {cfg.name} ({cfg.family}) with {args.optimizer}, "
        f"{args.steps} steps on {jax.device_count()} device(s)")
    train(bundle, tcfg, batch_at)


if __name__ == "__main__":
    main()
