"""Thread-throughput model for the lock-scheme wall-clock comparison.

The delay-simulation engine (repro.core.asysvrg) reproduces each scheme's
CONVERGENCE behaviour exactly, but wall-clock depends on lock contention,
which a single-device simulation cannot time directly. We therefore measure
the three primitive costs on this machine (per-update gradient compute,
shared-read, shared-write) and compose them per scheme (paper §4.1–4.2):

  consistent   — read AND write inside the lock: the critical section
                 serializes, wall = M̃·(t_read + t_write) + (M̃/p)·t_grad
  inconsistent — only the write locks: wall = M̃·t_write + (M̃/p)·(t_grad+t_read)
  unlock       — nothing locks:        wall = (M̃/p)·(t_grad+t_read+t_write)

This reproduces Table 2's qualitative shape: consistent plateaus (~2.4x),
inconsistent is better, unlock scales best at high p — with the measured
constants reported alongside.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.objective import LogisticRegression


def measure_primitives(obj: LogisticRegression, iters: int = 200) -> Dict[str, float]:
    w = jnp.zeros(obj.p)
    grad1 = jax.jit(lambda w, i: obj.sample_grad(w, i))
    copy = jax.jit(lambda x: x * 1.0)
    add = jax.jit(lambda x, y: x - 0.01 * y)

    grad1(w, 0).block_until_ready()
    copy(w).block_until_ready()
    add(w, w).block_until_ready()

    t0 = time.perf_counter()
    for i in range(iters):
        out = grad1(w, i % obj.n)
    out.block_until_ready()
    t_grad = (time.perf_counter() - t0) / iters

    t0 = time.perf_counter()
    for _ in range(iters):
        out = copy(w)
    out.block_until_ready()
    t_read = (time.perf_counter() - t0) / iters

    t0 = time.perf_counter()
    for _ in range(iters):
        out = add(w, w)
    out.block_until_ready()
    t_write = (time.perf_counter() - t0) / iters
    return {"t_grad": t_grad, "t_read": t_read, "t_write": t_write}


def wall_time(scheme: str, total_updates: int, p: int,
              prim: Dict[str, float]) -> float:
    tg, tr, tw = prim["t_grad"], prim["t_read"], prim["t_write"]
    if scheme == "consistent":
        return total_updates * (tr + tw) + total_updates / p * tg
    if scheme == "inconsistent":
        return total_updates * tw + total_updates / p * (tg + tr)
    if scheme == "unlock":
        return total_updates / p * (tg + tr + tw)
    raise ValueError(scheme)
