"""(step × τ) stability frontier — paper §5 discussion, as ONE sweep call.

Theorem 1 ties the admissible step size to the staleness bound τ: more
staleness shrinks the stable step region. This benchmark maps that frontier
empirically: a grid over step sizes × τ values runs as a single
`run_sweep`, each cell is classified stable / diverged from its loss
history, and the report gives, per τ, the largest step that still
converges.

Three engine features converge here:

  * the τ=0 column is serial SVRG routed through the same engine
    (``SweepSpec(algo="svrg")`` — the zero-delay degenerate case);
  * a pass-matched Hogwild! edge rides in the SAME call: its rows carry a
    3× per-row ``epochs`` budget (1 pass/epoch vs AsySVRG's ~3), which
    before the masked-epoch axis forced a second `run_sweep` call;
  * ``--sharded`` shards the config rows of every group across the host's
    devices (`make_sweep_mesh` / shard_map) — the paper-scale path, bit-
    identical per row to the single-device run on XLA:CPU.

buf_len is pinned per row (τ, thread count), so the whole asysvrg τ axis
at P threads is ONE compiled group; the svrg and hogwild rows get their
own groups.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.artifacts import write_bench_json
from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.launch.mesh import make_sweep_mesh

P = 10
STEPS = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0)
TAUS = (0, 1, 3, 7, 9)


def classify(history, f0: float) -> str:
    """stable = finite history that ends below the starting loss."""
    h = np.asarray(history, np.float64)
    if not np.all(np.isfinite(h)):
        return "diverged"
    return "stable" if h[-1] < f0 else "diverged"


def run(dataset: str = "rcv1", scale: float = 0.03,
        steps=STEPS, taus=TAUS, epochs: int = 6, quick: bool = False,
        sharded: bool = False):
    if quick:
        steps = tuple(steps)[1::2]
        taus = tuple(taus)[::2]
        epochs = 3
    ds = make_synthetic_libsvm(dataset, scale=scale)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    f0 = float(obj.loss(np.zeros(obj.p)))

    specs = []
    for tau in taus:
        for step in steps:
            if tau == 0:
                specs.append(SweepSpec(algo="svrg", step_size=step,
                                       num_threads=1))
            else:
                specs.append(SweepSpec(scheme="inconsistent", step_size=step,
                                       tau=tau, num_threads=P))
    n_async = len(specs)
    # pass-matched Hogwild! edge: same (τ>0 × step) grid, 3× epoch budget
    # (1 pass/epoch), in the SAME call via the per-row epochs axis
    for tau in taus:
        if tau == 0:
            continue
        for step in steps:
            specs.append(SweepSpec(algo="hogwild", scheme="inconsistent",
                                   step_size=step, tau=tau, num_threads=P,
                                   epochs=3 * epochs))

    mesh = make_sweep_mesh() if sharded and jax.device_count() > 1 else None
    t0 = time.perf_counter()
    res = run_sweep(obj, epochs, specs, mesh=mesh)
    sweep_s = time.perf_counter() - t0

    cells = []
    for c, spec in enumerate(res.specs):
        _, h = res.curve(c)
        verdict = classify(h, f0)
        final = float(h[-1])
        cells.append({"tau": spec.tau if spec.algo != "svrg" else 0,
                      "algo": spec.algo, "step": spec.step_size,
                      "epochs": int(res.epochs_per_row[c]),
                      "final_loss": final if np.isfinite(final) else None,
                      "verdict": verdict})

    def _frontier(rows, over):
        out = {}
        for tau in over:
            stable = [c["step"] for c in rows
                      if c["tau"] == tau and c["verdict"] == "stable"]
            out[tau] = max(stable) if stable else 0.0
        return out

    frontier = _frontier(cells[:n_async], taus)
    frontier_hogwild = _frontier(cells[n_async:],
                                 [t for t in taus if t != 0])

    return {"dataset": dataset, "f0": f0, "epochs": epochs,
            "grid_size": len(specs), "sweep_s": sweep_s,
            "devices": jax.device_count() if mesh is not None else 1,
            "cells": cells, "frontier": frontier,
            "frontier_hogwild": frontier_hogwild}


def main(quick: bool = True, sharded: bool = False):
    out = run(quick=quick, sharded=sharded)
    write_bench_json("frontier_stability", out)
    print("name,us_per_call,derived")
    print(f"frontier_sweep_engine,{out['sweep_s'] * 1e6:.1f},"
          f"cells={out['grid_size']};one_call_grid;"
          f"devices={out['devices']}")
    for tau, step in out["frontier"].items():
        print(f"frontier_tau{tau},0,max_stable_step={step}")
    for tau, step in out["frontier_hogwild"].items():
        print(f"frontier_hogwild_tau{tau},0,max_stable_step={step}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv, sharded="--sharded" in sys.argv)
