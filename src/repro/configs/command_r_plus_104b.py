"""command-r-plus-104b [dense] — GQA kv=8, no biases.
[hf:CohereForAI/c4ai-command-r-v01 (family); unverified]

64L, d_model=12288, 96 heads (kv=8), d_ff=33792, vocab=256000.
Cohere family: tied embeddings, layernorm, no biases anywhere.
The largest dense arch in the pool — the FSDP x TP 2D weight sharding
exists to fit this one (plus SVRG snapshot state) in 16 GB/chip.
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75_000_000.0,
    norm="layernorm",
    activation="silu",
    glu=True,
    tie_embeddings=True,
))
