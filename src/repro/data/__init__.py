from repro.data.synthetic_lm import SyntheticLMDataset, lm_batch_specs
from repro.data.libsvm import (
    LogRegDataset,
    make_synthetic_libsvm,
    parse_libsvm_file,
    PAPER_DATASETS,
)

__all__ = [
    "SyntheticLMDataset",
    "lm_batch_specs",
    "LogRegDataset",
    "make_synthetic_libsvm",
    "parse_libsvm_file",
    "PAPER_DATASETS",
]
