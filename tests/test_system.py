"""End-to-end behaviour: the paper's headline claims on its own workload.

  1. AsySVRG converges linearly (geometric objective-gap decay).
  2. AsySVRG beats Hogwild! per effective pass (Fig. 1 right).
  3. All three reading schemes reach the 1e-4 gap (Table 2 rows exist).
"""
import numpy as np
import pytest

from repro.config import SVRGConfig
from repro.core import LogisticRegression, run_asysvrg, run_hogwild
from repro.data.libsvm import make_synthetic_libsvm


@pytest.fixture(scope="module")
def problem():
    ds = make_synthetic_libsvm("rcv1", seed=0, scale=0.03)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    w_star, f_star = obj.optimum(max_iter=4000)
    return obj, f_star


def gaps(history, f_star):
    return np.maximum(np.asarray(history) - f_star, 1e-16)


def test_asysvrg_converges_linearly(problem):
    obj, f_star = problem
    cfg = SVRGConfig(scheme="inconsistent", step_size=2.0, num_threads=8,
                     tau=7)
    res = run_asysvrg(obj, epochs=8, cfg=cfg, seed=1)
    g = gaps(res.history, f_star)
    assert g[-1] < 1e-4, f"gap {g[-1]:.2e} not < 1e-4"
    # geometric decay: every epoch shrinks the gap by a stable factor
    ratios = g[1:] / g[:-1]
    assert np.median(ratios) < 0.75


def test_asysvrg_beats_hogwild_per_pass(problem):
    obj, f_star = problem
    cfg = SVRGConfig(scheme="unlock", step_size=2.0, num_threads=8, tau=7)
    svrg = run_asysvrg(obj, epochs=5, cfg=cfg, seed=2)
    hog = run_hogwild(obj, epochs=15, step_size=2.0, num_threads=8, seed=2)
    # compare at equal effective passes (15 = 5 svrg epochs * ~3 passes;
    # M = floor(2n/p) makes it 14.95 for n=607, p=8)
    assert svrg.effective_passes[-1] == pytest.approx(15.0, rel=0.01)
    assert hog.effective_passes[-1] == pytest.approx(15.0)
    g_svrg = gaps(svrg.history, f_star)[-1]
    g_hog = gaps(hog.history, f_star)[-1]
    assert g_svrg < g_hog, (g_svrg, g_hog)


@pytest.mark.parametrize("scheme", ["consistent", "inconsistent", "unlock"])
def test_all_schemes_reach_suboptimal_gap(problem, scheme):
    obj, f_star = problem
    cfg = SVRGConfig(scheme=scheme, step_size=2.0, num_threads=10, tau=9)
    res = run_asysvrg(obj, epochs=8, cfg=cfg, seed=3)
    assert gaps(res.history, f_star)[-1] < 1e-4
