"""Small AST helpers shared by the repro-lint checkers (stdlib-only —
the linter must run in CI lanes that install nothing, so no jax/numpy
imports anywhere under repro.analysis)."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple, Union

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]
FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jnp.sum' / 'jax.numpy.sum' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def keyword(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def walk_functions(tree: ast.AST) -> Iterator[FunctionNode]:
    for node in ast.walk(tree):
        if isinstance(node, FUNC_NODES):
            yield node


def positional_params(fn: Union[FunctionNode, ast.Lambda]) -> Tuple[str, ...]:
    """Positional(-or-keyword) parameter names — the house convention's
    TRACER arguments (kw-only params after ``*`` are the static config)."""
    args = fn.args
    return tuple(a.arg for a in args.posonlyargs + args.args
                 if a.arg not in ("self", "cls"))


def kwonly_params(fn: Union[FunctionNode, ast.Lambda]) -> Tuple[str, ...]:
    return tuple(a.arg for a in fn.args.kwonlyargs)


def param_names(fn: Union[FunctionNode, ast.Lambda]) -> Tuple[str, ...]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return tuple(names)


def is_self_attr(node: ast.AST, attr: Optional[str] = None) -> bool:
    """True for ``self.<attr>`` (any attr when ``attr`` is None)."""
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and (attr is None or node.attr == attr))


def local_bindings(scope: ast.AST) -> dict:
    """name -> value expression for simple assignments DIRECTLY in a
    function/module body (no recursion into nested functions): the scope
    RL002 resolves a jitted closure's free variables against."""
    out = {}
    body = getattr(scope, "body", [])
    stack = list(body)
    while stack:
        stmt = stack.pop(0)
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    out[tgt.id] = stmt.value
                elif isinstance(tgt, ast.Tuple):
                    for el in tgt.elts:
                        if isinstance(el, ast.Name):
                            out[el.id] = stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            if isinstance(stmt.target, ast.Name):
                out[stmt.target.id] = stmt.value
        elif isinstance(stmt, (ast.If, ast.For, ast.While, ast.With,
                               ast.Try)):
            for field in ("body", "orelse", "finalbody", "handlers"):
                for sub in getattr(stmt, field, []):
                    if isinstance(sub, ast.excepthandler):
                        stack.extend(sub.body)
                    else:
                        stack.append(sub)
    return out


def free_names(fn: Union[FunctionNode, ast.Lambda]) -> List[ast.Name]:
    """Name loads in ``fn``'s body that are not bound by its own params or
    local assignments (candidate closure captures), in source order."""
    bound = set(param_names(fn))
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for node in body:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx,
                                                        (ast.Store,)):
                bound.add(sub.id)
            elif isinstance(sub, FUNC_NODES):
                bound.add(sub.name)
                bound.update(param_names(sub))
            elif isinstance(sub, ast.Lambda):
                bound.update(param_names(sub))
    out = []
    for node in body:
        for sub in ast.walk(node):
            if (isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load)
                    and sub.id not in bound):
                out.append(sub)
    return out
