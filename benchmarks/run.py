"""Benchmark harness entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full]``

Prints ``name,us_per_call,derived`` CSV (quick mode by default; --full uses
the paper-scale settings).
"""
from __future__ import annotations

import sys


def main() -> None:
    quick = "--full" not in sys.argv
    from benchmarks import (fig1_convergence, fig1_speedup,
                            frontier_stability, kernel_sweep,
                            nonconvex_frontier, progress_ledger,
                            roofline_report, server_latency,
                            service_throughput, table2_schemes,
                            table3_vs_hogwild)
    table2_schemes.main(quick=quick)
    kernel_sweep.main(quick=quick)
    table3_vs_hogwild.main(quick=quick)
    frontier_stability.main(quick=quick)
    nonconvex_frontier.main(quick=quick)
    service_throughput.main(quick=quick)
    server_latency.main(quick=quick)
    progress_ledger.main(quick=quick)
    fig1_speedup.main(quick=quick)
    fig1_convergence.main(quick=quick)
    roofline_report.main(quick=quick)


if __name__ == "__main__":
    main()
