"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt (family); unverified]

34L, d_model=2560, 8 heads (kv=4), head_dim=256, d_ff=10240, vocab=262144.
Every 6th layer is global (pattern = 5 local : 1 global), local window 1024.
QK-norm on; logits softcap; tied embeddings (gemma family).

long_500k cell: SKIPPED — the global layers are full attention (quadratic);
recorded in DESIGN.md §5 / EXPERIMENTS.md.
Deviation: a single rope_theta is used (gemma3 uses 1M global / 10k local).
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    attn_pattern="local_global",
    local_window=1024,
    global_every=6,
    qk_norm=True,
    tie_embeddings=True,
    norm="rmsnorm",
    activation="gelu",
    glu=True,
))
