"""Request coalescing: many clients' sweep specs, one compiled dispatch.

A sweep service sees many small requests — different tenants probing the
same (engine, M̃, option, buf_len) program shape with different seeds /
steps / τ. Dispatching each request alone wastes the engine's one-jit-per-
group batching: a 3-row request runs a 3-row vmap even though ten other
requests want the same compiled program. This module merges compatible rows
ACROSS requests into shared groups before dispatch:

  * every pending request is planned independently (`plan_sweep` — the same
    normalization/resolution a standalone `run_sweep` performs, so what a
    request *means* never depends on its neighbours);
  * rows from all requests are pooled by the same static group key the
    engine compiles on, filling the (sharded) row axis of one runner call —
    only the remainder of the device-count multiple is padding, instead of
    per-request padding;
  * each merged group runs ONCE through the persistent runner cache
    (`repro.service.cache`), scanning to the merged members' max epoch
    budget — shorter rows freeze under the masked-epoch semantics;
  * per-row results are demultiplexed back to their requests.

Bit-exactness: a request's demuxed `SweepResult` is BIT-IDENTICAL to a
standalone ``run_sweep(obj, request.epochs, request.specs)``. This follows
from two already-tested engine contracts — per-row bits are independent of
the vmap batch composition (vmap-bitwise-stable reductions; the sharding
padding relies on the same fact), and a row scanned past its budget
freezes bit-exactly (carry passthrough + masked loss writes re-emit the
last live loss, so history entries beyond the row's budget carry the same
frozen value whatever the group's scan bound). tests/test_service.py and
tests/test_sweep_sharded.py assert the end-to-end equality, unsharded and
under a forced 8-device mesh.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import (Callable, Dict, List, NamedTuple, Optional, Sequence,
                    Tuple)

import numpy as np
from jax.sharding import Mesh

from repro.core.objective import Objective
from repro.core.sweep import (
    SweepPlan,
    SweepResult,
    SweepSpec,
    _assemble_result,
    _dispatch_group,
    _write_row_history,
    plan_sweep,
)
from repro.obs.trace import tracer as _tracer


@dataclasses.dataclass(frozen=True)
class SweepRequest:
    """One logical client's sweep: its spec rows + its default epoch budget
    (per-row ``SweepSpec.epochs`` overrides ride along unchanged).

    ``tenant``/``priority`` tag the request for admission control — the
    fair-share selector (`repro.server.fairness`) slices flushes by them;
    the numeric path below ignores both. ``submitted_at`` is the
    `time.monotonic()` admission stamp the background flush daemon's
    deadline policy and the latency metrics read. ``trace_id`` is the
    flight-recorder id `SweepService.submit` minted (empty when tracing
    is off); the dispatch path threads it through so pad/dispatch/demux
    spans land in every owning request's trace."""
    request_id: int
    specs: Tuple[SweepSpec, ...]
    epochs: int
    tenant: str = "default"
    priority: int = 0
    submitted_at: float = 0.0
    trace_id: str = ""

    @property
    def rows(self) -> int:
        return len(self.specs)


# A flush selector partitions the pending queue into (take, keep): `take`
# coalesces into this flush, `keep` stays queued for the next one. The
# fair-share scheduler is one; `None` means take everything.
FlushSelector = Callable[[Tuple[SweepRequest, ...]],
                         Tuple[Sequence[SweepRequest],
                               Sequence[SweepRequest]]]

# A width policy maps (group key, merged epoch bound, natural row count) to
# the row count actually dispatched (>= natural). Returning a previously
# compiled width lets a warm service stay at 0 compiles even when the
# pooled batch width drifts — the vmap row count is part of the traced
# shape, so a NEW width retraces even on a runner-cache hit. Padding rows
# repeat an existing member; per-row bits are batch-composition-independent
# (the same contract the sharding padding relies on), so results are
# unchanged and the pad rows are sliced off before demux.
WidthPolicy = Callable[[tuple, int, int], int]


class _RequestPlan(NamedTuple):
    request: SweepRequest
    plan: SweepPlan
    offset: int                 # this request's first row in the flat batch


class CoalescedBatch(NamedTuple):
    """The merged execution plan for one flush.

    ``specs``/``resolved`` are the requests' normalized rows concatenated in
    admission order; ``groups`` pools flat row indices by the engine's
    static group key, ACROSS requests. The group key leads with the
    objective fingerprint, so requests targeting DIFFERENT objectives
    coalesce in one flush without ever sharing a compiled dispatch;
    ``objectives`` maps each fingerprint to its resolved instance.
    """
    request_plans: Tuple[_RequestPlan, ...]
    specs: tuple
    resolved: tuple
    groups: Dict[tuple, List[int]]
    objectives: Dict[int, Objective]

    def group_epochs(self, key: tuple) -> int:
        """A merged group's static scan bound: max over ALL pooled rows."""
        return max(self.resolved[c].epochs for c in self.groups[key])


class DispatchInfo(NamedTuple):
    """What one flush did, for `ServiceStats` accounting."""
    groups_dispatched: int
    rows_dispatched: int
    rows_coalesced: int      # rows that shared a group with another request
    groups_merged: int       # groups holding rows from >1 request
    rows_padded: int = 0     # stable-width pad rows (wasted compute bought
    #                          against a retrace — see WidthPolicy)
    rows_diverged: int = 0   # rows the divergence watchdog flagged


def coalesce(obj: Optional[Objective],
             requests: Sequence[SweepRequest]) -> CoalescedBatch:
    """Plan every request independently, then pool rows by group key.

    ``obj`` backs specs with ``objective=""``; requests whose specs name a
    registered objective resolve through the registry exactly as a
    standalone `run_sweep` would (and ``obj`` may then be None)."""
    if not requests:
        raise ValueError("nothing to coalesce: no pending requests")
    request_plans: List[_RequestPlan] = []
    specs: list = []
    resolved: list = []
    groups: Dict[tuple, List[int]] = {}
    objectives: Dict[int, Objective] = {}
    offset = 0
    for req in requests:
        plan = plan_sweep(obj, req.epochs, req.specs)
        request_plans.append(_RequestPlan(req, plan, offset))
        objectives[plan.objective.fingerprint()] = plan.objective
        for key, members in plan.groups.items():
            groups.setdefault(key, []).extend(offset + c for c in members)
        specs.extend(plan.specs)
        resolved.extend(plan.resolved)
        offset += len(plan.specs)
    return CoalescedBatch(request_plans=tuple(request_plans),
                          specs=tuple(specs), resolved=tuple(resolved),
                          groups=groups, objectives=objectives)


def dispatch(obj: Optional[Objective], batch: CoalescedBatch, *, w0=None,
             drop_prob: float = 0.02, mesh: Optional[Mesh] = None,
             width_policy: Optional[WidthPolicy] = None,
             watchdog=None,
             ) -> Tuple[Dict[int, SweepResult], DispatchInfo]:
    """Run every merged group once, demux per-request `SweepResult`s.

    Returns ``({request_id: result}, DispatchInfo)``; each result is
    bit-identical to a standalone `run_sweep` of that request's specs with
    the same ``w0``/``drop_prob``/``mesh`` — with or without a
    ``width_policy`` (pad rows repeat member 0 and are dropped before
    demux, so they can only cost compute, never change bits).

    Each group dispatches with ITS objective (``batch.objectives``); ``w0``
    (flat or pytree) must fit every dispatched objective — leave it None
    for a mixed-objective flush (each starts from its own `init_flat`).

    ``watchdog`` (a `repro.obs.watchdog.Watchdog`) inspects each group's
    returned histories; a diverging row is handled per its OWNING
    request's tenant policy. A coalesced flush mixes tenants, so the
    ``cancel_job`` policy degrades to ``cancel_row`` here (one tenant's
    divergence must never cancel another's rows); the re-dispatch a
    cancel triggers reuses the padded width and the cached runner, and
    surviving rows keep their first-dispatch outputs bit-identical.
    """
    specs, resolved = batch.specs, batch.resolved
    w_inits = {ofp: (o.init_flat() if w0 is None else o.as_flat(w0))
               for ofp, o in batch.objectives.items()}
    offsets = [rp.offset for rp in batch.request_plans]

    tr = _tracer()

    def _member_tids(members: Sequence[int]) -> Tuple[str, ...]:
        """The owning requests' trace ids for a group's flat row indices
        (deduped by span_all; all-empty when tracing is off)."""
        if not tr.enabled:
            return ()
        return tuple(
            batch.request_plans[bisect.bisect_right(offsets, c) - 1]
            .request.trace_id for c in members)

    # per-request output buffers at the REQUEST's own history width (its
    # rows' max epoch budget) and ITS objective's flat dim, exactly like a
    # standalone run_sweep
    buffers = []
    for rp in batch.request_plans:
        e_rows = np.asarray([r.epochs for r in rp.plan.resolved], np.int64)
        width = int(e_rows.max()) + 1
        buffers.append((np.zeros((len(rp.plan.specs), width), np.float32),
                        np.zeros((len(rp.plan.specs),
                                  rp.plan.objective.flat_dim), np.float32),
                        e_rows))

    rows_coalesced = 0
    groups_merged = 0
    rows_padded = 0
    diverged_flat: Dict[int, int] = {}   # flat row -> last trusted epoch
    epoch_overrides: Dict[int, int] = {}  # flat row -> truncated budget
    for key_, members in batch.groups.items():
        member_tids = _member_tids(members)
        group_epochs = batch.group_epochs(key_)
        run_members = members
        if width_policy is not None:
            with tr.span_all(member_tids, "pad", parent_name="coalesce"):
                width = int(width_policy(key_, group_epochs, len(members)))
                if width < len(members):
                    raise ValueError(
                        f"width policy shrank group {key_}: {width} < "
                        f"{len(members)} real rows")
                run_members = (members
                               + [members[0]] * (width - len(members)))
                rows_padded += width - len(members)
                tr.annotate(natural=len(members), padded=len(run_members))
        group_obj = batch.objectives[key_[0]]
        with tr.span_all(member_tids, "dispatch", parent_name="coalesce",
                         group_rows=len(run_members),
                         group_epochs=int(group_epochs)):
            hist, w_fin = _dispatch_group(group_obj, specs, resolved,
                                          run_members, key_, group_epochs,
                                          w_inits[key_[0]], drop_prob, mesh)
        if watchdog is not None:
            from repro.obs.watchdog import enforce_group

            hist, w_fin, bad, overrides = enforce_group(
                watchdog, hist, w_fin, members=run_members,
                resolved=resolved, real=len(members),
                tenant_of=lambda c: batch.request_plans[
                    bisect.bisect_right(offsets, c) - 1].request.tenant,
                redispatch=lambda amended: _dispatch_group(
                    group_obj, specs, amended, run_members, key_,
                    group_epochs, w_inits[key_[0]], drop_prob, mesh),
                allow_cancel_job=False)
            diverged_flat.update(bad)
            epoch_overrides.update(overrides)
        hist, w_fin = hist[:len(members)], w_fin[:len(members)]
        owners = {bisect.bisect_right(offsets, c) - 1 for c in members}
        if len(owners) > 1:
            groups_merged += 1
            rows_coalesced += len(members)
        for row, c in enumerate(members):
            ri = bisect.bisect_right(offsets, c) - 1
            local = c - offsets[ri]
            hists, finals, _ = buffers[ri]
            # the merged bound may exceed (or undercut) the request's own
            # history width; _write_row_history trims/pads bit-exactly
            _write_row_history(hists[local], hist[row], group_epochs)
            finals[local] = w_fin[row]

    results: Dict[int, SweepResult] = {}
    all_tids = tuple(rp.request.trace_id for rp in batch.request_plans) \
        if tr.enabled else ()
    with tr.span_all(all_tids, "demux", parent_name="coalesce"):
        for rp, (hists, finals, _) in zip(batch.request_plans, buffers):
            res_rows = rp.plan.resolved
            req_diverged = None
            if diverged_flat:
                n = len(rp.plan.specs)
                req_diverged = {c - rp.offset: e
                                for c, e in diverged_flat.items()
                                if rp.offset <= c < rp.offset + n}
                if any(rp.offset <= c < rp.offset + n
                       for c in epoch_overrides):
                    res_rows = list(res_rows)
                    for c, k in epoch_overrides.items():
                        if rp.offset <= c < rp.offset + n:
                            local = c - rp.offset
                            res_rows[local] = \
                                res_rows[local]._replace(epochs=k)
            results[rp.request.request_id] = _assemble_result(
                rp.plan.specs, res_rows, hists, finals,
                param_shapes=rp.plan.objective.param_shapes(),
                w_init=w_inits[rp.plan.objective.fingerprint()],
                diverged=req_diverged)

    info = DispatchInfo(groups_dispatched=len(batch.groups),
                        rows_dispatched=len(specs),
                        rows_coalesced=rows_coalesced,
                        groups_merged=groups_merged,
                        rows_padded=rows_padded,
                        rows_diverged=len(diverged_flat))
    return results, info
