"""Device-sharded sweep equivalence suite (forced multi-device CPU).

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
``tier1-multidevice`` job); module-skips on a single-device host so the
plain tier-1 run stays green everywhere.

Contract under test: sharding `run_sweep`'s config-row axis over the mesh
`data` axis (shard_map, no cross-row collectives) is BIT-IDENTICAL per row
to the single-device vmapped path — for every algo, for group sizes that
divide the device count and sizes that need padding, and composed with
masked per-row epochs. This is the XLA:CPU calibration of the bit-exactness
contract; re-validate per backend before trusting it on TPU/GPU.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (LogisticRegression, SweepSpec, run_asysvrg,
                        run_hogwild, run_sweep)
from repro.data.libsvm import make_synthetic_libsvm
from repro.launch.mesh import make_sweep_mesh
from repro.sharding.context import mesh_context

if jax.device_count() < 2:
    pytest.skip("needs >= 2 devices (XLA_FLAGS="
                "--xla_force_host_platform_device_count=8)",
                allow_module_level=True)

SCHEMES = ("consistent", "inconsistent", "unlock")


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


@pytest.fixture(scope="module")
def mesh():
    return make_sweep_mesh()


def _assert_same(res_a, res_b):
    np.testing.assert_array_equal(res_a.histories, res_b.histories)
    np.testing.assert_array_equal(res_a.final_w, res_b.final_w)
    np.testing.assert_array_equal(res_a.effective_passes,
                                  res_b.effective_passes)
    np.testing.assert_array_equal(res_a.total_updates, res_b.total_updates)
    np.testing.assert_array_equal(res_a.epochs_per_row, res_b.epochs_per_row)


def test_sharded_matches_unsharded_asysvrg_unpadded(obj, mesh):
    """Group size = a multiple of the device count (no padding): bit-equal
    per row across schemes / seeds / steps."""
    D = jax.device_count()
    specs = [SweepSpec(scheme=s, step_size=st, tau=3, num_threads=4,
                       inner_steps=25, seed=sd)
             for s in SCHEMES for sd in range(3) for st in (0.25, 0.5)][:2 * D]
    assert len(specs) % D == 0
    base = run_sweep(obj, 2, specs)
    shard = run_sweep(obj, 2, specs, mesh=mesh)
    _assert_same(base, shard)


@pytest.mark.parametrize("rows", [1, 5, 11])
def test_sharded_matches_unsharded_padded_group_sizes(obj, mesh, rows):
    """Group sizes that do NOT divide the device count: padding rows are
    computed and discarded without perturbing real rows."""
    specs = [SweepSpec(scheme=SCHEMES[c % 3], step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25, seed=c)
             for c in range(rows)]
    base = run_sweep(obj, 2, specs)
    shard = run_sweep(obj, 2, specs, mesh=mesh)
    _assert_same(base, shard)


def test_sharded_matches_unsharded_all_algos(obj, mesh):
    """Mixed asysvrg / hogwild / svrg grid: every engine's sharded groups
    reproduce the unsharded rows, which themselves match the sequential
    drivers."""
    specs = [SweepSpec(scheme="inconsistent", step_size=0.5, tau=2,
                       num_threads=3, inner_steps=20, seed=1),
             SweepSpec(scheme="unlock", step_size=0.5, tau=2,
                       num_threads=3, inner_steps=20, seed=4),
             SweepSpec(algo="hogwild", scheme="unlock", step_size=0.5,
                       tau=2, num_threads=3, seed=2),
             SweepSpec(algo="hogwild", scheme="consistent", step_size=0.5,
                       tau=0, num_threads=3, seed=3),
             SweepSpec(algo="svrg", step_size=0.5, num_threads=1,
                       inner_steps=30, seed=5)]
    base = run_sweep(obj, 2, specs)
    shard = run_sweep(obj, 2, specs, mesh=mesh)
    _assert_same(base, shard)

    ref = run_asysvrg(obj, 2, specs[0].to_config(), seed=1)
    np.testing.assert_array_equal(np.asarray(ref.history, np.float32),
                                  shard.histories[0])
    ref_h = run_hogwild(obj, 2, 0.5, num_threads=3, scheme="unlock", tau=2,
                        seed=2)
    np.testing.assert_array_equal(np.asarray(ref_h.history, np.float32),
                                  shard.histories[2])


def test_sharded_masked_epochs_match_shorter_runs(obj, mesh):
    """Masked per-row epochs compose with sharding: each row of a sharded
    mixed-budget call equals an independent run of its own length."""
    specs = [SweepSpec(scheme="inconsistent", step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25, seed=7, epochs=e)
             for e in (1, 2, 3)]
    shard = run_sweep(obj, 3, specs, mesh=mesh)
    for c, spec in enumerate(specs):
        seq = run_asysvrg(obj, spec.epochs, spec.to_config(), seed=7)
        np.testing.assert_array_equal(
            np.asarray(seq.history, np.float32),
            shard.histories[c, :spec.epochs + 1])
        np.testing.assert_array_equal(np.asarray(seq.w, np.float32),
                                      shard.final_w[c])


def test_fig1_paired_budgets_sharded_single_call(obj, mesh):
    """The Fig. 1 shape — AsySVRG E vs Hogwild! 3E — sharded, one call,
    identical to the unsharded single call."""
    E, p = 2, 4
    specs = ([SweepSpec(scheme=s, step_size=0.5, num_threads=p, tau=p - 1,
                        epochs=E) for s in ("inconsistent", "unlock")]
             + [SweepSpec(algo="hogwild", scheme=s, step_size=0.5,
                          num_threads=p, tau=p - 1, epochs=3 * E)
                for s in ("inconsistent", "unlock")])
    base = run_sweep(obj, E, specs)
    shard = run_sweep(obj, E, specs, mesh=mesh)
    _assert_same(base, shard)


def test_ambient_mesh_context_shards(obj, mesh):
    """`with mesh_context(mesh)` shards the sweep with no call-site mesh=
    argument (the launcher integration), with identical bits."""
    specs = [SweepSpec(scheme="consistent", step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25, seed=s)
             for s in range(3)]
    explicit = run_sweep(obj, 2, specs, mesh=mesh)
    with mesh_context(mesh):
        ambient = run_sweep(obj, 2, specs)
    _assert_same(explicit, ambient)


def test_service_coalescing_sharded_bit_identical(obj, mesh):
    """The sweep service under a forced 8-device mesh: multi-request
    coalescing (all three algos, mixed per-row epochs, row counts needing
    padding) demuxes bit-identical to standalone `run_sweep` — sharded AND
    unsharded — and the second flush of the same shapes compiles nothing."""
    from repro.service import SweepService, cache_stats

    req_a = [SweepSpec(scheme=SCHEMES[c % 3], step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25, seed=c)
             for c in range(3)]
    req_b = [SweepSpec(scheme="unlock", step_size=0.25, tau=3,
                       num_threads=4, inner_steps=25, seed=9, epochs=1),
             SweepSpec(algo="hogwild", scheme="consistent", step_size=0.5,
                       tau=2, num_threads=3, seed=2),
             SweepSpec(algo="svrg", step_size=0.5, num_threads=1,
                       inner_steps=30, seed=5)]

    svc = SweepService(obj, epochs=2, mesh=mesh)
    rid_a, rid_b = svc.submit(req_a), svc.submit(req_b)
    svc.flush()
    for rid, specs in ((rid_a, req_a), (rid_b, req_b)):
        sharded = run_sweep(obj, 2, specs, mesh=mesh)
        unsharded = run_sweep(obj, 2, specs)
        got = svc.result(rid)
        _assert_same(got, sharded)
        _assert_same(got, unsharded)
    assert svc.stats().rows_coalesced > 0

    base = cache_stats()
    svc.submit(req_a)
    svc.submit(req_b)
    svc.flush()
    assert cache_stats().since(base).compiles == 0


def test_http_server_sharded_bit_identical(obj, mesh):
    """Acceptance (serving tier): results served over HTTP from a SHARDED
    service — background deadline flush, wire round-trip and all — are
    bit-identical to in-process `run_sweep`, sharded and unsharded, for
    every tenant under the forced 8-device mesh."""
    from repro.server import FlushPolicy, SweepClient, SweepServer
    from repro.service import SweepService

    tenants = {
        "team-a": [SweepSpec(scheme=SCHEMES[c % 3], step_size=0.5, tau=3,
                             num_threads=4, inner_steps=25, seed=40 + c)
                   for c in range(3)],
        "team-b": [SweepSpec(algo="hogwild", scheme="consistent",
                             step_size=0.5, tau=2, num_threads=3, seed=41)],
    }
    svc = SweepService(obj, epochs=2, mesh=mesh)
    with SweepServer(svc, policy=FlushPolicy(max_rows=64,
                                             max_delay_ms=25)) as server:
        client = SweepClient(server.url, poll_s=5.0)
        rids = {name: client.submit(specs, tenant=name)
                for name, specs in tenants.items()}
        for name, specs in tenants.items():
            got = client.result(rids[name], timeout=240)
            _assert_same(got, run_sweep(obj, 2, specs, mesh=mesh))
            _assert_same(got, run_sweep(obj, 2, specs))


@pytest.mark.nonconvex
def test_pytree_objectives_sharded_bit_identical(mesh):
    """The pluggable-objective workloads (MLP LM pytree params; nonconvex
    clipped-penalty logistic) under the forced 8-device mesh: sharded ==
    unsharded per row, and `final_params` rebuilds the same pytree."""
    from repro.core import NonconvexLogistic, mlp_lm_objective

    mlp = mlp_lm_objective(n=16, vocab_size=16, seq_len=4, d_model=8,
                           d_hidden=8)
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    ncv = NonconvexLogistic(ds.X, ds.y, lam=1e-3, alpha=10.0)
    for workload in (mlp, ncv):
        specs = [SweepSpec(scheme=SCHEMES[c % 3], step_size=0.1, tau=2,
                           num_threads=3, inner_steps=10, seed=c)
                 for c in range(3)]
        specs.append(SweepSpec(algo="hogwild", scheme="consistent",
                               step_size=0.1, tau=2, num_threads=3, seed=9))
        base = run_sweep(workload, 2, specs)
        shard = run_sweep(workload, 2, specs, mesh=mesh)
        _assert_same(base, shard)
        np.testing.assert_array_equal(
            np.asarray(workload.as_flat(shard.final_params(0))),
            shard.final_w[0])


@pytest.mark.nonconvex
def test_pytree_objectives_http_sharded_bit_identical(mesh):
    """Acceptance: both new workloads end-to-end through SweepService + the
    HTTP server OVER a forced 8-device mesh — results bit-identical to
    in-process sharded and unsharded `run_sweep`, wire round-trip included
    (the nonconvex workload addressed by registry name, service obj=None)."""
    from repro.core import NonconvexLogistic, mlp_lm_objective
    from repro.core.objective import (register_objective,
                                      unregister_objective)
    from repro.server import FlushPolicy, SweepClient, SweepServer
    from repro.service import SweepService

    mlp = mlp_lm_objective(n=16, vocab_size=16, seq_len=4, d_model=8,
                           d_hidden=8)
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    ncv = NonconvexLogistic(ds.X, ds.y, lam=1e-3, alpha=10.0)
    register_objective("sharded-test-ncv", ncv)
    try:
        mlp_specs = [SweepSpec(scheme="inconsistent", step_size=0.1, tau=2,
                               num_threads=3, inner_steps=10, seed=0),
                     SweepSpec(algo="hogwild", scheme="consistent",
                               step_size=0.1, tau=2, num_threads=3, seed=1)]
        ncv_specs = [SweepSpec(scheme="unlock", step_size=0.2, tau=2,
                               num_threads=3, inner_steps=10, seed=0,
                               objective="sharded-test-ncv")]
        svc = SweepService(mlp, epochs=2, mesh=mesh)
        with SweepServer(svc, policy=FlushPolicy(max_rows=64,
                                                 max_delay_ms=25)) as server:
            client = SweepClient(server.url, poll_s=5.0)
            rid_mlp = client.submit(mlp_specs, tenant="mlp")
            rid_ncv = client.submit(ncv_specs, tenant="ncv")
            got_mlp = client.result(rid_mlp, timeout=240)
            got_ncv = client.result(rid_ncv, timeout=240)
        _assert_same(got_mlp, run_sweep(mlp, 2, mlp_specs, mesh=mesh))
        _assert_same(got_mlp, run_sweep(mlp, 2, mlp_specs))
        _assert_same(got_ncv, run_sweep(None, 2, ncv_specs, mesh=mesh))
        _assert_same(got_ncv, run_sweep(None, 2, ncv_specs))
        assert set(got_mlp.final_params(0)) == {"embed", "norm", "w1",
                                                "b1", "w2"}
    finally:
        unregister_objective("sharded-test-ncv")


def test_fused_engine_sharded_matches_vmap_unsharded(obj, mesh):
    """The fused Pallas megakernel path (interpret mode on this CPU host)
    composes with shard_map row sharding: a sharded fused sweep over all
    three algos — at a row count that needs padding under 8 devices, with
    mixed per-row epoch budgets — is bit-identical to the unsharded VMAP
    path, closing fused==vmap and sharded==unsharded in one assertion."""
    specs = [SweepSpec(scheme=SCHEMES[c % 3], step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25, seed=c,
                       epochs=(c % 2) + 1)
             for c in range(3)]
    specs += [SweepSpec(algo="hogwild", scheme="unlock", step_size=0.5,
                        tau=2, num_threads=3, seed=8),
              SweepSpec(algo="svrg", step_size=0.5, inner_steps=30, seed=9)]
    fused = [dataclasses.replace(s, engine_mode="fused") for s in specs]
    base = run_sweep(obj, 2, specs)
    shard_fused = run_sweep(obj, 2, fused, mesh=mesh)
    _assert_same(base, shard_fused)


def test_watchdog_cancel_row_sharded_survivors_bit_identical(obj, mesh):
    """Divergence watchdog under the forced 8-device mesh, vmap AND fused
    engines: the step_size=1e30 row NaNs on epoch 1 and is cancelled
    (cancel_row), while every surviving row stays bit-identical to a
    watchdog-off run — sharded and unsharded."""
    from repro.obs.watchdog import Watchdog
    from repro.service import SweepService

    for engine_mode in ("vmap", "fused"):
        good = [SweepSpec(scheme=SCHEMES[c % 3], step_size=0.5, tau=3,
                          num_threads=4, inner_steps=25, seed=c,
                          engine_mode=engine_mode)
                for c in range(3)]
        bad = SweepSpec(scheme="inconsistent", step_size=1e30, tau=3,
                        num_threads=4, inner_steps=25, seed=99,
                        engine_mode=engine_mode)
        specs = good + [bad]

        svc = SweepService(obj, epochs=2, mesh=mesh,
                           watchdog=Watchdog(policy="cancel_row"))
        rid = svc.submit(specs)
        svc.flush()
        got = svc.result(rid)

        assert got.diverged_rows is not None
        assert np.flatnonzero(got.diverged_rows >= 0).tolist() == [3]
        assert got.epochs_per_row[3] == 0          # frozen at w0
        assert np.isfinite(got.histories[3]).all()

        ref_sharded = run_sweep(obj, 2, good, mesh=mesh)
        ref_unsharded = run_sweep(obj, 2, good)
        for ref in (ref_sharded, ref_unsharded):
            np.testing.assert_array_equal(got.histories[:3], ref.histories)
            np.testing.assert_array_equal(got.final_w[:3], ref.final_w)


def test_model_axis_mesh_degrades_to_unsharded(obj):
    """A mesh without a >1 `data` axis (e.g. the 1×1 host mesh) falls back
    to the single-device path rather than erroring."""
    from repro.launch.mesh import make_host_mesh
    specs = [SweepSpec(scheme="consistent", step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25)]
    base = run_sweep(obj, 1, specs)
    host = run_sweep(obj, 1, specs, mesh=make_host_mesh())
    _assert_same(base, host)
