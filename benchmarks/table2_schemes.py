"""Paper Table 2: lock vs unlock — per-scheme speedup over 1 thread.

For each scheme and thread count: the delay engine gives the converged
iterate (statistical behaviour) and the measured-cost throughput model
(benchmarks.cost_model) gives wall time. speedup(p) = wall(1)/wall(p) with
epochs inflated when staleness slows statistical progress (matching the
paper's "time to suboptimal solution" definition).
"""
from __future__ import annotations

import numpy as np

from repro.config import SVRGConfig
from repro.core import LogisticRegression, run_asysvrg
from repro.data.libsvm import make_synthetic_libsvm
from benchmarks.cost_model import measure_primitives, wall_time


def epochs_to_gap(obj, f_star, scheme, p, step, gap=1e-4, max_epochs=25,
                  seed=0):
    cfg = SVRGConfig(scheme=scheme, step_size=step, num_threads=p,
                     tau=max(0, p - 1))
    res = run_asysvrg(obj, max_epochs, cfg, seed=seed)
    gaps = np.asarray(res.history) - f_star
    hit = np.nonzero(gaps < gap)[0]
    epochs = int(hit[0]) if len(hit) else max_epochs
    updates_per_epoch = res.total_updates // max_epochs
    return epochs, updates_per_epoch


def run(scale=0.03, step=2.0, threads=(2, 4, 8, 10), quick=False):
    ds = make_synthetic_libsvm("rcv1", scale=scale)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    prim = measure_primitives(obj, iters=50 if quick else 200)

    e1, upd = epochs_to_gap(obj, f_star, "consistent", 1, step,
                            max_epochs=12 if quick else 25)
    base_wall = wall_time("unlock", e1 * upd, 1, prim)   # p=1: no contention

    rows = []
    for scheme in ("consistent", "inconsistent", "unlock"):
        for p in threads:
            e, updp = epochs_to_gap(obj, f_star, scheme, p, step,
                                    max_epochs=12 if quick else 25)
            wall = wall_time(scheme, e * updp, p, prim)
            rows.append({
                "scheme": scheme, "threads": p, "epochs_to_1e-4": e,
                "wall_s": wall, "speedup": base_wall / wall,
            })
    return {"rows": rows, "primitives": prim, "baseline_wall_s": base_wall}


def main(quick=True):
    out = run(quick=quick)
    print("name,us_per_call,derived")
    for r in out["rows"]:
        print(f"table2_{r['scheme']}_p{r['threads']},"
              f"{r['wall_s'] * 1e6:.1f},speedup={r['speedup']:.2f}x"
              f";epochs={r['epochs_to_1e-4']}")


if __name__ == "__main__":
    main(quick=False)
