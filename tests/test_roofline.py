"""Roofline extraction: HLO collective parsing + analytic FLOPs accounting."""
import numpy as np

from repro.config import SHAPE_GRID
from repro.configs import get_config
from repro.launch.roofline import (
    attention_flops, count_params, model_flops, parse_collective_bytes,
    roofline_terms)
from repro.models.factory import build_model

HLO = """
ENTRY %main {
  %ar = f32[32,2048]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[16,16]<=[256]
  %ag = bf16[64,4096]{1,0} all-gather(%p0), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%x), channel_id=3
  %cp = bf16[4,4]{1,0} collective-permute(%y), channel_id=4
  %dot2 = f32[12,12]{1,0} dot(%a, %b)
}
"""


def test_parse_collective_bytes():
    out = parse_collective_bytes(HLO)
    assert out["all-reduce"] == 32 * 2048 * 4
    # all-gather operand = output / group size (16)
    assert out["all-gather"] == 64 * 4096 * 2 // 16
    assert out["reduce-scatter"] == 8 * 128 * 4
    assert out["collective-permute"] == 4 * 4 * 2
    assert out["count"] == 4


def test_count_params_dense_vs_moe():
    dense = get_config("chatglm3-6b")
    b = build_model(dense)
    total, active = count_params(dense, b.param_defs)
    assert 5.5e9 < total < 7.5e9          # ~6B
    assert total == active                # dense: all params active

    moe = get_config("qwen3-moe-235b-a22b")
    bm = build_model(moe)
    t2, a2 = count_params(moe, bm.param_defs)
    assert 2.0e11 < t2 < 2.7e11           # ~235B
    assert 1.5e10 < a2 < 3.0e10           # ~22B active
    assert a2 < t2


def test_model_flops_scaling():
    cfg = get_config("chatglm3-6b")
    b = build_model(cfg)
    f_train = model_flops(cfg, SHAPE_GRID["train_4k"], b.param_defs)
    f_dec = model_flops(cfg, SHAPE_GRID["decode_32k"], b.param_defs)
    # train ≈ 6 * 6.2e9 * 1.05e6 tokens ≈ 3.9e16; decode's per-step work is
    # dominated by cache attention (B=128 x 32k keys) but still far smaller
    assert f_train > 1e16
    assert f_dec < f_train / 10


def test_attention_flops_window_aware():
    g = get_config("gemma3-4b")
    full = attention_flops(g.with_overrides(attn_pattern="global"),
                           32768, 1, decode=False)
    lg = attention_flops(g, 32768, 1, decode=False)
    assert lg < full                       # 5:1 local:global cuts attn flops
    ssm = get_config("falcon-mamba-7b")
    assert attention_flops(ssm, 32768, 1, decode=False) == 0.0


def test_roofline_terms_dominant():
    rec = {
        "cost": {"flops": 1e12, "bytes accessed": 1e9},
        "collectives": {"all-reduce": 5e8, "all-gather": 0,
                        "reduce-scatter": 0, "all-to-all": 0,
                        "collective-permute": 0, "count": 1},
        "num_devices": 256,
        "model_flops": 1e14,
    }
    t = roofline_terms(rec)
    # 1e12/197e12 ≈ 5.1ms; 1e9/819e9 ≈ 1.2ms; 5e8/50e9 = 10ms
    assert t["dominant"] == "collective"
    np.testing.assert_allclose(t["t_compute_s"], 1e12 / 197e12)
    assert 0 < t["useful_ratio"] < 1
