"""Optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.optim import clip_by_global_norm, make_optimizer, make_schedule


def _cfg(**kw):
    return TrainConfig(**kw)


def test_sgd_step():
    opt = make_optimizer(_cfg(optimizer="sgd"))
    params = {"w": jnp.ones(4)}
    v = {"w": jnp.full(4, 2.0)}
    new, _ = opt.apply(v, opt.init(params), 0.5, params, 0)
    np.testing.assert_allclose(np.asarray(new["w"]), np.zeros(4))


def test_momentum_accumulates():
    opt = make_optimizer(_cfg(optimizer="momentum", beta1=0.5))
    params = {"w": jnp.zeros(1)}
    st = opt.init(params)
    v = {"w": jnp.ones(1)}
    p1, st = opt.apply(v, st, 1.0, params, 0)       # m=1, w=-1
    p2, st = opt.apply(v, st, 1.0, p1, 1)           # m=1.5, w=-2.5
    np.testing.assert_allclose(np.asarray(p2["w"]), [-2.5])


def test_adamw_direction_and_decay():
    opt = make_optimizer(_cfg(optimizer="adamw", weight_decay=0.0))
    params = {"w": jnp.zeros(3)}
    st = opt.init(params)
    v = {"w": jnp.asarray([1.0, -1.0, 2.0])}
    new, st = opt.apply(v, st, 0.1, params, 0)
    # first adam step ≈ -lr * sign(g)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               [-0.1, 0.1, -0.1], atol=1e-3)


def test_svrg_optimizer_is_sgd():
    assert make_optimizer(_cfg(optimizer="svrg")).name == "sgd"


def test_clip_by_global_norm():
    tree = {"a": jnp.full(4, 3.0), "b": jnp.full(9, 4.0)}
    # norm = sqrt(4*9 + 9*16) = sqrt(180)
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(180.0), rtol=1e-5)
    total = np.sqrt(sum(float(jnp.sum(x * x))
                        for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-4)
    # no-clip path
    same, _ = clip_by_global_norm(tree, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)


def test_schedules():
    cfg = _cfg(steps=100, warmup_steps=10, learning_rate=1.0,
               schedule="cosine")
    s = make_schedule(cfg)
    assert float(s(0)) == 0.0
    np.testing.assert_allclose(float(s(10)), 1.0, atol=1e-6)
    assert float(s(99)) < 0.01
    lin = make_schedule(_cfg(steps=100, warmup_steps=0, learning_rate=2.0,
                             schedule="linear"))
    np.testing.assert_allclose(float(lin(50)), 1.0, atol=0.05)
    const = make_schedule(_cfg(schedule="constant", warmup_steps=1,
                               learning_rate=3.0))
    np.testing.assert_allclose(float(const(50)), 3.0)
