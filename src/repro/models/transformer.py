"""Dense decoder-only transformer (GQA + RoPE), scan-over-layers + remat.

Covers chatglm3-6b, stablelm-12b, gemma3-4b (5:1 local:global), and
command-r-plus-104b via ModelConfig knobs; reused as the backbone by the MoE
and VLM families. Three entry points per the shape grid:

  * ``loss_fn``      — train_4k (full fwd + chunked xent)
  * ``prefill``      — prefill_32k (returns last-position logits + KV cache)
  * ``decode_step``  — decode_32k / long_500k (one token, cache update)

KV caches are laid out [L, B, K, S, h] with the sequence dim tagged
``seq_shard`` (→ `model` mesh axis): flash-decode-style sharding, chosen
because GQA kv-head counts (1–20) do not divide a 16-way TP axis.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import layers as nn
from repro.sharding.context import constrain, constrain_tree
from repro.sharding.rules import ParamDef, layer_axes_strs

# residual-stream constraint for attention families: sequence parallelism
RESIDUAL_AXES = ("batch", "seq_shard", None)


def block_axes(cfg: ModelConfig) -> dict:
    """Axis-string tree for one layer's params (constrain_tree input)."""
    return layer_axes_strs(block_param_defs(cfg, 1, cfg.param_dtype))


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------

def _norm_defs(shape, cfg: ModelConfig, dtype):
    axes = ("layers", None) if len(shape) == 2 else (None,)
    if cfg.norm == "layernorm":
        return {
            "scale": ParamDef(shape, axes, "ones", dtype=dtype),
            "bias": ParamDef(shape, axes, "zeros", dtype=dtype),
        }
    # rmsnorm uses (1 + scale), so zeros == identity
    return {"scale": ParamDef(shape, axes, "zeros", dtype=dtype)}


def block_param_defs(cfg: ModelConfig, num_layers: int, dtype: str) -> Dict:
    """Stacked per-layer params for one homogeneous attention+MLP stack."""
    L, D = num_layers, cfg.d_model
    N, K, h, F = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_ff
    p = {
        "attn_norm": _norm_defs((L, D), cfg, dtype),
        "mlp_norm": _norm_defs((L, D), cfg, dtype),
        "attn": {
            "wq": ParamDef((L, D, N, h), ("layers", "embed", "heads", "head_dim"), dtype=dtype),
            "wk": ParamDef((L, D, K, h), ("layers", "embed", "kv_heads", "head_dim"), dtype=dtype),
            "wv": ParamDef((L, D, K, h), ("layers", "embed", "kv_heads", "head_dim"), dtype=dtype),
            "wo": ParamDef((L, N, h, D), ("layers", "heads", "head_dim", "embed"), dtype=dtype),
        },
        "mlp": {
            "w_up": ParamDef((L, D, F), ("layers", "embed", "mlp"), dtype=dtype),
            "w_down": ParamDef((L, F, D), ("layers", "mlp", "embed"), dtype=dtype),
        },
    }
    if cfg.glu:
        p["mlp"]["w_gate"] = ParamDef((L, D, F), ("layers", "embed", "mlp"), dtype=dtype)
    if cfg.use_qkv_bias:
        p["attn"]["bq"] = ParamDef((L, N, h), ("layers", "heads", "head_dim"), "zeros", dtype=dtype)
        p["attn"]["bk"] = ParamDef((L, K, h), ("layers", "kv_heads", "head_dim"), "zeros", dtype=dtype)
        p["attn"]["bv"] = ParamDef((L, K, h), ("layers", "kv_heads", "head_dim"), "zeros", dtype=dtype)
    if cfg.use_bias:
        p["attn"]["bo"] = ParamDef((L, D), ("layers", "embed"), "zeros", dtype=dtype)
        p["mlp"]["b_up"] = ParamDef((L, F), ("layers", "mlp"), "zeros", dtype=dtype)
        p["mlp"]["b_down"] = ParamDef((L, D), ("layers", "embed"), "zeros", dtype=dtype)
    if cfg.qk_norm:
        p["attn"]["q_norm"] = ParamDef((L, h), ("layers", None), "zeros", dtype=dtype)
        p["attn"]["k_norm"] = ParamDef((L, h), ("layers", None), "zeros", dtype=dtype)
    return p


def param_defs(cfg: ModelConfig) -> Dict:
    dt = cfg.param_dtype
    D, V = cfg.d_model, cfg.vocab_size
    p = {
        "tok_embed": ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt),
        "blocks": block_param_defs(cfg, cfg.num_layers, dt),
        "final_norm": _norm_defs((D,), cfg, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt)
    return p


def _layer_flags(cfg: ModelConfig) -> np.ndarray:
    """Per-layer local-attention window (0 = global)."""
    L = cfg.num_layers
    if cfg.attn_pattern == "global":
        return np.zeros(L, np.int32)
    if cfg.attn_pattern == "local":
        return np.full(L, cfg.local_window, np.int32)
    # local_global: one global layer every `global_every` (gemma3: 5 local : 1)
    w = np.full(L, cfg.local_window, np.int32)
    w[cfg.global_every - 1::cfg.global_every] = 0
    return w


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _qk_normalize(cfg, p, q, k):
    if cfg.qk_norm:
        q = nn.rmsnorm(q, p["q_norm"])
        k = nn.rmsnorm(k, p["k_norm"])
    return q, k


def block_apply(cfg: ModelConfig, lp: Dict, h, pos, window,
                kv_override: Optional[Tuple] = None, pos_k=None):
    """One transformer block. `window` is a traced int32 scalar (0 = global).

    kv_override, pos_k: (k, v) tensors + key positions for decode (cache).
    Returns (h_out, (k_new, v_new)) — the fresh K/V for cache maintenance.
    """
    x = nn.apply_norm(cfg, h, lp["attn_norm"])
    q, k, v = nn.gqa_project(x, lp["attn"], cfg, cfg.use_qkv_bias)
    q, k = _qk_normalize(cfg, lp["attn"], q, k)
    q = nn.apply_rope(q, pos, cfg)
    k = nn.apply_rope(k, pos, cfg)
    k_new, v_new = k, v
    if kv_override is not None:
        k, v = kv_override
        pk = pos_k
    else:
        pk = pos
    out = nn.attention(q, k, v, pos, pk, causal=True, window=window,
                       chunk_q=2048)
    h = h + nn.attn_output(out, lp["attn"], cfg.use_bias)
    x = nn.apply_norm(cfg, h, lp["mlp_norm"])
    h = h + nn.mlp(x, lp["mlp"], cfg)
    return h, (k_new, v_new)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params, tokens):
    table = constrain(params["tok_embed"], ("vocab", None))
    e = jnp.take(table, tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.family in ("dense", "moe", "vlm") and cfg.norm == "rmsnorm":
        e = e * jnp.sqrt(float(cfg.d_model)).astype(e.dtype)  # gemma-style scale
    return e


def _scan_blocks(cfg: ModelConfig, blocks, h, pos, windows, extra_xs=None,
                 body_fn=None):
    """lax.scan over stacked layer params with optional remat."""
    body_fn = body_fn or (lambda carry, lp, w: block_apply(cfg, lp, carry, pos, w)[0])

    axes = block_axes(cfg)

    def step(carry, xs):
        carry = constrain(carry, RESIDUAL_AXES)
        if extra_xs is not None:
            lp, w, ex = xs
            out = body_fn(carry, constrain_tree(lp, axes), w, ex)
        else:
            lp, w = xs
            out = body_fn(carry, constrain_tree(lp, axes), w)
        # output constrained too: scan saves/stacks body outputs for the
        # backward pass; unconstrained stacks accumulate replicated
        return constrain(out, RESIDUAL_AXES), None

    if cfg.remat == "full":
        step = jax.checkpoint(step, prevent_cse=False)
    xs = (blocks, windows) if extra_xs is None else (blocks, windows, extra_xs)
    h, _ = jax.lax.scan(step, h, xs)
    return h


def hidden_states(cfg: ModelConfig, params, tokens, positions=None):
    B, S = tokens.shape
    pos = positions if positions is not None else jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    h = embed_tokens(cfg, params, tokens)
    windows = jnp.asarray(_layer_flags(cfg))
    h = _scan_blocks(cfg, params["blocks"], h, pos, windows)
    return nn.apply_norm(cfg, h, params["final_norm"])


def unembed(cfg: ModelConfig, params):
    return params["tok_embed"] if cfg.tie_embeddings else params["lm_head"]


def loss_fn(cfg: ModelConfig, params, batch):
    h = hidden_states(cfg, params, batch["tokens"])
    return nn.lm_loss(h, unembed(cfg, params), batch["targets"],
                      batch["mask"], softcap=cfg.logits_softcap)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    L, K, h = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    kv_dt = cfg.dtype
    ax = ("layers", "batch", "cache_kv", "seq_shard", "head_dim")
    return {
        "k": ParamDef((L, batch, K, seq_len, h), ax, "zeros", dtype=kv_dt),
        "v": ParamDef((L, batch, K, seq_len, h), ax, "zeros", dtype=kv_dt),
    }


def prefill(cfg: ModelConfig, params, tokens, cache_len: int):
    """Process a full prompt; returns (last-token logits, cache dict)."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    h = embed_tokens(cfg, params, tokens)
    windows = jnp.asarray(_layer_flags(cfg))

    axes = block_axes(cfg)

    def body(carry, xs):
        lp, w = xs
        carry = constrain(carry, RESIDUAL_AXES)
        out, (k, v) = block_apply(cfg, constrain_tree(lp, axes), carry, pos, w)
        return out, (k, v)

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], windows))
    h = nn.apply_norm(cfg, h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h[:, -1, :], unembed(cfg, params))

    def pad_cache(x):  # [L,B,S,K,h] -> [L,B,K,cache_len,h]
        x = x.transpose(0, 1, 3, 2, 4)
        pad = cache_len - x.shape[3]
        return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))).astype(jnp.dtype(cfg.dtype))

    return logits.astype(jnp.float32), {"k": pad_cache(ks), "v": pad_cache(vs)}


def decode_step(cfg: ModelConfig, params, cache: Dict, tokens, pos_scalar):
    """One decode step. tokens [B] int32; pos_scalar [] int32 (shared position
    — continuous batching with per-seq positions is a serve-loop concern).
    Returns (logits [B,V] f32, updated cache).

    The cache travels in the scan CARRY and is updated with per-layer
    dynamic-update-slices: with donation this aliases in place. (The ys
    formulation materialized a second cache copy — and XLA:CPU additionally
    promoted the ys accumulator to f32: +12 GiB on command-r, see
    EXPERIMENTS.md §Perf.)"""
    B = tokens.shape[0]
    S = cache["k"].shape[3]
    L = cfg.num_layers
    tok = tokens[:, None]
    pos_q = jnp.broadcast_to(pos_scalar[None, None], (B, 1)).astype(jnp.int32)
    pos_k = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    h = embed_tokens(cfg, params, tok)
    windows = jnp.asarray(_layer_flags(cfg))

    def body(carry, xs):
        hh, ck_all, cv_all = carry
        lp, w, i = xs
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        x = nn.apply_norm(cfg, hh, lp["attn_norm"])
        q, k, v = nn.gqa_project(x, lp["attn"], cfg, cfg.use_qkv_bias)
        q, k = _qk_normalize(cfg, lp["attn"], q, k)
        q = nn.apply_rope(q, pos_q, cfg)
        k = nn.apply_rope(k, pos_q, cfg)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.transpose(0, 2, 1, 3).astype(ck.dtype), pos_scalar, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.transpose(0, 2, 1, 3).astype(cv.dtype), pos_scalar, axis=2)
        kk = ck.transpose(0, 2, 1, 3)  # [B,S,K,h]
        vv = cv.transpose(0, 2, 1, 3)
        out = nn.attention(q, kk, vv, pos_q, pos_k, causal=True, window=w,
                           chunk_q=2048, softcap=0.0)
        hh = hh + nn.attn_output(out, lp["attn"], cfg.use_bias)
        x = nn.apply_norm(cfg, hh, lp["mlp_norm"])
        hh = hh + nn.mlp(x, lp["mlp"], cfg)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
        return (hh, ck_all, cv_all), None

    (h, new_k, new_v), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"]),
        (params["blocks"], windows, jnp.arange(L)))
    h = nn.apply_norm(cfg, h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h[:, 0, :], unembed(cfg, params))
    return logits.astype(jnp.float32), {"k": new_k, "v": new_v}
