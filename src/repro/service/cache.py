"""Persistent compiled-runner cache — the ROADMAP "sweep-group runner
cache" item, closed.

Before this module, every `run_sweep` call rebuilt its jitted group runners
from fresh closures: the closure captured `X`/`y` and a new function object
per call, which defeats JAX's jit cache, so a service re-running the same
grid paid full XLA recompilation per call — the regime the paper's
"compute cost per effective pass" framing targets. The group bodies now
close over hashable statics only (`repro.core.sweep._group_fn`; data and
row arrays enter as runtime arguments) and THIS module owns the one place
they are jitted: a module-level dict keyed on everything that determines
the compiled program —

    (engine, M̃, option, buf_len, epochs-bound, drop_prob,
     mesh fingerprint, objective static key, data shapes + dtypes)

A repeated same-shape sweep — direct `run_sweep` or through the
`repro.service.api.SweepService` — fetches the SAME jitted callable and
compiles nothing. Compiles are counted by a wrapper that increments a
counter at TRACE time (the Python body only runs when jit traces), which is
version-independent and exactly counts (re)compilations; hit/miss counters
cover the cache itself. `tests/test_service.py` pins the regression: a
second same-shape sweep performs zero new traces.

The cache is process-global on purpose — many logical clients / services
in one process (the multi-tenant sweep server) share compiled programs —
and LRU-BOUNDED (`set_cache_limit`, default 64 runners) so tenants rotating
through shapes cannot grow the executable set without bound. `clear_cache()`
exists for tests and for dropping device buffers referenced by cached
executables.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.core import sweep as _sweep
from repro.obs import ledger as _ledger
from repro.obs.trace import tracer as _tracer
from repro.sharding.context import mesh_fingerprint


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Snapshot of the runner cache counters (monotonic since process start
    or the last `clear_cache(reset_stats=True)`)."""
    hits: int = 0
    misses: int = 0
    compiles: int = 0

    @property
    def hit_rate(self) -> float:
        lookups = self.hits + self.misses
        return self.hits / lookups if lookups else 0.0

    def since(self, base: "CacheStats") -> "CacheStats":
        """Counter deltas relative to an earlier snapshot."""
        return CacheStats(hits=self.hits - base.hits,
                          misses=self.misses - base.misses,
                          compiles=self.compiles - base.compiles)


class _Counters:
    def __init__(self):
        self.hits = 0
        self.misses = 0
        self.compiles = 0

    def snapshot(self) -> CacheStats:
        return CacheStats(hits=self.hits, misses=self.misses,
                          compiles=self.compiles)


# Per-lookup scoped attribution: a caller (one `SweepService` dispatch
# window) installs a private _Counters sink on ITS thread; every lookup —
# and every trace-time compile, which happens while the runner is called
# on the same thread — credits the sink in addition to the globals. Unlike
# the old absorb-the-global-delta-around-a-window scheme, two services
# flushing CONCURRENTLY cannot pollute each other's counters: each thread
# only feeds its own sink.
_TLS = threading.local()


@contextlib.contextmanager
def scoped_counters(sink: _Counters):
    """Credit this thread's cache lookups/compiles to ``sink`` (nests:
    the previous sink is restored on exit; only the innermost one counts)."""
    prev = getattr(_TLS, "sink", None)
    _TLS.sink = sink
    try:
        yield sink
    finally:
        _TLS.sink = prev


@contextlib.contextmanager
def uncounted_trace():
    """Suspend compile counting on this thread: a re-trace forced for
    bookkeeping (the ledger's one-time AOT ``cost_analysis`` of an
    already-compiled runner) is not a user-visible (re)compile, and must
    not perturb the exact-compile-count contracts (`tests/test_service.py`,
    the obs-smoke 0-recompiles gate)."""
    prev = getattr(_TLS, "uncounted", False)
    _TLS.uncounted = True
    try:
        yield
    finally:
        _TLS.uncounted = prev


def _credit(field: str) -> None:
    """Bump one counter on the globals and the thread's scoped sink (if
    any). Caller holds _LOCK; the sink is thread-private so the same lock
    suffices."""
    setattr(_COUNTERS, field, getattr(_COUNTERS, field) + 1)
    sink = getattr(_TLS, "sink", None)
    if sink is not None:
        setattr(sink, field, getattr(sink, field) + 1)


_LOCK = threading.Lock()
_RUNNERS: "OrderedDict[tuple, object]" = OrderedDict()
_COUNTERS = _Counters()
# LRU bound: a long-lived multi-tenant service must not accumulate XLA
# executables forever as tenants rotate through shapes. 64 runners is an
# order of magnitude above any one workload's live set (a grid is a few
# groups; a tenant fleet a few dozen); callers holding an evicted runner
# keep using it — eviction only drops the SHARED reference.
_MAX_RUNNERS = 64

_RunnerKey = Tuple  # (engine, M̃, option, buf_len, epochs, drop_prob,
#                     mesh fingerprint, objective static key,
#                     per-data-leaf (shape, dtype), fused kernel mode)


def _fused_mode_key(fused: bool) -> Optional[str]:
    """The cache-key facet for the engine body: None for the vmap path,
    else the RESOLVED megakernel mode ('interpret' | 'compiled'). Resolving
    at key time means flipping ``REPRO_KERNEL_MODE`` mid-process can never
    serve a runner built for the other lowering."""
    if not fused:
        return None
    from repro.kernels.dispatch import fused_sweep_mode
    return fused_sweep_mode()


def runner_key(engine: str, *, group_epochs: int, total: int, option: int,
               buf_len: int, drop_prob: float, mesh: Optional[Mesh],
               obj, fused: bool = False) -> _RunnerKey:
    """Everything that determines the compiled program. The objective's data
    enters the runner as arguments, so only its SHAPES/DTYPES are keyed
    (plus `obj.runner_static_key()`, the static config its pure methods
    close over) — two tenants sweeping same-shape datasets of one objective
    class share one compiled program."""
    data_sig = tuple((tuple(a.shape), str(jax.numpy.asarray(a).dtype))
                     for a in obj.data_args())
    return (engine, int(total), int(option), int(buf_len), int(group_epochs),
            float(drop_prob), mesh_fingerprint(mesh),
            obj.runner_static_key(), data_sig, _fused_mode_key(fused))


def _counted(fn):
    """Increment the compile counter at trace time: the wrapper body runs
    exactly once per jit (re)trace, never on a cached execution. Tracing
    happens when the cached runner is CALLED (no lock held), so taking
    _LOCK here cannot deadlock with `get_group_runner`."""
    def traced(*args):
        if getattr(_TLS, "uncounted", False):
            return fn(*args)
        with _LOCK:
            _credit("compiles")
        # trace-time host Python on the dispatching thread: the open
        # dispatch/execute span group (if any) gets the attribution; the
        # tracer's lock is a leaf, so holding no cache lock here matters
        _tracer().annotate(compiled=True)
        # same-thread hook: lets the performance ledger attribute the
        # wall time of the dispatch in flight to compilation
        _ledger.note_compile()
        return fn(*args)
    return traced


def get_group_runner(engine: str, *, group_epochs: int, total: int,
                     option: int, buf_len: int, drop_prob: float,
                     mesh: Optional[Mesh], obj, fused: bool = False):
    """The jitted runner for one (engine, M̃, option, buf_len, …) group,
    built at most once per key. ``fused=True`` keys and builds the Pallas
    sweep-epoch megakernel body instead of the vmap body.

    The returned callable takes ``(*obj.data_args(), *row_args)`` with
    every row array row-leading; under a mesh it is shard_mapped over the
    `data` axis (data args replicated) before jitting — see
    `repro.core.sweep._shard_group_fn` for the bit-exactness argument. The
    body closes over ``obj``'s pure methods, but the key carries only its
    `runner_static_key()` — any same-key instance's data can run through a
    runner another instance built.
    """
    key = runner_key(engine, group_epochs=group_epochs, total=total,
                     option=option, buf_len=buf_len, drop_prob=drop_prob,
                     mesh=mesh, obj=obj, fused=fused)
    num_data = len(obj.data_args())
    with _LOCK:
        runner = _RUNNERS.get(key)
        if runner is not None:
            _credit("hits")
            _tracer().annotate(cache="hit")
            _RUNNERS.move_to_end(key)            # LRU touch
            return runner
        _credit("misses")
        _tracer().annotate(cache="miss")
        fn, num_row = _sweep._group_fn(engine, obj=obj, num_data=num_data,
                                       epochs=group_epochs,
                                       total=total, buf_len=buf_len,
                                       option=option, drop_prob=drop_prob,
                                       fused=fused)
        if mesh is not None:
            fn = _sweep._shard_group_fn(fn, mesh, num_data, num_row)
        runner = jax.jit(_counted(fn))
        _RUNNERS[key] = runner
        while len(_RUNNERS) > _MAX_RUNNERS:
            _RUNNERS.popitem(last=False)         # evict least recently used
        return runner


def cache_stats() -> CacheStats:
    """Current hit/miss/compile counters (a frozen snapshot)."""
    with _LOCK:
        return CacheStats(hits=_COUNTERS.hits, misses=_COUNTERS.misses,
                          compiles=_COUNTERS.compiles)


def cache_size() -> int:
    with _LOCK:
        return len(_RUNNERS)


def clear_cache(reset_stats: bool = True) -> None:
    """Drop every cached runner (tests; or to release executables)."""
    with _LOCK:
        _RUNNERS.clear()
        if reset_stats:
            _COUNTERS.hits = _COUNTERS.misses = _COUNTERS.compiles = 0


def set_cache_limit(max_runners: int) -> int:
    """Set the LRU bound on cached runners; returns the previous bound.
    Deployments with many concurrent shapes raise it; tests shrink it."""
    global _MAX_RUNNERS
    if max_runners < 1:
        raise ValueError(f"cache limit must be >= 1, got {max_runners}")
    with _LOCK:
        prev, _MAX_RUNNERS = _MAX_RUNNERS, max_runners
        while len(_RUNNERS) > _MAX_RUNNERS:
            _RUNNERS.popitem(last=False)
    return prev
