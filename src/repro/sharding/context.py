"""Trace-time mesh context for activation sharding constraints.

Model code is mesh-agnostic; the launcher (dryrun/train) installs the mesh +
rule table here before tracing, and models call :func:`constrain` with
LOGICAL axis names. Outside a mesh context it is a no-op, so smoke tests on
one CPU device run the identical code path.

The sweep engine reuses the same ambient mesh: `repro.core.sweep.run_sweep`
picks up :func:`current_mesh` (when no explicit ``mesh=`` is passed) and
shards its config-row axis over the mesh's `data` axis — so a launcher that
entered `mesh_context(make_production_mesh())` shards its grids with no
call-site changes.

Key constraints applied (see DESIGN.md §4 and EXPERIMENTS.md §Perf):
  * attention/moe/encdec/vlm residual stream: ("batch", "seq_shard", None)
    — sequence-parallel saved activations (fits 32k prefill / 4k train).
  * ssm/hybrid residual stream: ("batch", None, "model")
    — channel sharding: RG-LRU / selective-scan recurrences are elementwise
    over channels, so the seq-wise scan never crosses devices.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.rules import DEFAULT_RULES, logical_to_pspec

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def mesh_fingerprint(mesh: Optional[Mesh]):
    """Hashable identity of a mesh for cache keying (None for no mesh).

    Two Mesh OBJECTS built over the same devices/axes (e.g. repeated
    `make_sweep_mesh()` calls, or the ambient `mesh_context` mesh vs an
    explicit `mesh=`) fingerprint equal, so the compiled-runner cache in
    `repro.service.cache` shares one entry across them instead of keying on
    object identity.
    """
    if mesh is None:
        return None
    return (tuple(mesh.axis_names),
            tuple(int(s) for s in mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def current_rules():
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules=None):
    prev_mesh = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", DEFAULT_RULES)
    _state.mesh = mesh
    _state.rules = rules or DEFAULT_RULES
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        _state.rules = prev_rules


def constrain(x, logical_axes: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_pspec(x.shape, logical_axes, mesh, current_rules())
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_heads_or_seq(x, head_axis: str = "heads"):
    """Attention q/k/v [B, S, N, h]: shard heads over `model` when the head
    count divides it, else fall back to sequence sharding. Keeps the f32
    score tensors sharded for archs whose head counts (10, 20, 8...) do not
    divide a 16-way TP axis."""
    mesh = current_mesh()
    if mesh is None or x.ndim != 4:
        return x
    rules = current_rules()
    target = rules.get(head_axis)
    target = (target,) if isinstance(target, str) else (target or ())
    size = 1
    for a in target:
        size *= mesh.shape.get(a, 1)
    if size > 1 and x.shape[2] % size == 0:
        return constrain(x, ("batch", None, head_axis, None))
    return constrain(x, ("batch", "seq_shard", None, None))


def constrain_tree(tree, axes_strs):
    """Constrain every leaf by its "a|b|c" axis string (from
    rules.layer_axes_strs). Applied to the SLICED layer params at scan-body
    entry: the primal constraint keeps the forward all-gather per-layer, and
    autodiff mirrors it onto the cotangent — per-layer weight grads become
    reduce-scattered instead of replicated (the +24 GiB/device failure mode
    recorded in EXPERIMENTS.md §Perf)."""
    if current_mesh() is None:
        return tree

    def one(x, s: str):
        axes = tuple(a if a else None for a in s.split("|")) if s else ()
        if len(axes) != x.ndim:
            return x
        return constrain(x, axes)

    return jax.tree.map(one, tree, axes_strs)
