"""Observability suite: flight recorder, Prometheus exposition, telemetry.

Pins the PR's acceptance contracts:

  * TRACE COMPLETENESS — every HTTP request's full span tree (submit →
    plan → coalesce → pad → dispatch → execute → demux → result) is
    retrievable at ``GET /trace?id=...`` using the ``X-Trace-Id`` the
    submit response echoed, with cache hit/miss + engine-mode attribution
    on the dispatch spans.
  * EXPOSITION VALIDITY — ``GET /metrics`` parses as Prometheus text
    format 0.0.4 and the histogram series keep the cumulative-bucket
    invariants (``le="+Inf"`` == ``_count``, buckets non-decreasing).
  * BIT-SAFETY — results with ``SweepSpec.telemetry`` on are bit-identical
    to runs with it off (telemetry is recomputed OUTSIDE jit; the flag is
    deliberately absent from the group key, so on/off share one compiled
    program), and the staleness series match the engines' delay schedule
    in closed form.
  * LIVENESS — ``/healthz`` turns 503 once the flush daemon's heartbeat
    stalls (wedged dispatch) or its thread dies, and recovers to 200.
"""
import dataclasses
import json
import re
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.obs import Histogram, ServiceHistograms, Tracer
from repro.obs import prometheus as obs_prometheus
from repro.obs import telemetry as obs_telemetry
from repro.obs.trace import disable_tracing, enable_tracing, tracer
from repro.server import FlushPolicy, SweepClient, SweepServer
from repro.server.http import result_from_dict, result_to_dict
from repro.service import SweepService


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def _specs(seeds, **over):
    base = dict(scheme="inconsistent", step_size=0.5, tau=3, num_threads=4,
                inner_steps=25)
    base.update(over)
    return [SweepSpec(seed=s, **base) for s in seeds]


# ------------------------------------------------------------------ tracer
def test_tracer_disabled_is_total_noop():
    tr = Tracer()
    assert tr.new_trace() == ""
    with tr.span("", "submit"):
        with tr.span_active("execute"):
            tr.annotate(cache="hit")
    tr.record_error("", RuntimeError("boom"))
    assert tr.recent() == []
    assert tr.get("") is None
    assert tr.last_error() is None


def test_tracer_span_tree_parenting_and_tags():
    tr = Tracer()
    tr.enable()
    tid = tr.new_trace()
    with tr.span(tid, "submit", rows=2):
        with tr.span(tid, "plan", parent_name="submit"):
            pass
    # a later phase can name a CLOSED parent (the flush path does)
    with tr.span_all([tid, "", "t-unknown"], "coalesce",
                     parent_name="submit"):
        # layers that never see trace ids attach to the open group
        with tr.span_active("execute", mode="vmap"):
            tr.annotate(cache="hit")
    dump = tr.get(tid)
    by_name = {s["name"]: s for s in dump["spans"]}
    assert set(by_name) == {"submit", "plan", "coalesce", "execute"}
    assert by_name["submit"]["parent_id"] is None
    assert by_name["plan"]["parent_id"] == by_name["submit"]["span_id"]
    assert by_name["coalesce"]["parent_id"] == by_name["submit"]["span_id"]
    assert by_name["execute"]["parent_id"] == by_name["coalesce"]["span_id"]
    assert by_name["execute"]["tags"] == {"mode": "vmap", "cache": "hit"}
    assert all(s["duration_ms"] is not None for s in dump["spans"])
    assert json.loads(json.dumps(dump)) == dump          # JSON-safe


def test_tracer_bounds_and_last_error_survive_eviction():
    tr = Tracer(max_traces=2, max_spans=3)
    tr.enable()
    t1 = tr.new_trace()
    with tr.span(t1, "submit"):
        pass
    tr.record_error(t1, RuntimeError("boom"))
    t2, t3 = tr.new_trace(), tr.new_trace()
    assert tr.get(t1) is None                 # evicted by the ring buffer
    err = tr.last_error()
    assert err["trace_id"] == t1 and "boom" in err["error"]
    assert [s["name"] for s in err["spans"]] == ["submit", "error"]
    with tr.span(t2, "a"), tr.span(t2, "b"), tr.span(t2, "c"):
        pass
    with tr.span(t2, "d"):                    # over max_spans: dropped
        pass
    assert [s["name"] for s in tr.get(t2)["spans"]] == ["a", "b", "c"]
    assert [r["trace_id"] for r in tr.recent()] == [t3, t2]
    tr.disable(clear=True)
    assert tr.recent() == [] and tr.last_error() is None


def test_service_records_complete_span_chain(obj):
    enable_tracing()
    try:
        svc = SweepService(obj, epochs=2)
        rid = svc.submit(_specs([1, 2]), tenant="team-a")
        svc.flush()
        svc.result(rid)
        tid = svc.trace_id(rid)
        assert tid
        dump = tracer().get(tid)
        names = [s["name"] for s in dump["spans"]]
        # no width policy on a bare service -> no pad span
        assert set(names) == {"submit", "plan", "coalesce", "dispatch",
                              "execute", "demux", "result"}
        by_name = {s["name"]: s for s in dump["spans"]}
        assert by_name["submit"]["tags"]["tenant"] == "team-a"
        assert by_name["submit"]["tags"]["request_id"] == rid
        assert by_name["dispatch"]["tags"]["cache"] in ("hit", "miss")
        assert by_name["execute"]["tags"]["engine_mode"] in ("vmap", "fused")
        # one flush latency + one request latency + rows + pad factor
        for h in svc.histograms.as_dict().values():
            assert h.snapshot()[2] == 1
    finally:
        disable_tracing(clear=True)


def test_untraced_service_mints_no_ids(obj):
    svc = SweepService(obj, epochs=1)
    rid = svc.submit(_specs([3]))
    svc.flush()
    svc.result(rid)
    assert svc.trace_id(rid) == ""


# -------------------------------------------------------------- histograms
def test_histogram_cumulative_bucket_semantics():
    h = Histogram((0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    cumulative, total, count = h.snapshot()
    assert cumulative == [(0.1, 1), (1.0, 2)]
    assert count == 3
    assert total == pytest.approx(5.55)


_PROM_LINE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"\})? '
    r"(-?\d+(\.\d+)?([eE][+-]?\d+)?|NaN|[+-]Inf)$")
_PROM_TYPE = re.compile(r"^# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* histogram$")


def _assert_parses_as_prometheus(text):
    assert text.endswith("\n")
    lines = text.rstrip("\n").split("\n")
    for line in lines:
        if line.startswith("#"):
            assert _PROM_TYPE.match(line), line
        else:
            assert _PROM_LINE.match(line), line
    return lines


def test_prometheus_render_gauges_labels_and_histograms():
    snapshot = {
        "service": {"flushes": 3, "cache_hit_rate": 0.5, "note": "skip-me"},
        "tenants": {"team-a": {"rows_submitted": 128}},
        "daemon": {"last_error": None, "running": True},
    }
    hists = ServiceHistograms()
    hists.flush_latency_seconds.observe(0.004)
    hists.flush_latency_seconds.observe(12.0)
    text = obs_prometheus.render(snapshot, histograms=hists.as_dict())
    lines = _assert_parses_as_prometheus(text)
    assert "repro_service_flushes 3" in lines
    assert "repro_service_cache_hit_rate 0.5" in lines
    assert 'repro_tenants_rows_submitted{tenant="team-a"} 128' in lines
    assert "repro_daemon_running 1" in lines
    assert not any("skip-me" in ln or "note" in ln for ln in lines)
    assert 'repro_flush_latency_seconds_bucket{le="+Inf"} 2' in lines
    assert "repro_flush_latency_seconds_count 2" in lines
    # cumulative buckets are non-decreasing in bound order
    buckets = [int(ln.split()[-1]) for ln in lines
               if ln.startswith("repro_flush_latency_seconds_bucket")]
    assert buckets == sorted(buckets)


# --------------------------------------------------------------- telemetry
def test_fixed_delay_staleness_matches_closed_form(obj):
    """delay_kind="fixed" draws delay d_m = min(m, τ) deterministically, so
    the realized-staleness series has a closed form independent of the
    replay code under test."""
    tau, total, epochs = 3, 100, 3
    specs = _specs([1], delay_kind="fixed", telemetry=True)
    res = run_sweep(obj, epochs, specs)
    tel = res.telemetry
    expect = np.minimum(np.arange(total), tau).astype(np.float64)
    assert tel.rows.tolist() == [True]
    assert tel.staleness_max[0] == tau
    assert tel.staleness_mean[0] == pytest.approx(expect.mean())
    assert tel.staleness_var[0] == pytest.approx(expect.var())
    np.testing.assert_allclose(tel.staleness_per_epoch[0],
                               np.full(epochs, expect.mean()))
    # update-norm and loss-delta come from the returned arrays directly
    w0 = obj.init_flat()
    assert tel.update_norm[0] == pytest.approx(float(np.linalg.norm(
        np.asarray(res.final_w[0], np.float64) - np.asarray(w0, np.float64))))
    hist64 = np.asarray(res.histories[0], np.float64)
    np.testing.assert_allclose(tel.loss_delta[0], hist64[1:] - hist64[:-1])


def test_zero_and_uniform_delay_staleness_properties(obj):
    specs = [SweepSpec(algo="svrg", step_size=0.5, num_threads=1,
                       inner_steps=30, seed=2, telemetry=True),
             SweepSpec(scheme="inconsistent", step_size=0.5, tau=5,
                       num_threads=4, inner_steps=25, seed=3,
                       delay_kind="uniform", telemetry=True),
             SweepSpec(scheme="inconsistent", step_size=0.5, tau=5,
                       num_threads=4, inner_steps=25, seed=4)]
    res = run_sweep(obj, 2, specs)
    tel = res.telemetry
    assert tel.rows.tolist() == [True, True, False]
    # svrg has no stale reads: the whole staleness series is zero
    assert tel.staleness_max[0] == 0 and tel.staleness_mean[0] == 0.0
    # uniform draws are bounded by τ and not degenerate
    assert 0 < tel.staleness_mean[1] < 5
    assert 0 < tel.staleness_max[1] <= 5
    # un-flagged rows carry zeros everywhere
    assert tel.staleness_mean[2] == 0.0 and tel.update_norm[2] == 0.0
    assert not tel.loss_delta[2].any()
    # the replay is deterministic: same seed, same series
    again = run_sweep(obj, 2, specs).telemetry
    for name in tel._fields:
        np.testing.assert_array_equal(getattr(tel, name),
                                      getattr(again, name))


def test_telemetry_flag_never_changes_bits(obj):
    """Acceptance: telemetry on/off is bit-identical — the flag is not in
    the group key and the compiled program never sees it."""
    specs_on = _specs([5, 6], delay_kind="uniform", telemetry=True)
    specs_off = [dataclasses.replace(s, telemetry=False) for s in specs_on]
    on, off = run_sweep(obj, 3, specs_on), run_sweep(obj, 3, specs_off)
    np.testing.assert_array_equal(on.histories, off.histories)
    np.testing.assert_array_equal(on.final_w, off.final_w)
    np.testing.assert_array_equal(on.effective_passes, off.effective_passes)
    np.testing.assert_array_equal(on.total_updates, off.total_updates)
    assert off.telemetry is None and on.telemetry is not None


def test_telemetry_round_trips_through_wire_codec(obj):
    res = run_sweep(obj, 2, _specs([7], delay_kind="fixed", telemetry=True))
    payload = json.loads(json.dumps(result_to_dict(0, res)))
    back = result_from_dict(payload)
    for name in res.telemetry._fields:
        got, want = getattr(back.telemetry, name), getattr(res.telemetry,
                                                           name)
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)
    # absent telemetry stays absent
    plain = run_sweep(obj, 2, _specs([7], delay_kind="fixed"))
    assert result_from_dict(
        json.loads(json.dumps(result_to_dict(0, plain)))).telemetry is None


# ------------------------------------------------------------------- HTTP
@pytest.fixture()
def traced_server(obj):
    enable_tracing()
    svc = SweepService(obj, epochs=1, max_results=8)
    server = SweepServer(svc, policy=FlushPolicy(max_rows=64,
                                                 max_delay_ms=20)).start()
    try:
        yield svc, server, SweepClient(server.url, poll_s=5.0)
    finally:
        server.stop()
        disable_tracing(clear=True)


def test_http_request_has_complete_retrievable_span_tree(traced_server, obj):
    svc, server, client = traced_server
    body = json.dumps({
        "specs": [dataclasses.asdict(s) for s in _specs([1, 2])],
        "tenant": "team-a"}).encode()
    req = urllib.request.Request(server.url + "/submit", data=body,
                                 method="POST",
                                 headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=10) as resp:
        payload = json.loads(resp.read().decode())
        header_tid = resp.getheader("X-Trace-Id")
    assert payload["trace_id"] == header_tid and header_tid
    client.result(payload["request_id"], timeout=30)
    dump = client.trace(header_tid)
    names = {s["name"] for s in dump["spans"]}
    # the daemon installs a width policy, so the pad phase appears too
    assert names == {"submit", "plan", "coalesce", "pad", "dispatch",
                     "execute", "demux", "result"}
    recent = client.trace()
    assert recent["enabled"] is True
    assert header_tid in [t["trace_id"] for t in recent["recent"]]
    with pytest.raises(Exception):          # unknown id -> 404
        client.trace("t-nope")


def test_http_metrics_endpoint_is_valid_prometheus(traced_server, obj):
    svc, server, client = traced_server
    rid = client.submit(_specs([3]), tenant="team-b")
    client.result(rid, timeout=30)
    req = urllib.request.Request(server.url + "/metrics")
    with urllib.request.urlopen(req, timeout=10) as resp:
        ctype = resp.getheader("Content-Type")
        text = resp.read().decode()
    assert "version=0.0.4" in ctype
    lines = _assert_parses_as_prometheus(text)
    joined = "\n".join(lines)
    assert "repro_service_flushes " in joined
    assert "repro_queue_depth_requests " in joined
    assert 'repro_tenants_rows_completed{tenant="team-b"} 1' in lines
    assert "repro_daemon_heartbeat_age_s " in joined
    # histogram invariant: +Inf bucket equals _count, per series
    for name in ("repro_flush_latency_seconds", "repro_request_latency_seconds",
                 "repro_rows_per_flush", "repro_pad_factor"):
        inf = [ln for ln in lines if ln.startswith(f'{name}_bucket{{le="+Inf"}}')]
        count = [ln for ln in lines if ln.startswith(f"{name}_count")]
        assert len(inf) == 1 and len(count) == 1
        assert inf[0].split()[-1] == count[0].split()[-1]
    _assert_parses_as_prometheus(client.metrics())


def test_healthz_reports_stalled_daemon(obj):
    """/healthz flips to 503 while the flush thread is wedged inside a
    dispatch (heartbeat older than the policy bound) and recovers after."""
    svc = SweepService(obj, epochs=1)
    release = threading.Event()
    real_flush = svc.flush

    def wedged_flush(selector=None):
        release.wait(timeout=10.0)
        return real_flush(selector)

    server = SweepServer(svc, policy=FlushPolicy(
        max_rows=1, max_delay_ms=5, heartbeat_stall_s=0.4)).start()
    client = SweepClient(server.url, poll_s=2.0)
    try:
        assert client.healthz()["status"] == "ok"
        svc.flush = wedged_flush
        client.submit(_specs([9]))            # size trigger -> wedged flush
        deadline = time.monotonic() + 5.0
        status, payload = 200, {}
        while time.monotonic() < deadline:
            try:
                payload = client.healthz()
                status = 200
            except Exception as e:            # ServerError carries payload
                status, payload = e.status, e.payload
                break
            time.sleep(0.05)
        assert status == 503, payload
        assert payload["status"] == "stalled"
        assert payload["heartbeat_age_s"] > 0.4
        release.set()
        svc.flush = real_flush
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            try:
                assert client.healthz()["status"] == "ok"
                break
            except Exception:
                time.sleep(0.05)
        else:
            pytest.fail("healthz never recovered after the wedge released")
    finally:
        release.set()
        svc.flush = real_flush
        server.stop()


def test_healthz_reports_dead_daemon_thread(obj):
    svc = SweepService(obj, epochs=1)
    server = SweepServer(svc, policy=FlushPolicy(max_delay_ms=10)).start()
    client = SweepClient(server.url)
    try:
        assert client.healthz()["daemon_running"] is True
        # kill the flush thread out from under the server: liveness, not
        # just construction, must back daemon_running
        server.daemon._stop.set()
        server.daemon._wake.set()
        server.daemon._thread.join(5.0)
        try:
            payload = client.healthz()
            status = 200
        except Exception as e:
            status, payload = e.status, e.payload
        assert status == 503 and payload["status"] == "stalled"
        assert payload["daemon_running"] is False
    finally:
        server.stop()               # joins the already-dead flush thread
