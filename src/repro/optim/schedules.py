"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def make_schedule(cfg: TrainConfig):
    base = cfg.learning_rate
    warm = max(1, cfg.warmup_steps)
    total = max(cfg.steps, warm + 1)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warmup = base * jnp.minimum(1.0, step / warm)
        if cfg.schedule == "constant":
            return warmup
        frac = jnp.clip((step - warm) / max(1, total - warm), 0.0, 1.0)
        if cfg.schedule == "linear":
            decay = base * (1.0 - frac)
        else:  # cosine
            decay = base * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warm, warmup, decay)

    return schedule
