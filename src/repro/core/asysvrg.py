"""AsySVRG — the paper's contribution, as an exact delay-simulation engine.

The paper's convergence analysis (§4) models the asynchronous execution as a
SERIAL sequence of updates  u_{m+1} = u_m − η v_m  where the gradient inside
v_m was evaluated at a stale view of u whose age lag is bounded by τ. We
implement precisely that semantics as a `lax.scan`, which makes the algorithm
bit-reproducible on any hardware while preserving every property the theory
depends on:

  * consistent reading (§4.1):  v_m = p_{k(m), i_m}; the read is one whole
    buffered iterate u_{k(m)}, with m − k(m) ≤ τ.
  * inconsistent reading (§4.2, Eq. 10):  û_m = P_{g1} u_{a(m)} + P_{g2}
    u_{a(m)+1} — a per-coordinate mixture of two ADJACENT ages.
  * unlock (§5.2):  per-coordinate ages mixed over the whole window
    [a(m), m] AND a write-race model that drops a random fraction of an
    update's coordinates (the paper gives no theory for unlock; this models
    exactly the races removing the locks admits).

The ring buffer holds the last τ+1 iterates; delays come from a pluggable
schedule ("fixed" models p equal-speed threads in round-robin — Assumption 3 —
where a gradient applied at m was read τ = p−1 updates earlier; "uniform"
models speed jitter).

The epoch body (`_epoch_core`) is written to be `vmap`-able over a batch of
(seed, scheme, step-size, τ, delay-kind) configurations — that is what
`repro.core.sweep` compiles into ONE jitted grid run (and, via the `algo`
axis, the same engine also serves serial-SVRG rows as the τ=0 degenerate
case; `repro.core.hogwild` reuses the dispatch-as-data pieces for the
baseline). Two design rules make the batched run BIT-IDENTICAL to the
sequential driver here:

  1. scheme / delay-kind dispatch is data (``lax.switch`` / ``where``), not
     Python control flow, so a config batch shares one trace;
  2. every reduction is either elementwise, a row-reduce over a trailing
     axis, or a fixed-order `lax.scan` accumulation (see
     objective.loss_fixed_order) — the shapes XLA:CPU reduces identically
     with and without a leading batch axis. Plain `X @ w` / `jnp.mean`
     change summation order under vmap and break bitwise equality.

The inner-loop update u − η(g − g0 + gf) routes through the fused
`kernels/svrg_update` op (4 reads + 1 write at peak HBM bandwidth on TPU;
bit-identical jnp reference on other backends).

On p-thread hardware the schemes differ in THROUGHPUT (lock cost), not in
per-update semantics; the benchmark layer (benchmarks/table2_schemes.py)
carries the measured-cost throughput model, while this engine carries the
convergence behaviour. Together they reproduce Tables 2–3 and Figure 1.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import SVRGConfig
from repro.core.objective import LogisticRegression, Objective
from repro.kernels.svrg_update import ops as svrg_update_ops

SCHEME_IDS = {"consistent": 0, "inconsistent": 1, "unlock": 2}
DELAY_IDS = {"zero": 0, "fixed": 1, "uniform": 2}
_UNLOCK = SCHEME_IDS["unlock"]


class AsyRunResult(NamedTuple):
    w: jnp.ndarray
    history: tuple          # objective value after each epoch (incl. epoch 0)
    effective_passes: tuple # cumulative effective passes at each history point
    total_updates: int


def _delay_schedule_core(delay_id, num_updates: int, tau, key) -> jnp.ndarray:
    """Numeric-dispatch delay schedule: 0 ≤ d_m ≤ min(m, τ).

    ``delay_id`` and ``tau`` may be traced scalars (the sweep batches over
    them); ``num_updates`` is static. All three kinds are computed from the
    same key and selected elementwise, so the choice is data, not control
    flow — and τ=0 collapses every kind to the zero schedule.
    """
    m = jnp.arange(num_updates)
    cap = jnp.minimum(m, tau).astype(jnp.int32)
    u = jax.random.uniform(key, (num_updates,))
    uniform = jnp.floor(u * (cap + 1)).astype(jnp.int32)
    zero = jnp.zeros((num_updates,), jnp.int32)
    return jnp.where(delay_id == DELAY_IDS["zero"], zero,
                     jnp.where(delay_id == DELAY_IDS["fixed"], cap, uniform))


def make_delay_schedule(kind: str, num_updates: int, tau: int, key,
                        p: int = 1) -> jnp.ndarray:
    """Delays d_m with 0 ≤ d_m ≤ min(m, τ).

    "fixed":    d_m = min(m, τ)  — p equal-speed round-robin threads
                (thread that applies update m read the iterate τ updates ago).
    "uniform":  d_m ~ U{0..min(m, τ)} — jittered thread speeds.
    "zero":     d_m = 0 — degenerates to sequential SVRG.
    """
    if kind not in DELAY_IDS:
        raise ValueError(f"unknown delay schedule {kind!r}")
    delay_id = DELAY_IDS["zero"] if tau == 0 else DELAY_IDS[kind]
    return _delay_schedule_core(delay_id, num_updates, tau, key)


def _read_consistent(buffer, slot_of, a, m, key, dim):
    """Locked read: one whole iterate of age a."""
    del m, key, dim
    return buffer[slot_of(a)]


def _read_inconsistent(buffer, slot_of, a, m, key, dim):
    """Eq. 10: coordinates mix ages a and a+1 (a+1 capped at m)."""
    ua = buffer[slot_of(a)]
    ub = buffer[slot_of(jnp.minimum(a + 1, m))]
    mask = jax.random.bernoulli(key, 0.5, (dim,))
    return jnp.where(mask, ua, ub)


def _read_unlock(buffer, slot_of, a, m, key, dim):
    """Lock-free read: every coordinate gets an independent age in [a, m]."""
    span = (m - a + 1).astype(jnp.float32)
    ages = a + jnp.floor(jax.random.uniform(key, (dim,)) * span).astype(jnp.int32)
    slots = slot_of(ages)
    return buffer[slots, jnp.arange(dim)]


_READERS = {
    "consistent": _read_consistent,
    "inconsistent": _read_inconsistent,
    "unlock": _read_unlock,
}
# switch branches in SCHEME_IDS order
_READER_LIST = (_read_consistent, _read_inconsistent, _read_unlock)


def read_dispatch(scheme_id, buffer, tau, a, m, key, dim: int):
    """`lax.switch` over the three reading schemes.

    ``scheme_id``/``tau`` may be traced (one trace serves every scheme in a
    sweep batch); ``dim`` is static. The ring-buffer slot arithmetic uses the
    DYNAMIC τ, so a buffer padded to any length ≥ τ+1 reads identically.
    """
    buf_len = tau + 1

    def slot_of(age):
        return jnp.mod(age, buf_len)

    branches = [
        (lambda ops, r=reader: r(ops[0], slot_of, ops[1], ops[2], ops[3], dim))
        for reader in _READER_LIST
    ]
    return jax.lax.switch(scheme_id, branches, (buffer, a, m, key))


def _epoch_core(obj: Objective, data, w, key, eta, tau, scheme_id, delay_id,
                *, total: int, buf_len: int, option: int, drop_prob: float):
    """One outer iteration of Algorithm 1, vmap-able over configurations.

    ``obj`` is any `repro.core.objective.Objective`; only its PURE methods
    (and static config) are used — ``data`` (the `obj.data_args()` tuple)
    carries every numeric input, so this function can close over ``obj``
    inside a cached runner and still serve other same-static-key instances'
    data. ``w`` is the objective's FLAT param vector (pytree objectives
    cross through `repro.utils.tree`'s bit-exact ravel): the delay ring
    buffer, the reader coordinate masks and the fused-kernel update below
    all work on that one vector, unchanged from the logreg-only engine.

    Dynamic (batchable): w, key, eta, tau, scheme_id, delay_id.
    Static (shared by the batch): total = M̃ = pM, buf_len ≥ max τ + 1,
    option, drop_prob.
    """
    n = obj.num_samples(data)
    dim = w.shape[0]
    k_idx, k_delay, k_scan = jax.random.split(key, 3)
    mu = obj.flat_full_grad(data, w)                    # parallel snapshot pass
    u0 = w
    idx = jax.random.randint(k_idx, (total,), 0, n)
    delays = _delay_schedule_core(delay_id, total, tau, k_delay)

    buffer = jnp.tile(u0[None, :], (buf_len, 1))        # slot m%(τ+1) = u_m

    def body(carry, inp):
        u, buffer, acc = carry
        m, i, d, k = inp
        k_read, k_drop = jax.random.split(k)
        a = jnp.maximum(m - d, 0)
        u_read = read_dispatch(scheme_id, buffer, tau, a, m, k_read, dim)
        g = obj.flat_sample_grad(data, i, u_read)
        g0 = obj.flat_sample_grad(data, i, u0)
        gf = mu
        if drop_prob > 0:
            # unlock write-write race: drop a random coordinate fraction.
            # Masking the three inputs with the same 0/1 mask equals masking
            # v = g − g0 + gf (exact for 0/1 factors), which keeps the update
            # expressible as the fused kernel's 4-read form.
            keep = jax.random.bernoulli(
                k_drop, 1.0 - drop_prob, (dim,)).astype(u.dtype)
            mask = jnp.where(scheme_id == _UNLOCK, keep, jnp.ones_like(keep))
            g, g0, gf = g * mask, g0 * mask, gf * mask
        u_next = svrg_update_ops.apply_leaf(u, g, g0, gf, eta)
        buffer = buffer.at[jnp.mod(m + 1, tau + 1)].set(u_next)
        return (u_next, buffer, acc + u_next), None

    keys = jax.random.split(k_scan, total)
    ms = jnp.arange(total)
    (u_last, _, acc), _ = jax.lax.scan(
        body, (u0, buffer, jnp.zeros_like(u0)), (ms, idx, delays, keys))

    return u_last if option == 1 else acc / total


def _asysvrg_epochs_core(obj: Objective, data, w0, key, eta, tau, scheme_id,
                         delay_id, *, epochs: int, total: int, buf_len: int,
                         option: int, drop_prob: float, row_epochs=None):
    """``epochs`` outer AsySVRG iterations as one `lax.scan`, with the
    fixed-order loss recorded after every epoch (index 0 = loss at w0).

    The multi-epoch mirror of `_hogwild_epochs_core`: ``row_epochs`` (a
    dynamic, batchable scalar; default = the static ``epochs`` bound) is
    this config's own budget — past it the row FREEZES (carry passthrough +
    masked loss writes re-emitting the last live loss), so a sweep row with
    a shorter budget is bit-identical to an independent shorter run.

    This is the ONE definition of the per-row epochs scan: the sweep
    engine's vmap path batches it (`repro.core.sweep._asysvrg_group_fn`)
    and the fused Pallas megakernel runs it per grid row
    (`repro.kernels.sweep_epoch`) — both paths execute literally this
    function, which is what makes them bit-identical on XLA:CPU.
    """
    loss0 = obj.flat_loss(data, w0)
    bound = jnp.int32(epochs) if row_epochs is None else row_epochs

    def step(carry, e):
        w, key, loss_prev = carry
        key, sub = jax.random.split(key)
        active = e < bound
        w_new = _epoch_core(
            obj, data, w, sub, eta, tau, scheme_id, delay_id,
            total=total, buf_len=buf_len, option=option,
            drop_prob=drop_prob)
        # frozen rows: carry passthrough + masked loss write (the last
        # live loss is re-emitted), so a row with a shorter budget is
        # bit-identical to an independent shorter run
        w_next = jnp.where(active, w_new, w)
        loss_next = jnp.where(active, obj.flat_loss(data, w_next),
                              loss_prev)
        return (w_next, key, loss_next), loss_next

    (w_fin, _, _), losses = jax.lax.scan(
        step, (w0, key, loss0), jnp.arange(epochs))
    return w_fin, jnp.concatenate([loss0[None], losses])


def _resolve_steps(obj: Objective, cfg: SVRGConfig):
    """(p, M, M̃=pM, clamped τ) from the config — paper §5.1 defaults."""
    p_threads = max(1, cfg.num_threads)
    M = cfg.inner_steps or (2 * obj.n) // p_threads
    total = p_threads * M                               # M̃ = pM
    tau = cfg.tau if cfg.tau else (p_threads - 1)
    tau = max(0, min(tau, total - 1)) if total > 1 else 0
    return p_threads, M, total, tau


def asysvrg_epoch(obj: Objective, w, key, cfg: SVRGConfig,
                  delay_kind: str = "fixed", drop_prob: float = 0.02):
    """One outer iteration of Algorithm 1 under the chosen reading scheme.

    ``w`` may be the objective's param pytree or its flat vector; the
    return matches the flat form. Returns w_{t+1} per cfg.option (1 = final
    iterate, 2 = inner average).
    """
    if cfg.scheme not in SCHEME_IDS:
        raise ValueError(f"unknown scheme {cfg.scheme!r}")
    if delay_kind not in DELAY_IDS:
        raise ValueError(f"unknown delay schedule {delay_kind!r}")
    _, _, total, tau = _resolve_steps(obj, cfg)
    delay_id = DELAY_IDS["zero"] if tau == 0 else DELAY_IDS[delay_kind]
    return _epoch_core(
        obj, obj.data_args(), obj.as_flat(w), key,
        jnp.float32(cfg.step_size), jnp.int32(tau),
        jnp.int32(SCHEME_IDS[cfg.scheme]), jnp.int32(delay_id),
        total=total, buf_len=tau + 1, option=cfg.option, drop_prob=drop_prob)


def run_asysvrg(obj: Objective, epochs: int, cfg: SVRGConfig,
                seed: int = 0, w0=None, delay_kind: str = "fixed",
                drop_prob: float = 0.02) -> AsyRunResult:
    """Multi-epoch driver (one configuration, one jit per call).

    Effective-pass accounting follows §5.1: each epoch visits the dataset 3x
    (1 full-gradient pass + 2n inner visits when M̃ = 2n). The history is
    recorded with the fixed-order loss so `repro.core.sweep` reproduces it
    bit-identically from a single batched compilation. `AsyRunResult.w` is
    the FLAT iterate; pytree objectives unravel it via
    ``obj.unravel_params``.
    """
    w = obj.init_flat() if w0 is None else obj.as_flat(w0)
    key = jax.random.PRNGKey(seed)

    _, _, total, _ = _resolve_steps(obj, cfg)
    # §5.1 accounting: one inner update visits ONE instance; with M̃ = 2n the
    # epoch visits the dataset 3x (1 snapshot pass + 2n inner visits)
    passes_per_epoch = 1.0 + total / obj.n

    data = obj.data_args()
    epoch_fn = jax.jit(lambda w, k: asysvrg_epoch(
        obj, w, k, cfg, delay_kind=delay_kind, drop_prob=drop_prob))
    loss_fn = jax.jit(lambda w: obj.flat_loss(data, w))  # repro-lint: ignore[RL002] sequential reference driver: one obj per process, capture is intentional; the cached-runner path (service/cache) passes data as arguments

    history = [float(loss_fn(w))]
    passes = [0.0]
    for e in range(epochs):
        key, sub = jax.random.split(key)
        w = epoch_fn(w, sub)
        history.append(float(loss_fn(w)))
        passes.append(passes[-1] + passes_per_epoch)
    return AsyRunResult(w=w, history=tuple(history),
                        effective_passes=tuple(passes),
                        total_updates=epochs * total)


def parallel_full_grad(obj: LogisticRegression, w, p_threads: int):
    """The paper's partitioned snapshot pass: thread a computes φ_a over its
    disjoint shard; the sum of partitions equals n·∇f(w) (up to the L2 term).
    Used by tests to verify the partitioned pass is exact."""
    n = obj.n
    base = n // p_threads
    sizes = [base + (1 if a < n % p_threads else 0) for a in range(p_threads)]
    parts = []
    lo = 0
    for sz in sizes:
        parts.append(obj.partial_full_grad(w, lo, sz))
        lo += sz
    return sum(parts) / n + obj.l2 * w
