"""AsySVRG — the paper's contribution, as an exact delay-simulation engine.

The paper's convergence analysis (§4) models the asynchronous execution as a
SERIAL sequence of updates  u_{m+1} = u_m − η v_m  where the gradient inside
v_m was evaluated at a stale view of u whose age lag is bounded by τ. We
implement precisely that semantics as a `lax.scan`, which makes the algorithm
bit-reproducible on any hardware while preserving every property the theory
depends on:

  * consistent reading (§4.1):  v_m = p_{k(m), i_m}; the read is one whole
    buffered iterate u_{k(m)}, with m − k(m) ≤ τ.
  * inconsistent reading (§4.2, Eq. 10):  û_m = P_{g1} u_{a(m)} + P_{g2}
    u_{a(m)+1} — a per-coordinate mixture of two ADJACENT ages.
  * unlock (§5.2):  per-coordinate ages mixed over the whole window
    [a(m), m] AND a write-race model that drops a random fraction of an
    update's coordinates (the paper gives no theory for unlock; this models
    exactly the races removing the locks admits).

The ring buffer holds the last τ+1 iterates; delays come from a pluggable
schedule ("fixed" models p equal-speed threads in round-robin — Assumption 3 —
where a gradient applied at m was read τ = p−1 updates earlier; "uniform"
models speed jitter).

On p-thread hardware the schemes differ in THROUGHPUT (lock cost), not in
per-update semantics; the benchmark layer (benchmarks/table2_schemes.py)
carries the measured-cost throughput model, while this engine carries the
convergence behaviour. Together they reproduce Tables 2–3 and Figure 1.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import SVRGConfig
from repro.core.objective import LogisticRegression


class AsyRunResult(NamedTuple):
    w: jnp.ndarray
    history: tuple          # objective value after each epoch (incl. epoch 0)
    effective_passes: tuple # cumulative effective passes at each history point
    total_updates: int


def make_delay_schedule(kind: str, num_updates: int, tau: int, key,
                        p: int = 1) -> jnp.ndarray:
    """Delays d_m with 0 ≤ d_m ≤ min(m, τ).

    "fixed":    d_m = min(m, τ)  — p equal-speed round-robin threads
                (thread that applies update m read the iterate τ updates ago).
    "uniform":  d_m ~ U{0..min(m, τ)} — jittered thread speeds.
    "zero":     d_m = 0 — degenerates to sequential SVRG.
    """
    m = jnp.arange(num_updates)
    cap = jnp.minimum(m, tau)
    if kind == "zero" or tau == 0:
        return jnp.zeros(num_updates, jnp.int32)
    if kind == "fixed":
        return cap.astype(jnp.int32)
    if kind == "uniform":
        u = jax.random.uniform(key, (num_updates,))
        return jnp.floor(u * (cap + 1)).astype(jnp.int32)
    raise ValueError(f"unknown delay schedule {kind!r}")


def _read_consistent(buffer, slot_of, a, m, key, dim):
    """Locked read: one whole iterate of age a."""
    del m, key, dim
    return buffer[slot_of(a)]


def _read_inconsistent(buffer, slot_of, a, m, key, dim):
    """Eq. 10: coordinates mix ages a and a+1 (a+1 capped at m)."""
    ua = buffer[slot_of(a)]
    ub = buffer[slot_of(jnp.minimum(a + 1, m))]
    mask = jax.random.bernoulli(key, 0.5, (dim,))
    return jnp.where(mask, ua, ub)


def _read_unlock(buffer, slot_of, a, m, key, dim):
    """Lock-free read: every coordinate gets an independent age in [a, m]."""
    span = (m - a + 1).astype(jnp.float32)
    ages = a + jnp.floor(jax.random.uniform(key, (dim,)) * span).astype(jnp.int32)
    slots = slot_of(ages)
    return buffer[slots, jnp.arange(dim)]


_READERS = {
    "consistent": _read_consistent,
    "inconsistent": _read_inconsistent,
    "unlock": _read_unlock,
}


def asysvrg_epoch(obj: LogisticRegression, w, key, cfg: SVRGConfig,
                  delay_kind: str = "fixed", drop_prob: float = 0.02):
    """One outer iteration of Algorithm 1 under the chosen reading scheme.

    Returns w_{t+1} per cfg.option (1 = final iterate, 2 = inner average).
    """
    scheme = cfg.scheme
    if scheme not in _READERS:
        raise ValueError(f"unknown scheme {scheme!r}")
    reader = _READERS[scheme]

    p_threads = max(1, cfg.num_threads)
    M = cfg.inner_steps or (2 * obj.n) // p_threads
    total = p_threads * M                               # M̃ = pM
    tau = cfg.tau if cfg.tau else (p_threads - 1)
    tau = max(0, min(tau, total - 1)) if total > 1 else 0
    eta = cfg.step_size
    dim = obj.p

    k_idx, k_delay, k_scan = jax.random.split(key, 3)
    mu = obj.full_grad(w)                               # parallel snapshot pass
    u0 = w
    idx = jax.random.randint(k_idx, (total,), 0, obj.n)
    delays = make_delay_schedule(
        "zero" if tau == 0 else delay_kind, total, tau, k_delay)

    buf_len = tau + 1
    buffer = jnp.tile(u0[None, :], (buf_len, 1))        # slot m%buf_len = u_m

    def slot_of(age):
        return jnp.mod(age, buf_len)

    def body(carry, inp):
        u, buffer, acc = carry
        m, i, d, k = inp
        k_read, k_drop = jax.random.split(k)
        a = jnp.maximum(m - d, 0)
        u_read = reader(buffer, slot_of, a, m, k_read, dim)
        v = obj.sample_grad(u_read, i) - obj.sample_grad(u0, i) + mu
        if scheme == "unlock" and drop_prob > 0:
            keep = jax.random.bernoulli(k_drop, 1.0 - drop_prob, (dim,))
            v = v * keep                                # write-write race
        u_next = u - eta * v
        buffer = buffer.at[slot_of(m + 1)].set(u_next)
        return (u_next, buffer, acc + u_next), None

    keys = jax.random.split(k_scan, total)
    ms = jnp.arange(total)
    (u_last, _, acc), _ = jax.lax.scan(
        body, (u0, buffer, jnp.zeros_like(u0)), (ms, idx, delays, keys))

    return u_last if cfg.option == 1 else acc / total


def run_asysvrg(obj: LogisticRegression, epochs: int, cfg: SVRGConfig,
                seed: int = 0, w0=None, delay_kind: str = "fixed",
                drop_prob: float = 0.02) -> AsyRunResult:
    """Multi-epoch driver. Effective-pass accounting follows §5.1: each epoch
    visits the dataset 3x (1 full-gradient pass + 2n inner visits when
    M̃ = 2n)."""
    w = jnp.zeros(obj.p) if w0 is None else jnp.asarray(w0)
    key = jax.random.PRNGKey(seed)

    p_threads = max(1, cfg.num_threads)
    M = cfg.inner_steps or (2 * obj.n) // p_threads
    total = p_threads * M
    # §5.1 accounting: one inner update visits ONE instance; with M̃ = 2n the
    # epoch visits the dataset 3x (1 snapshot pass + 2n inner visits)
    passes_per_epoch = 1.0 + total / obj.n

    epoch_fn = jax.jit(lambda w, k: asysvrg_epoch(
        obj, w, k, cfg, delay_kind=delay_kind, drop_prob=drop_prob))

    history = [float(obj.loss(w))]
    passes = [0.0]
    for e in range(epochs):
        key, sub = jax.random.split(key)
        w = epoch_fn(w, sub)
        history.append(float(obj.loss(w)))
        passes.append(passes[-1] + passes_per_epoch)
    return AsyRunResult(w=w, history=tuple(history),
                        effective_passes=tuple(passes),
                        total_updates=epochs * total)


def parallel_full_grad(obj: LogisticRegression, w, p_threads: int):
    """The paper's partitioned snapshot pass: thread a computes φ_a over its
    disjoint shard; the sum of partitions equals n·∇f(w) (up to the L2 term).
    Used by tests to verify the partitioned pass is exact."""
    n = obj.n
    base = n // p_threads
    sizes = [base + (1 if a < n % p_threads else 0) for a in range(p_threads)]
    parts = []
    lo = 0
    for sz in sizes:
        parts.append(obj.partial_full_grad(w, lo, sz))
        lo += sz
    return sum(parts) / n + obj.l2 * w
