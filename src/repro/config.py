"""Config system: typed dataclasses for model / shape / mesh / train / serve.

Every architecture in ``repro.configs`` instantiates :class:`ModelConfig`.
Configs are plain frozen dataclasses so they hash (usable as jit static args)
and serialize to/from dicts for checkpoint manifests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Tuple


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Families:

    - ``dense``   decoder-only transformer (GQA, RoPE, optional local/global)
    - ``moe``     dense + mixture-of-experts FFN (shared + routed experts)
    - ``encdec``  encoder-decoder (whisper-style; frontend stubbed)
    - ``vlm``     dense + interleaved cross-attention layers (image stub)
    - ``hybrid``  RG-LRU recurrent blocks + local attention (recurrentgemma)
    - ``ssm``     attention-free Mamba1 selective-SSM stack
    - ``logreg``  the paper's own workload (L2-regularized logistic regression)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention options ---
    rope_theta: float = 10000.0
    rope_style: str = "neox"          # "neox" | "partial" (chatglm 2d) | "none"
    rope_fraction: float = 1.0        # fraction of head_dim rotated
    attn_pattern: str = "global"      # "global" | "local_global" | "local"
    local_window: int = 4096
    global_every: int = 6             # gemma3: 1 global per 6 (5 local : 1 global)
    use_qkv_bias: bool = False
    use_bias: bool = False
    norm: str = "rmsnorm"             # "rmsnorm" | "layernorm"
    activation: str = "silu"          # "silu" | "gelu" | "geglu" | "relu"
    glu: bool = True                  # gated MLP (SwiGLU-style)
    tie_embeddings: bool = False
    logits_softcap: float = 0.0
    qk_norm: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                 # per-expert hidden size
    first_dense_layers: int = 0       # deepseek: layer 0 stays dense
    router_aux_loss: float = 0.001

    # --- encoder-decoder ---
    encoder_layers: int = 0
    encoder_seq: int = 0              # whisper: 1500 frames after conv stub
    encoder_feature_dim: int = 0      # stub input feature dim (mel bins x conv)

    # --- VLM cross-attention ---
    cross_attn_every: int = 0         # insert cross-attn layer every N layers
    num_image_tokens: int = 0
    image_embed_dim: int = 0

    # --- hybrid / SSM ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec","rec","attn") repeated
    lru_width: int = 0                    # RG-LRU width (recurrentgemma)
    ssm_state: int = 0                    # mamba state dim N
    d_conv: int = 4
    expand: int = 2                       # mamba d_inner = expand*d_model
    dt_rank: int = 0                      # mamba dt rank (0 -> ceil(d_model/16))

    # --- logreg (paper workload) ---
    num_features: int = 0
    l2_reg: float = 1e-4

    # --- numerics / compilation ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"               # "none" | "full"
    scan_layers: bool = True

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Input shapes (assigned grid)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPE_GRID: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
HOST_MESH = MeshConfig((1, 1), ("data", "model"))   # CPU smoke tests


# ---------------------------------------------------------------------------
# SVRG / AsySVRG (the paper's technique)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SVRGConfig:
    """AsySVRG knobs (paper Algorithm 1 + our SPMD adaptation).

    scheme:
      "consistent"    locked read+write (paper §4.1)
      "inconsistent"  lock-free read, locked write (paper §4.2, Eq. 10)
      "unlock"        fully lock-free (paper §5.2, AsySVRG-unlock)
    """
    scheme: str = "inconsistent"
    step_size: float = 0.1
    num_threads: int = 8          # p in the paper (simulated workers)
    tau: int = 0                  # bounded delay; 0 -> sequential SVRG
    inner_steps: int = 0          # M per thread; 0 -> 2n/p (paper §5.1)
    option: int = 2               # w_{t+1}: 1 = last iterate, 2 = average
    # SPMD distributed variant
    local_steps: int = 1          # H: reconcile every H inner steps (tau analogue)
    snapshot_every: int = 100     # refresh (w_snap, g_snap) every N steps
    snapshot_batches: int = 8     # reference batches accumulated per snapshot
    compression: str = "none"     # "none" | "topk" | "randk" | "int8"
    compression_k: float = 0.01   # fraction of coordinates kept
    error_feedback: bool = True


# ---------------------------------------------------------------------------
# Training / serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    optimizer: str = "svrg"           # "svrg" | "sgd" | "momentum" | "adamw"
    microbatches: int = 1             # gradient-accumulation splits of the
                                      # global batch (activation peak ~ 1/mb)
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "cosine"          # "constant" | "cosine" | "linear"
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    seed: int = 0
    svrg: SVRGConfig = field(default_factory=SVRGConfig)
    # fault tolerance
    checkpoint_dir: str = ""
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    log_every: int = 10


@dataclass(frozen=True)
class ServeConfig:
    max_decode_steps: int = 32
    temperature: float = 0.0
    kv_cache_dtype: str = "bfloat16"


# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e target) for the roofline model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12       # FLOP/s per chip
    hbm_bandwidth: float = 819e9          # B/s per chip
    ici_bandwidth: float = 50e9           # B/s per link (~ per axis direction)
    hbm_bytes: float = 16e9               # capacity per chip


TPU_V5E = HardwareSpec()
