"""Async sweep serving demo: flush daemon + 3 tenants with mixed
priorities over HTTP, plus a time-sliced giant job.

Four scenes on one server (the paper's logistic-regression workload):

  1. BOOT — `SweepServer` = service + background flush daemon (size /
     deadline `FlushPolicy`, stable batch widths) + stdlib HTTP listener,
     with a `FairShare` admission policy: an *interactive* tenant in a
     high priority class, a weight-2 *batch* tenant, and a weight-1
     *bulk* tenant.
  2. ASYNC SERVING — the three tenants submit concurrently over HTTP and
     just wait on their results: nobody calls flush(); the daemon's
     deadline fires once and serves everyone from ONE coalesced dispatch,
     each result bit-identical to a standalone `run_sweep` (asserted).
  3. WARM PATH — a second wave of same-shape probes: the runner cache +
     width registry serve it with ZERO new compiles.
  4. GIANT JOB — bulk's 3-group grid runs group-by-group through the
     checkpointed ``run_job(max_groups=1)`` lane while interactive's
     small requests keep landing in between (time-slicing: the giant
     cannot starve the queue).

    PYTHONPATH=src python examples/serve_sweeps.py
"""
import threading

import numpy as np

from repro.core import LogisticRegression, SweepSpec, make_grid, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.server import (FairShare, FlushPolicy, SweepClient, SweepServer,
                          snapshot)
from repro.service import SweepService, cache_stats, clear_cache


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.03)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    clear_cache()

    # ---- 1. boot: service + daemon + HTTP listener ----------------------
    fair = FairShare(quantum_rows=8, max_rows_per_flush=32)
    fair.set_tenant("interactive", priority=1)       # drains strictly first
    fair.set_tenant("batch", weight=2.0)             # 2x bulk's fair share
    fair.set_tenant("bulk", weight=1.0)
    svc = SweepService(obj, epochs=3)
    server = SweepServer(svc, policy=FlushPolicy(max_rows=24,
                                                 max_delay_ms=30),
                         fairness=fair).start()
    print(f"serving sweeps on {server.url} "
          f"(deadline 30ms, fair-share quanta {fair.quantum_rows} rows)\n")

    # ---- 2. three tenants submit concurrently; the daemon flushes -------
    grids = {
        "interactive": make_grid(schemes=("inconsistent",), seeds=(1,),
                                 step_sizes=(1.0,), taus=(9,),
                                 num_threads=10),
        "batch": make_grid(schemes=("unlock", "consistent"), seeds=(2, 3),
                           step_sizes=(1.0,), taus=(9,), num_threads=10),
        "bulk": make_grid(schemes=("consistent",), seeds=(4,),
                          step_sizes=(0.5, 1.0), taus=(9,),
                          num_threads=10),
    }
    results = {}

    def tenant(name, specs):
        client = SweepClient(server.url)
        rid = client.submit(specs, tenant=name,
                            priority=1 if name == "interactive" else 0)
        results[name] = client.result(rid, timeout=600)

    threads = [threading.Thread(target=tenant, args=item)
               for item in grids.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = svc.stats()
    print(f"3 tenants, {stats.rows_submitted} rows -> {stats.flushes} "
          f"daemon flush(es), {stats.rows_coalesced} rows coalesced "
          "across tenants; nobody called flush()")
    for name, specs in grids.items():
        np.testing.assert_array_equal(results[name].histories,
                                      run_sweep(obj, 3, specs).histories)
    print("every tenant's HTTP result bit-identical to its own "
          "run_sweep\n")

    # ---- 3. warm path: a second wave costs zero compiles ----------------
    base = cache_stats()
    client = SweepClient(server.url)
    rid = client.submit(make_grid(schemes=("inconsistent",), seeds=(9,),
                                  step_sizes=(2.0,), taus=(9,),
                                  num_threads=10), tenant="interactive",
                        priority=1)
    client.result(rid, timeout=600)
    print(f"warm same-shape probe: {cache_stats().since(base).compiles} "
          "new compiles (runner cache + stable widths)\n")

    # ---- 4. giant job time-sliced between flushes -----------------------
    giant = (make_grid(schemes=("unlock",), seeds=(5, 6), step_sizes=(1.0,),
                       taus=(9,), num_threads=10)
             + [SweepSpec(algo="svrg", step_size=1.0, num_threads=1),
                SweepSpec(algo="hogwild", scheme="unlock", step_size=1.0,
                          tau=9, num_threads=10)])
    handle = server.daemon.submit_job(giant, tenant="bulk")
    rid = client.submit(grids["interactive"], tenant="interactive",
                        priority=1)
    client.result(rid, timeout=600)          # lands between job slices
    res = handle.result(timeout=600)
    np.testing.assert_array_equal(res.histories,
                                  run_sweep(obj, 3, giant).histories)
    print(f"bulk's {len(giant)}-row job ran in {handle.slices} "
          "checkpointed slices while interactive kept being served; "
          "job result bit-identical to one run_sweep")

    snap = snapshot(svc, server.daemon, fair)
    print(f"\nmetrics: flush p50/p95 "
          f"{snap['flush_latency']['p50_ms']:.0f}/"
          f"{snap['flush_latency']['p95_ms']:.0f} ms, request p50/p95 "
          f"{snap['request_latency']['p50_ms']:.0f}/"
          f"{snap['request_latency']['p95_ms']:.0f} ms, per-tenant rows "
          f"{ {t: v['rows_completed'] for t, v in snap['tenants'].items()} }")
    server.stop()


if __name__ == "__main__":
    main()
