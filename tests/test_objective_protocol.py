"""Pluggable-objective protocol suite.

Pins the contracts the objective generalization introduces:

  * PRE-REFACTOR REGRESSION — `run_sweep` / `run_svrg` on the paper's
    `LogisticRegression` workload are BIT-IDENTICAL to the engine before
    the protocol refactor: tests/data/sweep_regression_pin.json (all three
    algos through the sweep engine) and svrg_serial_pin.json were captured
    from the pre-protocol code and must reproduce exactly.
  * PYTREE WORKLOADS END-TO-END — the MLP language model and the
    nonconvex-regularized logistic objective run through `run_sweep`, the
    coalescing `SweepService` and the HTTP server with bit-exact demux and
    wire round-trips, and `SweepResult.final_params` rebuilds the pytree
    bit-exactly from the flat row.
  * REGISTRY ADDRESSING — specs naming a registered objective resolve
    identically in-process and over HTTP (service obj=None); one plan
    never mixes objectives; mixed-objective FLUSHES coalesce without ever
    sharing a compiled group.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    LogisticRegression,
    NonconvexLogistic,
    SweepSpec,
    mlp_lm_objective,
    plan_sweep,
    run_svrg,
    run_sweep,
)
from repro.core.objective import register_objective, unregister_objective
from repro.data.libsvm import make_synthetic_libsvm

PIN_DIR = os.path.join(os.path.dirname(__file__), "data")


def _load_pin(name):
    with open(os.path.join(PIN_DIR, name)) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


@pytest.fixture(scope="module")
def mlp():
    return mlp_lm_objective(n=16, vocab_size=16, seq_len=4, d_model=8,
                            d_hidden=8)


@pytest.fixture(scope="module")
def ncv():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return NonconvexLogistic(ds.X, ds.y, lam=1e-3, alpha=10.0)


def _mlp_specs():
    return [SweepSpec(scheme="inconsistent", step_size=0.1, tau=2,
                      num_threads=3, inner_steps=10, seed=0),
            SweepSpec(scheme="unlock", step_size=0.1, tau=2,
                      num_threads=3, inner_steps=10, seed=1),
            SweepSpec(algo="hogwild", scheme="consistent", step_size=0.1,
                      tau=2, num_threads=3, seed=2)]


def _assert_same(got, want):
    np.testing.assert_array_equal(got.histories, want.histories)
    np.testing.assert_array_equal(got.final_w, want.final_w)
    np.testing.assert_array_equal(got.effective_passes,
                                  want.effective_passes)
    np.testing.assert_array_equal(got.total_updates, want.total_updates)
    np.testing.assert_array_equal(got.epochs_per_row, want.epochs_per_row)
    assert got.param_shapes == want.param_shapes


# ------------------------------------------------- pre-refactor regression
def test_logreg_sweep_bit_identical_to_prerefactor_pin(obj):
    """Acceptance: the refactored engine reproduces the PRE-protocol sweep
    engine bit-for-bit on the paper workload — histories, final iterates
    and accounting, across asysvrg/hogwild/svrg and all read schemes."""
    pin = _load_pin("sweep_regression_pin.json")
    assert pin["dataset"] == {"name": "real-sim", "seed": 11,
                              "scale": 0.002, "l2": 1e-3}
    specs = [SweepSpec(**d) for d in pin["specs"]]
    res = run_sweep(obj, pin["epochs"], specs)
    np.testing.assert_array_equal(
        res.histories, np.asarray(pin["histories"], np.float32))
    np.testing.assert_array_equal(
        res.final_w, np.asarray(pin["final_w"], np.float32))
    np.testing.assert_array_equal(
        res.effective_passes, np.asarray(pin["effective_passes"], np.float64))
    np.testing.assert_array_equal(
        res.total_updates, np.asarray(pin["total_updates"], np.int64))
    # the flat-vector objective reports its params as one unnamed leaf and
    # hands the final row back unchanged
    assert res.param_shapes == (("", (obj.p,), "float32"),)
    np.testing.assert_array_equal(res.final_params(0), res.final_w[0])


def test_svrg_serial_bit_identical_to_prerefactor_pin(obj):
    """Satellite: sequential SVRG on the tree-op formulation is bit-equal
    to the pre-protocol flat-vector implementation."""
    pin = _load_pin("svrg_serial_pin.json")
    w, history = run_svrg(obj, 3, 0.3, num_inner=40, option=2, seed=3)
    np.testing.assert_array_equal(np.asarray(w, np.float32),
                                  np.asarray(pin["w"], np.float32))
    np.testing.assert_array_equal(np.asarray(history, np.float32),
                                  np.asarray(pin["history"], np.float32))


# ------------------------------------------------------- plan-time contracts
def test_plan_requires_an_objective():
    specs = [SweepSpec(scheme="consistent", step_size=0.1, tau=2,
                       num_threads=3, inner_steps=10)]
    with pytest.raises(ValueError, match="objective"):
        plan_sweep(None, 1, specs)


def test_plan_rejects_unknown_registered_name(obj):
    specs = [SweepSpec(scheme="consistent", step_size=0.1, tau=2,
                       num_threads=3, inner_steps=10,
                       objective="never-registered")]
    with pytest.raises(KeyError):
        plan_sweep(obj, 1, specs)


def test_plan_rejects_mixed_objectives_in_one_sweep(obj, mlp):
    """One plan = one objective: rows resolving to DIFFERENT objectives in
    a single run_sweep call are a spec error (coalesce multi-objective work
    through the service, which pools by fingerprint instead)."""
    register_objective("proto-test-mlp-mixed", mlp)
    try:
        specs = [SweepSpec(scheme="consistent", step_size=0.1, tau=2,
                           num_threads=3, inner_steps=10),
                 SweepSpec(scheme="consistent", step_size=0.1, tau=2,
                           num_threads=3, inner_steps=10,
                           objective="proto-test-mlp-mixed")]
        with pytest.raises(ValueError, match="objective"):
            plan_sweep(obj, 1, specs)
    finally:
        unregister_objective("proto-test-mlp-mixed")


# --------------------------------------------------- pytree workloads e2e
@pytest.mark.nonconvex
def test_mlp_rows_batch_composition_independent(mlp):
    """A pytree objective inherits the engine's core guarantee: a row's
    bits do not depend on which other rows share its vmapped group."""
    specs = _mlp_specs()
    together = run_sweep(mlp, 2, specs)
    for c, spec in enumerate(specs):
        alone = run_sweep(mlp, 2, [spec])
        np.testing.assert_array_equal(alone.histories[0],
                                      together.histories[c])
        np.testing.assert_array_equal(alone.final_w[0], together.final_w[c])


@pytest.mark.nonconvex
def test_mlp_final_params_rebuild_bit_exact(mlp):
    """`final_params` rebuilds the {embed, norm, w1, b1, w2} dict from the
    flat row bit-exactly, and re-flattening gives the row back."""
    res = run_sweep(mlp, 2, _mlp_specs()[:1])
    params = res.final_params(0)
    assert set(params) == {"embed", "norm", "w1", "b1", "w2"}
    assert params["embed"].shape == (mlp.vocab_size, mlp.d_model)
    np.testing.assert_array_equal(np.asarray(mlp.as_flat(params)),
                                  res.final_w[0])
    # the nonconvex loss actually went somewhere
    assert res.histories[0, -1] < res.histories[0, 0]


@pytest.mark.nonconvex
def test_mlp_through_service_and_http_bit_identical(mlp):
    """Acceptance: the MLP workload end-to-end through the serving tier —
    coalesced service flush AND HTTP wire round-trip — bit-identical to a
    standalone `run_sweep`, pytree param rebuild included."""
    from repro.server import SweepClient, SweepServer
    from repro.service import SweepService

    specs = _mlp_specs()
    want = run_sweep(mlp, 2, specs)

    svc = SweepService(mlp, epochs=2)
    rid_a = svc.submit(specs[:2])
    rid_b = svc.submit(specs[2:])
    svc.flush()
    np.testing.assert_array_equal(svc.result(rid_a).final_w,
                                  want.final_w[:2])
    np.testing.assert_array_equal(svc.result(rid_b).histories,
                                  want.histories[2:])

    with SweepServer(SweepService(mlp, epochs=2)) as server:
        client = SweepClient(server.url)
        rid = client.submit(specs)
        client.flush()
        got = client.result(rid)
    _assert_same(got, want)
    np.testing.assert_array_equal(
        np.asarray(mlp.as_flat(got.final_params(0))), want.final_w[0])


@pytest.mark.nonconvex
def test_nonconvex_registered_objective_over_http(ncv):
    """Acceptance: the nonconvex workload addressed BY NAME through the
    HTTP tier — the service holds no objective (obj=None); specs name a
    registered one and resolve exactly as an in-process run_sweep."""
    from repro.server import SweepClient, SweepServer
    from repro.service import SweepService

    register_objective("proto-test-ncv", ncv)
    try:
        specs = [SweepSpec(scheme="inconsistent", step_size=0.2, tau=2,
                           num_threads=3, inner_steps=10, seed=0,
                           objective="proto-test-ncv"),
                 SweepSpec(algo="hogwild", scheme="consistent",
                           step_size=0.2, tau=2, num_threads=3, seed=1,
                           objective="proto-test-ncv")]
        want = run_sweep(None, 2, specs)
        assert want.histories[0, -1] < want.histories[0, 0]
        with SweepServer(SweepService(None, epochs=2)) as server:
            client = SweepClient(server.url)
            rid = client.submit(specs)
            client.flush()
            got = client.result(rid)
        _assert_same(got, want)
    finally:
        unregister_objective("proto-test-ncv")


@pytest.mark.nonconvex
def test_mixed_objective_flush_coalesces_without_sharing(obj, mlp):
    """One flush holding requests for DIFFERENT objectives: the group key
    leads with the objective fingerprint, so the rows coalesce in one
    dispatch window yet never share a compiled group — and each request
    demuxes bit-identical to its own standalone run_sweep."""
    from repro.service import SweepService, coalesce

    register_objective("proto-test-mlp", mlp)
    try:
        logreg_specs = [SweepSpec(scheme="inconsistent", step_size=0.5,
                                  tau=3, num_threads=4, inner_steps=25,
                                  seed=s) for s in range(2)]
        mlp_specs = [SweepSpec(scheme="inconsistent", step_size=0.1, tau=2,
                               num_threads=3, inner_steps=10, seed=0,
                               objective="proto-test-mlp")]
        svc = SweepService(obj, epochs=2)
        rid_l = svc.submit(logreg_specs)
        rid_m = svc.submit(mlp_specs)
        batch = coalesce(obj, tuple(svc._pending))
        fps = {key[0] for key in batch.groups}
        assert fps == {obj.fingerprint(), mlp.fingerprint()}
        svc.flush()
        _assert_same(svc.result(rid_l), run_sweep(obj, 2, logreg_specs))
        _assert_same(svc.result(rid_m), run_sweep(None, 2, mlp_specs))
    finally:
        unregister_objective("proto-test-mlp")


@pytest.mark.nonconvex
def test_pytree_job_checkpoint_resume_and_foreign_data_guard(mlp, tmp_path):
    """Satellite: checkpoint-resumable jobs work for PYTREE objectives —
    a preempted MLP job resumes bit-identical to one `run_sweep`, and the
    job fingerprint (now `obj.fingerprint()` over arbitrary pytree data)
    rejects a resume against a different objective's data."""
    from repro.checkpoint import Checkpointer
    from repro.core import mlp_lm_objective
    from repro.service import SweepService

    specs = _mlp_specs()
    svc = SweepService(mlp, epochs=2)
    res, done, calls = None, False, 0
    while not done:
        res, done = svc.run_job(specs,
                                checkpointer=Checkpointer(str(tmp_path)),
                                max_groups=1)
        calls += 1
        assert calls < 10
    assert calls >= 2                          # >=2 groups -> a real resume
    _assert_same(res, run_sweep(mlp, 2, specs))

    other = mlp_lm_objective(n=16, vocab_size=16, seq_len=4, d_model=8,
                             d_hidden=8, seed=99)
    svc_b = SweepService(other, epochs=2)
    ckpt = Checkpointer(str(tmp_path / "partial"))
    _, done = svc.run_job(specs, checkpointer=ckpt, max_groups=1)
    assert not done
    with pytest.raises(ValueError, match="different job"):
        svc_b.run_job(specs, checkpointer=Checkpointer(
            str(tmp_path / "partial")))


# -------------------------------------------- cross-process determinism
_DIGEST_CHILD = r"""
import os, sys, zlib
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
from repro.data.libsvm import make_synthetic_libsvm
from repro.data.synthetic_lm import SyntheticLMDataset

ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
crc = zlib.crc32(np.ascontiguousarray(np.asarray(ds.X)).tobytes())
crc = zlib.crc32(np.ascontiguousarray(np.asarray(ds.y)).tobytes(), crc)
lm = SyntheticLMDataset(vocab_size=32, seq_len=8, global_batch=16, seed=7)
for step in (0, 3, 17):
    b = lm.batch_at(step)
    crc = zlib.crc32(np.ascontiguousarray(b["tokens"]).tobytes(), crc)
    crc = zlib.crc32(np.ascontiguousarray(b["targets"]).tobytes(), crc)
print(crc)
"""


def test_datasets_deterministic_across_processes():
    """Satellite: the same (dataset, step) must yield the same bytes in
    EVERY process — `SyntheticLMDataset.batch_at` and the synthetic libsvm
    generator may not depend on per-process state (PYTHONHASHSEED salting
    of `hash(str)` broke exactly this before the zlib.crc32 fix; pinned
    regressions and checkpoint-resume fingerprints rely on it)."""
    digests = set()
    for hashseed in ("0", "1", "random"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed,
                   PYTHONPATH=os.pathsep.join(
                       filter(None, [os.environ.get("PYTHONPATH", ""),
                                     os.path.join(os.path.dirname(PIN_DIR),
                                                  os.pardir, "src")])))
        out = subprocess.run([sys.executable, "-c", _DIGEST_CHILD],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert out.returncode == 0, out.stderr
        digests.add(out.stdout.strip())
    assert len(digests) == 1, f"dataset bytes vary across processes: {digests}"
