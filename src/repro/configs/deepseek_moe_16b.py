"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed, top-6.
[arXiv:2401.06066; hf]

28L, d_model=2048, 16 MHA heads (kv=16), per-expert d_ff=1408,
vocab=102400, first layer dense (d_ff defaults to
moe_d_ff*(top_k + shared) = 1408*8 = 11264 ≈ the published 10944).
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,           # MHA
    head_dim=128,
    d_ff=0,                    # dense layer size derived (see module doc)
    vocab_size=102400,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    norm="rmsnorm",
    activation="silu",
    glu=True,
))
