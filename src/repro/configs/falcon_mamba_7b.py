"""falcon-mamba-7b [ssm] — attention-free Mamba-1.
[arXiv:2410.05355; unverified]

64L, d_model=4096 (d_inner=8192), ssm_state=16, conv=4, dt_rank=256,
vocab=65024. Runs the long_500k cell: O(1) decode state.
The paper's technique (AsySVRG) applies unchanged — it is
architecture-agnostic (see DESIGN.md §5).
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,               # unused (attention-free)
    num_kv_heads=1,
    head_dim=1,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    d_conv=4,
    expand=2,
    dt_rank=256,
    rope_style="none",
    norm="rmsnorm",
))
