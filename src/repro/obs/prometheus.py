"""Prometheus text exposition (format 0.0.4) over the existing snapshot.

`render(snapshot, histograms)` turns the JSON dict that already backs
``GET /stats`` (`repro.server.metrics.snapshot`) into the plain-text
gauge lines a Prometheus scrape expects, plus the cumulative bucket
series for each `repro.obs.metrics.Histogram`. Stdlib-only — no client
library is installed in this container, and none is needed: the format
is lines of ``name{labels} value``.

Mapping rules, applied recursively over the snapshot dict:

  * numeric leaves become gauges named by their dict path:
    ``{"service": {"flushes": 3}}`` -> ``repro_service_flushes 3``;
    booleans render as 0/1;
  * the per-key maps whose KEYS are identifiers, not metric names —
    ``tenants`` and ``fairness.deficits`` — render as labels:
    ``repro_tenants_rows_submitted{tenant="team-a"} 128``;
  * strings / None are skipped (``last_error`` et al. belong in ``/stats``
    and ``/trace``, not in a numeric time series);
  * every value passes through ``float()``/``int()``, so a numpy scalar
    that slipped into the snapshot could never leak its repr into the
    exposition (and the snapshot tests pin that none slips in at all).

Metric names are ``repro_``-prefixed and sanitized to the Prometheus
grammar ``[a-zA-Z_:][a-zA-Z0-9_:]*``.
"""
from __future__ import annotations

import re
from typing import Dict, List, Mapping, Optional

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")
# snapshot subtrees whose keys are arbitrary identifiers -> label name
# ("ledger" keys are per-group runner labels -> repro_ledger_* series;
# its string leaves like flops_source are skipped by _format_value)
_LABELED = {"tenants": "tenant", "deficits": "tenant", "ledger": "group"}


def _metric_name(prefix: str, parts: List[str]) -> str:
    return _NAME_OK.sub("_", "_".join([prefix] + parts))


def _escape_label(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace(
        "\n", "\\n")


def _format_value(value) -> Optional[str]:
    if isinstance(value, bool):
        return "1" if value else "0"
    if isinstance(value, int):
        return str(int(value))
    if isinstance(value, float):
        f = float(value)
        if f != f:
            return "NaN"
        if f in (float("inf"), float("-inf")):
            return "+Inf" if f > 0 else "-Inf"
        return repr(f)
    return None          # strings, None, nested handled by the caller


def _walk(prefix: str, parts: List[str], node, labels: str,
          lines: List[str]) -> None:
    if isinstance(node, Mapping):
        for key, child in node.items():
            key = str(key)
            label_name = _LABELED.get(key)
            if label_name is not None and isinstance(child, Mapping):
                # one level of labeled fan-out: child keys become label
                # values, grandchildren become suffixed metric names
                for ident, sub in child.items():
                    lab = f'{{{label_name}="{_escape_label(str(ident))}"}}'
                    if isinstance(sub, Mapping):
                        for leaf, v in sub.items():
                            val = _format_value(v)
                            if val is not None:
                                name = _metric_name(prefix,
                                                    parts + [key, str(leaf)])
                                lines.append(f"{name}{lab} {val}")
                    else:
                        val = _format_value(sub)
                        if val is not None:
                            name = _metric_name(prefix, parts + [key])
                            lines.append(f"{name}{lab} {val}")
                continue
            _walk(prefix, parts + [key], child, labels, lines)
        return
    val = _format_value(node)
    if val is not None:
        lines.append(f"{_metric_name(prefix, parts)}{labels} {val}")


def render_histogram(name: str, histogram, lines: List[str]) -> None:
    """Classic cumulative exposition: ``_bucket{le=...}``/``_sum``/
    ``_count``, with the mandatory ``le="+Inf"`` == ``_count`` bucket."""
    cumulative, total, count = histogram.snapshot()
    lines.append(f"# TYPE {name} histogram")
    for bound, c in cumulative:
        le = _format_value(float(bound))
        lines.append(f'{name}_bucket{{le="{le}"}} {c}')
    lines.append(f'{name}_bucket{{le="+Inf"}} {count}')
    lines.append(f"{name}_sum {repr(float(total))}")
    lines.append(f"{name}_count {count}")


def render(snapshot: dict, histograms: Optional[Dict[str, object]] = None,
           prefix: str = "repro") -> str:
    """The full ``/metrics`` payload: every numeric leaf of ``snapshot``
    as a gauge, then each histogram's bucket series. Ends with a trailing
    newline as the exposition format requires."""
    lines: List[str] = []
    _walk(prefix, [], snapshot, "", lines)
    if histograms:
        for name, histogram in sorted(histograms.items()):
            render_histogram(_metric_name(prefix, [name]), histogram,
                             lines)
    return "\n".join(lines) + "\n"
