"""Stdlib-only HTTP front-end over `SweepService` + `ServeDaemon`.

One `ThreadingHTTPServer` (a thread per connection — the service and
daemon below it are already thread-safe) exposing the serving tier:

    POST /submit    {"specs": [...], "epochs"?, "tenant"?, "priority"?}
                    -> {"request_id": N}           (admits; nothing runs)
    GET  /result/N?timeout_s=S
                    -> the request's SweepResult   (blocks until the
                    daemon's size/deadline policy has flushed it — the
                    handler WAITS, it never forces a flush, so a result
                    poll cannot defeat coalescing)
    POST /flush     -> {"completed": [ids]}        (operator escape hatch)
    GET  /stats     -> repro.server.metrics.snapshot(...)
    GET  /metrics   -> the same snapshot as Prometheus text exposition
                    0.0.4, plus the service histograms (flush/request
                    latency, rows-per-flush, pad-factor)
    GET  /trace     -> flight-recorder state: recent traces + the retained
                    last-error dump; ``?id=tNN`` returns one request's
                    full span tree (404 once evicted). Submit/result
                    responses echo the trace id in ``X-Trace-Id``.
    GET  /healthz   -> {"status": "ok", ...}; 503 {"status": "stalled"}
                    when the flush daemon's heartbeat is older than
                    ``FlushPolicy.heartbeat_stall_s`` or its thread died
    GET  /watch?id=job-N&cursor=C&timeout_s=S
                    -> {"events": [...], "cursor": C', "enabled": bool}
                    long-poll on the live-progress bus
                    (`repro.obs.progress`): per-slice loss events while a
                    job/flush is still running. ``cursor`` resumes past
                    the last seen event; omit ``id`` for the firehose
                    (every channel). Empty ``events`` after ``timeout_s``
                    means "nothing new yet" — poll again with the same
                    cursor.
    POST /job       {"specs": [...], "epochs"?, "tenant"?}
                    -> {"job_id": N, "watch_id": "job-N"}  (requires the
                    flush daemon; the job time-slices between flushes and
                    streams per-slice events on its watch channel)
    GET  /job/N?timeout_s=S
                    -> the finished job's SweepResult (504 pending while
                    slices still run — watch /watch?id=job-N meanwhile)
    GET  /ledger    -> {"enabled": bool, "groups": {...}} — the per-group
                    performance ledger (`repro.obs.ledger`): compile
                    time, FLOPs/bytes, attained-vs-roofline fraction per
                    compiled group runner (all zeros/empty until
                    ``enable_ledger()``)

Status mapping: bad input 400; unknown id 404; completed-but-evicted id
410 (`ResultEvictedError` — re-submit or raise ``max_results``); result
not ready within ``timeout_s`` 504 with ``{"status": "pending"}`` (the
client long-polls again). Everything is JSON; numeric payloads round-trip
bit-exactly (Python floats serialize via shortest-round-trip repr, and
float32→float64→float32 is lossless), so an HTTP client's `SweepResult`
is bit-identical to an in-process ``run_sweep`` — pinned by
tests/test_server_http.py, sharded and unsharded.
"""
from __future__ import annotations

import dataclasses
import json
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Tuple
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro.core.sweep import SweepResult, SweepSpec
from repro.obs import ledger as _ledger
from repro.obs import progress as _progress
from repro.obs import prometheus as _prometheus
from repro.obs import telemetry as _obs_telemetry
from repro.obs.trace import tracer as _tracer
from repro.server import metrics as _metrics
from repro.server.daemon import ServeDaemon
from repro.server.fairness import FairShare
from repro.service.api import ResultEvictedError, SweepService

_SPEC_FIELDS = {f.name: f.type for f in dataclasses.fields(SweepSpec)}
_RESULT_PATH = re.compile(r"^/result/(\d+)$")
_JOB_PATH = re.compile(r"^/job/(\d+)$")
# bound server-side result waits so a dead daemon can't pin handler
# threads forever; clients long-poll in increments below this
MAX_WAIT_S = 30.0


# ------------------------------------------------------------- wire codecs
def spec_to_dict(spec: SweepSpec) -> dict:
    return dataclasses.asdict(spec)


def spec_from_dict(payload: dict) -> SweepSpec:
    if not isinstance(payload, dict):
        raise ValueError(f"spec must be an object, got {type(payload).__name__}")
    unknown = set(payload) - set(_SPEC_FIELDS)
    if unknown:
        raise ValueError(f"unknown SweepSpec fields {sorted(unknown)} "
                         f"(valid: {sorted(_SPEC_FIELDS)})")
    return SweepSpec(**payload)


def result_to_dict(request_id: int, res: SweepResult) -> dict:
    """JSON payload for one result. Arrays go as nested lists of Python
    scalars — exact: float32/float64 survive the repr round-trip."""
    return {
        "request_id": request_id,
        "specs": [spec_to_dict(s) for s in res.specs],
        "histories": res.histories.tolist(),
        "effective_passes": res.effective_passes.tolist(),
        "final_w": res.final_w.tolist(),
        "total_updates": res.total_updates.tolist(),
        "epochs_per_row": res.epochs_per_row.tolist(),
        "param_shapes": [list(entry) for entry in res.param_shapes],
        "telemetry": (None if res.telemetry is None
                      else _obs_telemetry.to_dict(res.telemetry)),
        "diverged_rows": (None if res.diverged_rows is None
                          else res.diverged_rows.tolist()),
    }


def result_from_dict(payload: dict) -> SweepResult:
    telemetry = payload.get("telemetry")
    diverged = payload.get("diverged_rows")   # absent on pre-watchdog wires
    return SweepResult(
        specs=tuple(spec_from_dict(s) for s in payload["specs"]),
        histories=np.asarray(payload["histories"], np.float32),
        effective_passes=np.asarray(payload["effective_passes"], np.float64),
        final_w=np.asarray(payload["final_w"], np.float32),
        total_updates=np.asarray(payload["total_updates"], np.int64),
        epochs_per_row=np.asarray(payload["epochs_per_row"], np.int64),
        param_shapes=tuple((path, tuple(shape), dtype) for path, shape, dtype
                           in payload.get("param_shapes", ())),
        telemetry=(None if telemetry is None
                   else _obs_telemetry.from_dict(telemetry)),
        diverged_rows=(None if diverged is None
                       else np.asarray(diverged, np.int64)))


# ---------------------------------------------------------------- handler
class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-sweep-server/1.0"
    protocol_version = "HTTP/1.1"

    def log_message(self, *args):        # quiet: metrics replace the log
        pass

    # `self.server` is the SweepHTTPServer below
    @property
    def svc(self) -> SweepService:
        return self.server.service

    def _json(self, code: int, payload: dict,
              headers: Optional[dict] = None) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for name, value in (headers or {}).items():
            self.send_header(name, value)
        self.end_headers()
        self.wfile.write(body)

    def _text(self, code: int, body: str, content_type: str) -> None:
        data = body.encode()
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _error(self, code: int, message: str, **extra) -> None:
        self._json(code, {"error": message, **extra})

    def _read_body(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        if length == 0:
            return {}
        payload = json.loads(self.rfile.read(length).decode())
        if not isinstance(payload, dict):
            raise ValueError("request body must be a JSON object")
        return payload

    # ------------------------------------------------------------- routes
    def do_GET(self) -> None:          # noqa: N802 (stdlib handler API)
        url = urlparse(self.path)
        m = _RESULT_PATH.match(url.path)
        mj = _JOB_PATH.match(url.path)
        try:
            if url.path == "/healthz":
                self._get_healthz()
            elif url.path == "/watch":
                self._get_watch(url.query)
            elif url.path == "/ledger":
                self._json(200, {"enabled": _ledger.ledger_enabled(),
                                 "groups": _ledger.ledger().snapshot()})
            elif url.path == "/stats":
                self._json(200, _metrics.snapshot(
                    self.svc, self.server.daemon, self.server.fairness))
            elif url.path == "/metrics":
                body = _prometheus.render(
                    _metrics.snapshot(self.svc, self.server.daemon,
                                      self.server.fairness),
                    histograms=self.svc.histograms.as_dict())
                self._text(200, body,
                           "text/plain; version=0.0.4; charset=utf-8")
            elif url.path == "/trace":
                self._get_trace(url.query)
            elif m:
                self._get_result(int(m.group(1)), url.query)
            elif mj:
                self._get_job(int(mj.group(1)), url.query)
            else:
                self._error(404, f"no route {url.path!r}")
        except BrokenPipeError:          # client went away mid-write
            pass
        except Exception as e:           # any other failure must still be
            self._safe_error(e)          # an HTTP answer, not a dropped
        #                                  socket the client can't map

    def _get_healthz(self) -> None:
        daemon = self.server.daemon
        payload = {
            "status": "ok",
            "uptime_s": time.monotonic() - self.server.started_at,
            "pending_requests": self.svc.pending(),
            "daemon_running": daemon is not None and daemon.running(),
        }
        if daemon is None:           # eager-flush deployment: no liveness
            return self._json(200, payload)   # to report beyond "we answered"
        age = daemon.heartbeat_age_s()
        payload["heartbeat_age_s"] = age
        payload["heartbeat_stall_s"] = daemon.policy.heartbeat_stall_s
        if (not daemon.running() or age is None
                or age > daemon.policy.heartbeat_stall_s):
            payload["status"] = "stalled"
            return self._json(503, payload)
        self._json(200, payload)

    def _get_trace(self, query: str) -> None:
        tr = _tracer()
        ids = parse_qs(query).get("id")
        if ids:
            dump = tr.get(ids[0])
            if dump is None:
                return self._error(
                    404, f"unknown trace id {ids[0]!r} (never minted, or "
                    "evicted from the ring buffer)", status="unknown")
            return self._json(200, dump)
        self._json(200, {"enabled": tr.enabled, "recent": tr.recent(),
                         "last_error": tr.last_error()})

    def _get_watch(self, query: str) -> None:
        q = parse_qs(query)
        try:
            cursor = int(q.get("cursor", ["0"])[0])
            timeout = float(q.get("timeout_s", ["10"])[0])
        except ValueError:
            return self._error(400, "cursor must be an int and timeout_s "
                               "a number")
        timeout = max(0.0, min(timeout, MAX_WAIT_S))
        ids = q.get("id")
        watch_id = ids[0] if ids else None    # None = firehose
        bus = _progress.progress_bus()
        events, nxt = bus.watch(cursor=cursor, watch_id=watch_id,
                                timeout=timeout)
        self._json(200, {"events": [e.to_dict() for e in events],
                         "cursor": nxt,
                         "enabled": _progress.progress_enabled()})

    def _get_job(self, job_id: int, query: str) -> None:
        daemon = self.server.daemon
        if daemon is None:
            return self._error(400, "no flush daemon: jobs need a "
                               "policy-driven server (policy=...)")
        try:
            timeout = float(parse_qs(query).get("timeout_s", ["10"])[0])
        except ValueError:
            return self._error(400, "timeout_s must be a number")
        timeout = max(0.0, min(timeout, MAX_WAIT_S))
        try:
            handle = daemon.job(job_id)
        except KeyError:
            return self._error(404, f"unknown job id {job_id} (never "
                               "submitted, or aged out of the handle "
                               "registry)", status="unknown")
        try:
            res = handle.result(timeout=timeout)
        except TimeoutError:
            return self._error(
                504, f"job {job_id} still running after {timeout}s "
                f"({handle.slices} slices so far; stream "
                f"/watch?id=job-{job_id} meanwhile)", status="pending")
        payload = result_to_dict(job_id, res)
        payload["job_id"] = job_id
        self._json(200, payload)

    def _safe_error(self, e: Exception) -> None:
        try:
            self._error(500, f"{type(e).__name__}: {e}")
        except OSError:                  # response already partly written
            pass

    def _get_result(self, rid: int, query: str) -> None:
        try:
            timeout = float(parse_qs(query).get("timeout_s", ["10"])[0])
        except ValueError:
            return self._error(400, "timeout_s must be a number")
        timeout = max(0.0, min(timeout, MAX_WAIT_S))
        try:
            res = self.svc.wait_result(rid, timeout=timeout)
        except ResultEvictedError as e:
            return self._error(410, str(e), status="evicted")
        except TimeoutError:
            return self._error(504, f"request {rid} still pending after "
                               f"{timeout}s (the flush daemon will run it;"
                               " poll again)", status="pending")
        except KeyError:
            return self._error(404, f"unknown request id {rid}",
                               status="unknown")
        tid = self.svc.trace_id(rid)
        self._json(200, result_to_dict(rid, res),
                   {"X-Trace-Id": tid} if tid else None)

    def do_POST(self) -> None:         # noqa: N802 (stdlib handler API)
        url = urlparse(self.path)
        try:
            if url.path == "/submit":
                self._post_submit()
            elif url.path == "/job":
                self._post_job()
            elif url.path == "/flush":
                if self.server.daemon is not None:
                    done = self.server.daemon.flush_now()
                else:
                    # no daemon: still honour a configured fair-share
                    # policy rather than draining in arrival order
                    fair = self.server.fairness
                    done = self.svc.flush(
                        fair.select if fair is not None else None)
                self._json(200, {"completed": done})
            else:
                self._error(404, f"no route {url.path!r}")
        except BrokenPipeError:
            pass
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            self._error(400, str(e))
        except Exception as e:           # e.g. a dispatch error from /flush
            self._safe_error(e)          # (requests re-queued service-side)

    def _post_submit(self) -> None:
        payload = self._read_body()
        specs_raw = payload.get("specs")
        if not isinstance(specs_raw, list) or not specs_raw:
            raise ValueError('"specs" must be a non-empty list of spec '
                             "objects")
        specs = [spec_from_dict(s) for s in specs_raw]
        epochs = payload.get("epochs")
        if epochs is not None:
            epochs = int(epochs)
        rid = self.svc.submit(
            specs, epochs, tenant=str(payload.get("tenant", "default")),
            priority=int(payload.get("priority", 0)))
        tid = self.svc.trace_id(rid)
        self._json(200, {"request_id": rid, "trace_id": tid},
                   {"X-Trace-Id": tid} if tid else None)

    def _post_job(self) -> None:
        if self.server.daemon is None:
            return self._error(400, "no flush daemon: jobs need a "
                               "policy-driven server (policy=...)")
        payload = self._read_body()
        specs_raw = payload.get("specs")
        if not isinstance(specs_raw, list) or not specs_raw:
            raise ValueError('"specs" must be a non-empty list of spec '
                             "objects")
        specs = [spec_from_dict(s) for s in specs_raw]
        epochs = payload.get("epochs")
        if epochs is not None:
            epochs = int(epochs)
        handle = self.server.daemon.submit_job(
            specs, epochs, tenant=str(payload.get("tenant", "default")))
        # watch_id matches the progress channel run_job publishes on for
        # daemon-sliced jobs (daemon passes progress_id=f"job-{id}")
        self._json(200, {"job_id": handle.job_id,
                         "watch_id": f"job-{handle.job_id}"})


# ----------------------------------------------------------------- server
class SweepHTTPServer(ThreadingHTTPServer):
    daemon_threads = True            # handler threads die with the process
    # a handler thread blocked in wait_result holds no lock that accept()
    # needs, so threading + blocking waits coexist

    def __init__(self, address: Tuple[str, int], service: SweepService,
                 daemon: Optional[ServeDaemon],
                 fairness: Optional[FairShare]):
        super().__init__(address, _Handler)
        self.service = service
        self.daemon = daemon
        self.fairness = fairness
        self.started_at = time.monotonic()


class SweepServer:
    """Bundle of service + flush daemon + HTTP listener with one lifecycle.

        server = SweepServer(svc, policy=FlushPolicy(max_delay_ms=25))
        server.start()                       # daemon thread + HTTP thread
        ... SweepClient(server.url) ...
        server.stop()                        # drains the queue first

    ``port=0`` binds an ephemeral port (tests); ``daemon=None`` with
    ``policy=None`` serves without a background flusher (clients must
    POST /flush — the eager baseline the latency benchmark compares).
    """

    def __init__(self, service: SweepService, *,
                 policy=None, fairness: Optional[FairShare] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self.service = service
        self.fairness = fairness
        self.daemon = (ServeDaemon(service, policy, fairness=fairness)
                       if policy is not None else None)
        self._http = SweepHTTPServer((host, port), service, self.daemon,
                                     fairness)
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> Tuple[str, int]:
        return self._http.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def start(self) -> "SweepServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self.daemon is not None:
            self.daemon.start()
        self._thread = threading.Thread(target=self._http.serve_forever,
                                        daemon=True,
                                        name="sweep-http-server")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._http.shutdown()        # stop accepting, then drain the daemon
        self._thread.join(30.0)
        self._thread = None
        self._http.server_close()
        if self.daemon is not None:
            self.daemon.stop(drain=True)

    def __enter__(self) -> "SweepServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
