from repro.optim.optimizers import (
    Optimizer,
    make_optimizer,
    clip_by_global_norm,
)
from repro.optim.schedules import make_schedule

__all__ = ["Optimizer", "make_optimizer", "clip_by_global_norm",
           "make_schedule"]
