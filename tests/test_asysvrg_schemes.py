"""Reading-scheme semantics + delay-schedule invariants (paper §4.1–4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dep (requirements-dev.txt); only the property test needs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.config import SVRGConfig
from repro.core import LogisticRegression, make_delay_schedule, run_asysvrg
from repro.core.asysvrg import (
    _read_consistent, _read_inconsistent, _read_unlock)
from repro.data.libsvm import make_synthetic_libsvm


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("rcv1", seed=2, scale=0.02)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def _check_delay_bounds(num, tau, seed):
    """0 ≤ d_m ≤ min(m, τ) — the paper's bounded-delay requirement."""
    for kind in ("fixed", "uniform", "zero"):
        d = np.asarray(make_delay_schedule(
            kind, num, tau, jax.random.PRNGKey(seed)))
        m = np.arange(num)
        assert (d >= 0).all()
        assert (d <= np.minimum(m, tau)).all()


@pytest.mark.parametrize("num,tau,seed", [(1, 0, 0), (17, 3, 1), (256, 32, 2),
                                          (2000, 8, 3)])
def test_delay_schedule_bounded(num, tau, seed):
    _check_delay_bounds(num, tau, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 2000), st.integers(0, 32), st.integers(0, 10))
    def test_delay_schedule_bounded_property(num, tau, seed):
        _check_delay_bounds(num, tau, seed)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_delay_schedule_bounded_property():
        pass


def _mk_buffer(tau, dim, key):
    # buffer[j] = iterate of age j (distinct constant per age for testing)
    return jnp.tile(jnp.arange(tau + 1, dtype=jnp.float32)[:, None],
                    (1, dim))


def test_consistent_read_is_single_age():
    """Consistent reading returns ONE whole iterate (locked read)."""
    tau, dim = 4, 16
    buf = _mk_buffer(tau, dim, None)
    got = _read_consistent(buf, lambda a: jnp.mod(a, tau + 1),
                           jnp.asarray(2), jnp.asarray(4),
                           jax.random.PRNGKey(0), dim)
    assert len(np.unique(np.asarray(got))) == 1     # all coords same age


def test_inconsistent_read_mixes_two_adjacent_ages():
    """Eq. 10: û mixes coordinates of EXACTLY ages a and a+1."""
    tau, dim = 4, 512
    buf = _mk_buffer(tau, dim, None)
    got = np.asarray(_read_inconsistent(
        buf, lambda a: jnp.mod(a, tau + 1), jnp.asarray(1), jnp.asarray(4),
        jax.random.PRNGKey(1), dim))
    ages = np.unique(got)
    assert set(ages).issubset({1.0, 2.0})
    assert len(ages) == 2    # with 512 coords both ages appear whp


def test_unlock_read_spans_full_window():
    """Unlock: coordinate ages span the whole [a, m] window."""
    tau, dim = 4, 2048
    buf = _mk_buffer(tau, dim, None)
    got = np.asarray(_read_unlock(
        buf, lambda a: jnp.mod(a, tau + 1), jnp.asarray(0), jnp.asarray(4),
        jax.random.PRNGKey(2), dim))
    ages = set(np.unique(got))
    assert ages == {0.0, 1.0, 2.0, 3.0, 4.0}


@pytest.mark.parametrize("delay_kind", ["fixed", "uniform"])
def test_convergence_robust_to_delay_schedule(obj, delay_kind):
    cfg = SVRGConfig(scheme="inconsistent", step_size=2.0, num_threads=8,
                     tau=7)
    res = run_asysvrg(obj, epochs=4, cfg=cfg, seed=5, delay_kind=delay_kind)
    assert res.history[-1] < res.history[0]
    assert all(b <= a * 1.05 for a, b in zip(res.history, res.history[1:]))


def test_larger_tau_never_diverges_smaller_rate(obj):
    """More staleness (larger τ) can slow but must not break convergence
    at a conservative step size (Theorem 1's qualitative content)."""
    gaps = {}
    for tau in (0, 4, 16):
        cfg = SVRGConfig(scheme="consistent", step_size=0.5,
                         num_threads=tau + 1, tau=tau)
        res = run_asysvrg(obj, epochs=3, cfg=cfg, seed=6)
        gaps[tau] = res.history[-1]
    assert gaps[16] < res.history[0]            # still converging
    assert gaps[0] <= gaps[16] * 1.1            # τ=0 at least as good
