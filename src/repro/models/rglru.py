"""Hybrid recurrent family (recurrentgemma-2b / Griffin).

26 layers in the repeating pattern (recurrent, recurrent, local-attention):
8 full groups + 2 trailing recurrent layers. The recurrent block is the
RG-LRU: causal conv(4) → gated linear recurrence
    a_t = exp(−c·softplus(Λ)·r_t),  h_t = a_t⊙h_{t−1} + √(1−a_t²)⊙(i_t⊙x_t)
computed with `lax.associative_scan` over the sequence (channels are
independent → the scan is elementwise, so channel-sharding over the `model`
axis never crosses devices; see sharding/context.py).

Attention layers are MQA (kv=1) with a 2048 local window; decode uses a
RING-BUFFER cache of exactly `local_window` slots — constant memory in
sequence length, which is why this arch runs the long_500k cell.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as nn
from repro.models import transformer as tf
from repro.sharding.context import constrain
from repro.sharding.rules import ParamDef

RG_C = 8.0
# channel sharding over the `model` mesh axis via the "mlp" LOGICAL rule
RESIDUAL_AXES = ("batch", None, "mlp")


def _pattern(cfg: ModelConfig):
    """Returns (num_groups, num_tail_rec). Pattern = (rec, rec, attn)*G + rec*T."""
    L = cfg.num_layers
    G = L // 3
    tail = L - 3 * G
    return G, tail


def _rec_defs(cfg: ModelConfig, L: int, dt: str) -> Dict:
    D, W = cfg.d_model, cfg.lru_width
    nb = max(1, cfg.num_heads)                  # block-diagonal gate blocks
    bs = W // nb
    return {
        "norm": tf._norm_defs((L, D), cfg, dt),
        "w_x": ParamDef((L, D, W), ("layers", "embed", "mlp"), dtype=dt),
        "w_y": ParamDef((L, D, W), ("layers", "embed", "mlp"), dtype=dt),
        "w_out": ParamDef((L, W, D), ("layers", "mlp", "embed"), dtype=dt),
        "conv_w": ParamDef((L, 4, W), ("layers", "conv", "mlp"), "scaled", scale=0.2, dtype=dt),
        "conv_b": ParamDef((L, W), ("layers", "mlp"), "zeros", dtype=dt),
        "gate_r_w": ParamDef((L, nb, bs, bs), ("layers", None, "mlp", None), dtype=dt),
        "gate_r_b": ParamDef((L, W), ("layers", "mlp"), "zeros", dtype=dt),
        "gate_i_w": ParamDef((L, nb, bs, bs), ("layers", None, "mlp", None), dtype=dt),
        "gate_i_b": ParamDef((L, W), ("layers", "mlp"), "zeros", dtype=dt),
        "lam": ParamDef((L, W), ("layers", "mlp"), "ones", dtype=dt),
    }


def _mlp_defs(cfg: ModelConfig, L: int, dt: str) -> Dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "norm": tf._norm_defs((L, D), cfg, dt),
        "w_gate": ParamDef((L, D, F), ("layers", "embed", "mlp"), dtype=dt),
        "w_up": ParamDef((L, D, F), ("layers", "embed", "mlp"), dtype=dt),
        "w_down": ParamDef((L, F, D), ("layers", "mlp", "embed"), dtype=dt),
    }


def param_defs(cfg: ModelConfig) -> Dict:
    dt = cfg.param_dtype
    D, V = cfg.d_model, cfg.vocab_size
    G, T = _pattern(cfg)
    attn_stack = {k: v for k, v in tf.block_param_defs(cfg, G, dt).items()}
    p = {
        "tok_embed": ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt),
        "rec1": {**_rec_defs(cfg, G, dt), "mlp": _mlp_defs(cfg, G, dt)},
        "rec2": {**_rec_defs(cfg, G, dt), "mlp": _mlp_defs(cfg, G, dt)},
        "attn": attn_stack,
        "final_norm": tf._norm_defs((D,), cfg, dt),
    }
    if T > 0:
        p["tail"] = {**_rec_defs(cfg, T, dt), "mlp": _mlp_defs(cfg, T, dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamDef((V, D), ("vocab", None), "embed", scale=0.02, dtype=dt)
    return p


# ---------------------------------------------------------------------------
# RG-LRU block
# ---------------------------------------------------------------------------

def _block_diag(x, w):
    """x [B,S,W], w [nb,bs,bs] block-diagonal matmul."""
    B, S, W = x.shape
    nb = w.shape[0]
    xb = x.reshape(B, S, nb, W // nb)
    return jnp.einsum("bsnk,nkj->bsnj", xb, w).reshape(B, S, W)


def _causal_conv(x, conv_w, conv_b, state=None):
    """Depthwise causal conv, width 4. x [B,S,W], conv_w [4,W].
    state [B,3,W] carries the previous 3 inputs (decode)."""
    if state is None:
        pad = jnp.zeros((x.shape[0], 3, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+3, W]
    S = x.shape[1]
    out = sum(xp[:, j:j + S, :] * conv_w[3 - j] for j in range(4))
    return out + conv_b, xp[:, -3:, :]


CHUNK = 512


def _rg_lru_block(x, gates_r, gates_i, lam, h0):
    """One chunk: x [B,C,W] f32 scan; returns (y, h_last) in f32."""
    r = jax.nn.sigmoid(gates_r.astype(jnp.float32))
    i = jax.nn.sigmoid(gates_i.astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * x.astype(jnp.float32)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    As, Bs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    Bs = Bs + As * h0[:, None, :]
    return Bs, Bs[:, -1, :]


def rg_lru(x, gates_r, gates_i, lam, h0=None):
    """x [B,S,W] -> (y [B,S,W], h_last [B,W]).

    Chunked associative scan (cf. mamba.selective_scan): the full-sequence
    f32 scan tree cost ~50 GiB/device on recurrentgemma train_4k; per-chunk
    scan + sequential chunk carry bounds it to [B, CHUNK, W/16] tensors.
    Channels are independent -> W shards over `model` with no cross-device
    sequential dependency."""
    B, S, W = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)
    chunk = min(CHUNK, S)
    while S % chunk != 0:
        chunk //= 2
    nch = S // chunk
    if nch == 1:
        y, h_last = _rg_lru_block(x, gates_r, gates_i, lam, h0)
        return y.astype(x.dtype), h_last

    def chunk_body(h_prev, inp):
        x_c, gr_c, gi_c = inp
        x_c = constrain(x_c, ("batch", None, "mlp"))
        y, h_last = _rg_lru_block(x_c, gr_c, gi_c, lam, h_prev)
        return h_last, y.astype(x.dtype)

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    rs = lambda t: t.reshape(B, nch, chunk, W).transpose(1, 0, 2, 3)
    h_last, ys = jax.lax.scan(chunk_body, h0,
                              (rs(x), rs(gates_r), rs(gates_i)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, W)
    return y.astype(x.dtype), h_last


def _rec_block(cfg, lp, h, conv_state=None, h0=None):
    """Returns (h_out, (new_conv_state, new_h_state))."""
    x = nn.apply_norm(cfg, h, lp["norm"])
    xb = constrain(jnp.einsum("bsd,dw->bsw", x, lp["w_x"]),
                   ("batch", None, "mlp"))
    yb = jax.nn.gelu(constrain(jnp.einsum("bsd,dw->bsw", x, lp["w_y"]),
                               ("batch", None, "mlp")))
    xb, new_conv = _causal_conv(xb, lp["conv_w"], lp["conv_b"], conv_state)
    gr = _block_diag(xb, lp["gate_r_w"]) + lp["gate_r_b"]
    gi = _block_diag(xb, lp["gate_i_w"]) + lp["gate_i_b"]
    rec, h_last = rg_lru(xb, gr, gi, lp["lam"], h0)
    out = jnp.einsum("bsw,wd->bsd", rec * yb, lp["w_out"])
    h = h + out
    x = nn.apply_norm(cfg, h, lp["mlp"]["norm"])
    gate = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, lp["mlp"]["w_gate"]))
    up = jnp.einsum("bsd,df->bsf", x, lp["mlp"]["w_up"])
    h = h + jnp.einsum("bsf,fd->bsd", gate * up, lp["mlp"]["w_down"])
    return h, (new_conv, h_last)


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def hidden_states(cfg: ModelConfig, params, tokens, collect_state=False):
    B, S = tokens.shape
    G, T = _pattern(cfg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    h = tf.embed_tokens(cfg, params, tokens)

    def body(carry, xs):
        r1, r2, ap = xs
        carry = constrain(carry, RESIDUAL_AXES)
        carry, s1 = _rec_block(cfg, r1, carry)
        carry, s2 = _rec_block(cfg, r2, carry)
        carry, kv = tf.block_apply(cfg, ap, carry, pos, cfg.local_window)
        return constrain(carry, RESIDUAL_AXES), (s1, s2, kv)

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    h, states = jax.lax.scan(
        body, h, (params["rec1"], params["rec2"], params["attn"]))

    tail_states = []
    for t in range(T):
        lp = jax.tree.map(lambda x: x[t], params["tail"])
        h, st = _rec_block(cfg, lp, h)
        tail_states.append(st)
    h = nn.apply_norm(cfg, h, params["final_norm"])
    if collect_state:
        return h, states, tail_states
    return h


def loss_fn(cfg: ModelConfig, params, batch):
    h = hidden_states(cfg, params, batch["tokens"])
    return nn.lm_loss(h, tf.unembed(cfg, params), batch["targets"],
                      batch["mask"], softcap=cfg.logits_softcap)


# ---------------------------------------------------------------------------
# Serving — ring-buffer attention cache + recurrent states
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    G, T = _pattern(cfg)
    W = cfg.lru_width
    K, hd = cfg.num_kv_heads, cfg.head_dim
    win = min(cfg.local_window, seq_len)
    return {
        "conv": ParamDef((2 * G + T, batch, 3, W), ("layers", "batch", None, "mlp"), "zeros", dtype=cfg.dtype),
        "rg_h": ParamDef((2 * G + T, batch, W), ("layers", "batch", "mlp"), "zeros", dtype="float32"),
        "k": ParamDef((G, batch, K, win, hd), ("layers", "batch", "cache_kv", "seq", "head_dim"), "zeros", dtype=cfg.dtype),
        "v": ParamDef((G, batch, K, win, hd), ("layers", "batch", "cache_kv", "seq", "head_dim"), "zeros", dtype=cfg.dtype),
    }


def prefill(cfg: ModelConfig, params, tokens, cache_len: int):
    B, S = tokens.shape
    G, T = _pattern(cfg)
    win = min(cfg.local_window, cache_len)
    h, states, tail_states = hidden_states(cfg, params, tokens,
                                           collect_state=True)
    logits = jnp.einsum("bd,vd->bv", h[:, -1, :], tf.unembed(cfg, params))

    (conv1, rg1), (conv2, rg2), (ks, vs) = states

    # interleave rec1/rec2 per group then append tail
    conv_cache = jnp.concatenate(
        [jnp.stack([conv1, conv2], axis=1).reshape((-1,) + conv1.shape[1:])]
        + [st[0][None] for st in tail_states], axis=0)
    rg_cache = jnp.concatenate(
        [jnp.stack([rg1, rg2], axis=1).reshape((-1,) + rg1.shape[1:])]
        + [st[1][None].astype(jnp.float32) for st in tail_states], axis=0)

    # ring cache: slot j holds the newest position p ≡ j (mod win); compute
    # the slot->position map explicitly (a plain tail slice is only correct
    # when S % win == 0)
    j = jnp.arange(win)
    p_j = (S - 1) - jnp.mod(S - 1 - j, win)          # may be < 0 when S < win
    idx = jnp.maximum(p_j, 0)

    def ring(x):  # [G,B,S,K,h] -> [G,B,K,win,h]
        picked = jnp.take(x, idx, axis=2)
        picked = jnp.where((p_j >= 0)[None, None, :, None, None], picked, 0.0)
        return picked.transpose(0, 1, 3, 2, 4).astype(jnp.dtype(cfg.dtype))

    return logits.astype(jnp.float32), {
        "conv": conv_cache.astype(jnp.dtype(cfg.dtype)),
        "rg_h": rg_cache.astype(jnp.float32),
        "k": ring(ks), "v": ring(vs),
    }


def decode_step(cfg: ModelConfig, params, cache: Dict, tokens, pos_scalar):
    B = tokens.shape[0]
    G, T = _pattern(cfg)
    win = cache["k"].shape[3]
    pos_q = jnp.broadcast_to(pos_scalar[None, None], (B, 1)).astype(jnp.int32)
    slot = jnp.mod(pos_scalar, win)
    # ring-slot positions: p_j = pos - ((pos - j) mod win); p_j < 0 ⇒ empty
    j = jnp.arange(win, dtype=jnp.int32)
    pos_k = pos_scalar - jnp.mod(pos_scalar - j, win)
    pos_k = jnp.broadcast_to(pos_k[None, :], (B, win))
    h = tf.embed_tokens(cfg, params, tokens[:, None])

    conv_g = cache["conv"][:2 * G].reshape((G, 2) + cache["conv"].shape[1:])
    rg_g = cache["rg_h"][:2 * G].reshape((G, 2) + cache["rg_h"].shape[1:])

    def rec_step(lp, hh, conv_st, rg_st):
        hh, (nc, nh) = _rec_block(cfg, lp, hh, conv_state=conv_st, h0=rg_st)
        return hh, nc, nh

    def body(carry, xs):
        r1, r2, ap, cs, rs, ck, cv = xs
        carry, nc1, nh1 = rec_step(r1, carry, cs[0], rs[0])
        carry, nc2, nh2 = rec_step(r2, carry, cs[1], rs[1])
        # local attention against the ring buffer
        x = nn.apply_norm(cfg, carry, ap["attn_norm"])
        q, k, v = nn.gqa_project(x, ap["attn"], cfg, cfg.use_qkv_bias)
        q = nn.apply_rope(q, pos_q, cfg)
        k = nn.apply_rope(k, pos_q, cfg)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.transpose(0, 2, 1, 3).astype(ck.dtype), slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.transpose(0, 2, 1, 3).astype(cv.dtype), slot, axis=2)
        valid_pos = jnp.where(pos_k >= 0, pos_k, jnp.int32(1 << 30))
        out = nn.attention(q, ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
                           pos_q, valid_pos, causal=True,
                           window=cfg.local_window)
        carry = carry + nn.attn_output(out, ap["attn"], cfg.use_bias)
        x = nn.apply_norm(cfg, carry, ap["mlp_norm"])
        carry = carry + nn.mlp(x, ap["mlp"], cfg)
        return carry, (jnp.stack([nc1, nc2]), jnp.stack([nh1, nh2]), ck, cv)

    h, (ncs, nrs, nk, nv) = jax.lax.scan(
        body, h, (params["rec1"], params["rec2"], params["attn"],
                  conv_g, rg_g, cache["k"], cache["v"]))

    new_conv = ncs.reshape((-1,) + ncs.shape[2:])
    new_rg = nrs.reshape((-1,) + nrs.shape[2:])
    for t in range(T):
        lp = jax.tree.map(lambda x: x[t], params["tail"])
        h, nct, nht = rec_step(lp, h, cache["conv"][2 * G + t],
                               cache["rg_h"][2 * G + t])
        new_conv = jnp.concatenate([new_conv, nct[None]], axis=0)
        new_rg = jnp.concatenate([new_rg, nht[None]], axis=0)

    h = nn.apply_norm(cfg, h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h[:, 0, :], tf.unembed(cfg, params))
    return logits.astype(jnp.float32), {
        "conv": new_conv.astype(cache["conv"].dtype),
        "rg_h": new_rg.astype(jnp.float32),
        "k": nk, "v": nv,
    }
