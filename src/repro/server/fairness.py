"""Per-tenant admission + fair-share flush slicing.

The serving queue has the classic multi-tenant failure mode: one tenant's
giant grid lands first, a plain FIFO flush takes the whole queue, and
every other tenant's two-row probe waits behind minutes of someone else's
XLA time. `FairShare` is a flush *selector* (`repro.service.scheduler
.FlushSelector`): each flush takes a bounded, weighted fair slice of the
queue and leaves the rest pending, so successive daemon flushes drain the
queue in fair-share order instead of arrival order.

The accounting is deficit round robin (DRR), the textbook O(1) fair
scheduler, with spec ROWS as the byte-equivalent cost unit (rows are what
a flush dispatches; a request's XLA time is roughly linear in them):

  * every round, each tenant with queued work earns ``quantum_rows × its
    weight`` of row credit (its *deficit* counter);
  * a tenant's FIFO head request is admitted when its credit covers the
    request's rows, and the rows are charged against the credit;
  * credit persists across flushes while the tenant has queued work (and
    resets when its queue drains, per standard DRR), so a GIANT request
    banks credit over several flushes and eventually gets admitted —
    bounded waiting instead of starvation in either direction: small
    tenants keep flowing past the giant, and the giant's turn provably
    arrives after ~rows/(quantum×weight) flushes.

Priority classes sit above the weights: a flush admits strictly from the
highest priority class with queued work before looking at lower ones
(weighted DRR applies WITHIN a class). A request's own ``priority`` tag
wins; tenants can carry a default class in their `TenantPolicy`.

Giant grids that are one single request cannot be split by admission
control (results are per-request atomic) — for those the serving tier
time-slices THROUGH the engine instead, running them group-by-group via
``SweepService.run_job(max_groups=…)`` between flushes (see
`repro.server.daemon.ServeDaemon.submit_job`).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.service.scheduler import SweepRequest


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """One tenant's admission knobs.

    ``weight`` scales the tenant's per-round row credit (2.0 earns twice
    the rows per round of a 1.0 tenant in the same priority class).
    ``priority`` is the tenant's default class for requests that don't tag
    their own (higher drains first).
    """
    weight: float = 1.0
    priority: int = 0

    def __post_init__(self):
        if not self.weight > 0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")


class FairShare:
    """Deficit-round-robin flush selector over tenant-tagged requests.

    ``quantum_rows`` is the per-round credit a weight-1.0 tenant earns;
    ``max_rows_per_flush`` bounds one flush's slice (None = unbounded, in
    which case the selector still orders admission fairly but takes
    everything admissible). The one exception to the bound: if NOTHING has
    been admitted yet and the next request alone exceeds it, that request
    is admitted by itself once its banked credit covers its rows — an
    oversized request gets a dedicated flush rather than waiting forever.

    Instances are thread-safe and meant to be long-lived: the deficit
    counters ARE the fairness state, persisting across flushes.
    """

    def __init__(self, *, quantum_rows: int = 16,
                 max_rows_per_flush: Optional[int] = None,
                 default: TenantPolicy = TenantPolicy()):
        if quantum_rows < 1:
            raise ValueError(f"quantum_rows must be >= 1, got {quantum_rows}")
        if max_rows_per_flush is not None and max_rows_per_flush < 1:
            raise ValueError("max_rows_per_flush must be >= 1 or None, "
                             f"got {max_rows_per_flush}")
        self.quantum_rows = quantum_rows
        self.max_rows_per_flush = max_rows_per_flush
        self._default = default
        self._policies: Dict[str, TenantPolicy] = {}  # guarded-by: _lock
        self._deficit: Dict[str, float] = {}  # guarded-by: _lock
        self._lock = threading.Lock()

    # ------------------------------------------------------------- registry
    def set_tenant(self, name: str, *, weight: Optional[float] = None,
                   priority: Optional[int] = None) -> TenantPolicy:
        """Register / update one tenant's policy; unset fields keep their
        current (or default) value. Unknown tenants get the default policy,
        so registration is optional."""
        with self._lock:
            cur = self._policies.get(name, self._default)
            pol = TenantPolicy(
                weight=cur.weight if weight is None else weight,
                priority=cur.priority if priority is None else priority)
            self._policies[name] = pol
            return pol

    def policy(self, name: str) -> TenantPolicy:
        with self._lock:
            return self._policies.get(name, self._default)

    def deficits(self) -> Dict[str, float]:
        """Current per-tenant banked row credit (the DRR accounting state —
        exposed for the metrics endpoint and the accounting tests)."""
        with self._lock:
            return dict(self._deficit)

    def _class_of(self, req: SweepRequest) -> int:  # holds: _lock
        """A request's own priority tag wins; 0 (the untagged default)
        falls back to the tenant's policy class."""
        if req.priority != 0:
            return req.priority
        return self._policies.get(req.tenant, self._default).priority

    # ------------------------------------------------------------- selector
    def select(self, pending: Sequence[SweepRequest],
               ) -> Tuple[List[SweepRequest], List[SweepRequest]]:
        """Partition the queue into (this flush's slice, still pending).

        Admission order: priority classes high→low; within a class,
        deficit round robin over tenants in first-appearance order, each
        tenant's own requests strictly FIFO.
        """
        with self._lock:
            budget = self.max_rows_per_flush
            take: List[SweepRequest] = []
            taken_rows = 0
            admitted_ids = set()

            by_class: Dict[int, Dict[str, List[SweepRequest]]] = {}
            for req in pending:
                by_class.setdefault(self._class_of(req), {}) \
                    .setdefault(req.tenant, []).append(req)

            for cls in sorted(by_class, reverse=True):
                queues = by_class[cls]
                order = list(queues)             # first-appearance order
                # tenants whose head can no longer fit THIS flush's budget
                # stop earning credit this select (they retry next flush);
                # every loop round either admits a row or blocks a tenant
                # or grows some deficit toward a finite head size, so the
                # rounds terminate
                blocked = set()
                while True:
                    progressed = False
                    for tenant in order:
                        queue = queues[tenant]
                        if not queue or tenant in blocked:
                            continue
                        pol = self._policies.get(tenant, self._default)
                        self._deficit[tenant] = (
                            self._deficit.get(tenant, 0.0)
                            + self.quantum_rows * pol.weight)
                        while queue:
                            head = queue[0]
                            if self._deficit[tenant] < head.rows:
                                break
                            fits = (budget is None
                                    or taken_rows + head.rows <= budget
                                    # oversized escape: alone in its flush
                                    or not take)
                            if not fits:
                                blocked.add(tenant)
                                break
                            queue.pop(0)
                            take.append(head)
                            admitted_ids.add(head.request_id)
                            taken_rows += head.rows
                            self._deficit[tenant] -= head.rows
                            progressed = True
                            if budget is not None and taken_rows >= budget:
                                blocked.update(order)    # budget exhausted
                                break
                        if not queue:
                            # standard DRR: an emptied queue forfeits its
                            # leftover credit (no banking while idle)
                            self._deficit[tenant] = 0.0
                    if not progressed:
                        admissible = [
                            t for t in order
                            if queues[t] and t not in blocked]
                        if not admissible:
                            break
                if budget is not None and taken_rows >= budget:
                    break                        # lower classes wait

            # drop zeroed entries so the deficit map stays bounded by the
            # tenants actually banking credit, not every tag ever seen
            # (tenant strings are arbitrary client input)
            for tenant in [t for t, d in self._deficit.items() if d <= 0.0]:
                del self._deficit[tenant]
            keep = [r for r in pending if r.request_id not in admitted_ids]
            return take, keep

    # a FairShare IS a FlushSelector
    __call__ = select
