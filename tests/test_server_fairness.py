"""Fair-share admission suite: deficit-round-robin accounting.

Pure-Python (no XLA): `FairShare.select` partitions queues of tenant-
tagged `SweepRequest`s; these tests pin the DRR accounting — weighted
quotas, priority classes, per-tenant FIFO order, giant-request behaviour
(bounded waiting in BOTH directions: smalls can't be starved by a giant,
the giant can't be starved by smalls), and the partition property the
service's flush contract relies on.
"""
from collections import Counter

import pytest

from repro.core import SweepSpec
from repro.server.fairness import FairShare, TenantPolicy
from repro.service.scheduler import SweepRequest


def _req(rid: int, tenant: str, rows: int = 1, priority: int = 0):
    return SweepRequest(request_id=rid,
                        specs=tuple(SweepSpec(seed=100 * rid + i)
                                    for i in range(rows)),
                        epochs=1, tenant=tenant, priority=priority)


def _queue(counts, rows=1, priority=None):
    """Interleaved queues: counts = {tenant: n_requests}."""
    out, rid = [], 0
    for i in range(max(counts.values())):
        for tenant, n in counts.items():
            if i < n:
                out.append(_req(rid, tenant, rows,
                                0 if priority is None
                                else priority.get(tenant, 0)))
                rid += 1
    return out


def test_weighted_quotas_drr_accounting():
    """Acceptance: per-flush admitted rows split by tenant weight — the
    deficit-round-robin accounting test. Weight 2 earns twice the rows of
    weight 1 in every slice, and the deficit bookkeeping conserves rows:
    earned = spent + banked."""
    fair = FairShare(quantum_rows=1, max_rows_per_flush=9)
    fair.set_tenant("A", weight=2.0)
    fair.set_tenant("B", weight=1.0)
    pending = _queue({"A": 12, "B": 12})
    shares = []
    while pending:
        take, pending = fair.select(pending)
        assert take, "fair-share made no progress"
        got = Counter(r.tenant for r in take)
        shares.append((got["A"], got["B"]))
    # full slices split 6:3 by the 2:1 weights; the tail drains B's backlog
    assert shares[0] == (6, 3) and shares[1] == (6, 3)
    assert sum(a for a, _ in shares) == 12
    assert sum(b for _, b in shares) == 12
    # each tenant's own requests were served strictly FIFO
    fair2 = FairShare(quantum_rows=1, max_rows_per_flush=9)
    fair2.set_tenant("A", weight=2.0)
    pending, seen = _queue({"A": 12, "B": 12}), {"A": [], "B": []}
    while pending:
        take, pending = fair2.select(pending)
        for r in take:
            seen[r.tenant].append(r.request_id)
    assert seen["A"] == sorted(seen["A"])
    assert seen["B"] == sorted(seen["B"])


def test_priority_classes_drain_strictly_first():
    """A higher priority class is admitted before ANY lower-class rows,
    whatever the weights; classes come from the request tag or the tenant
    default."""
    fair = FairShare(quantum_rows=4, max_rows_per_flush=4)
    fair.set_tenant("bulk", weight=10.0)            # weight can't jump class
    fair.set_tenant("interactive", priority=5)      # tenant-default class
    pending = (_queue({"bulk": 4}) +
               [_req(50, "interactive"), _req(51, "interactive")] +
               [_req(60, "bulk", priority=9)])      # request tag wins
    take, keep = fair.select(pending)
    assert [r.request_id for r in take] == [60, 50, 51, 0]
    assert all(r.tenant == "bulk" for r in keep)


def test_giant_request_cannot_starve_small_tenants():
    """One tenant's giant grid waits (banking credit) while single-row
    tenants keep flowing; the giant then gets a dedicated oversized flush
    — no starvation in either direction."""
    fair = FairShare(quantum_rows=2, max_rows_per_flush=4)
    giant = _req(100, "G", rows=10)
    pending = [giant] + [_req(200 + i, "S") for i in range(6)]
    rounds = []
    while pending:
        take, pending = fair.select(pending)
        assert take, "no progress"
        rounds.append([r.request_id for r in take])
    # smalls drain first, then the giant rides alone (oversized escape)
    assert [100] in rounds
    giant_round = rounds.index([100])
    assert giant_round == len(rounds) - 1
    assert sorted(sum(rounds[:giant_round], [])) == [200 + i
                                                     for i in range(6)]


def test_select_partitions_the_queue():
    fair = FairShare(quantum_rows=1, max_rows_per_flush=3)
    pending = _queue({"A": 5, "B": 5})
    take, keep = fair.select(pending)
    assert sorted(r.request_id for r in take + keep) == \
        sorted(r.request_id for r in pending)
    assert len(take) == 3
    # unbounded budget takes everything (still fair-ordered)
    take_all, keep_all = FairShare(quantum_rows=1).select(pending)
    assert keep_all == [] and len(take_all) == 10


def test_deficit_resets_when_tenant_queue_drains():
    """Standard DRR: an emptied queue forfeits leftover credit (the entry
    is pruned entirely — tenant tags are arbitrary client strings, so the
    accounting map must stay bounded by tenants actively banking credit),
    and an idle tenant can't hoard a burst allowance."""
    fair = FairShare(quantum_rows=8, max_rows_per_flush=None)
    take, keep = fair.select([_req(0, "A")])
    assert [r.request_id for r in take] == [0] and keep == []
    assert "A" not in fair.deficits()
    # a BLOCKED tenant's banked credit does persist across selects
    fair2 = FairShare(quantum_rows=1, max_rows_per_flush=2)
    giant = _req(1, "G", rows=8)
    take, keep = fair2.select([giant, _req(2, "S"), _req(3, "S")])
    assert [r.request_id for r in take] == [2, 3]
    assert fair2.deficits().get("G", 0.0) > 0.0


def test_policy_validation_and_registry():
    with pytest.raises(ValueError):
        TenantPolicy(weight=0.0)
    with pytest.raises(ValueError):
        FairShare(quantum_rows=0)
    with pytest.raises(ValueError):
        FairShare(max_rows_per_flush=0)
    fair = FairShare()
    fair.set_tenant("A", weight=3.0)
    fair.set_tenant("A", priority=2)              # updates keep other fields
    assert fair.policy("A") == TenantPolicy(weight=3.0, priority=2)
    assert fair.policy("unregistered") == TenantPolicy()
