"""Fused Pallas sweep-epoch megakernel: one launch per (group × run)."""
from repro.kernels.sweep_epoch.kernel import sweep_epoch_call
from repro.kernels.sweep_epoch.ops import fused_group_fn

__all__ = ["sweep_epoch_call", "fused_group_fn"]
