"""Vectorized sweep engine vs the sequential driver (bit-exactness contract).

(a) one jitted sweep over the Table-2 grid (3 schemes × 3 seeds × 2 step
    sizes) reproduces each config's loss history AND final iterate
    bit-identically to a per-config `run_asysvrg` call;
(b) the `lax.switch` reader dispatch matches the direct `_READERS` functions
    for all three schemes;
plus grouping across heterogeneous M̃ and delay-schedule dispatch checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LogisticRegression, SweepSpec, make_grid,
                        plan_sweep, run_asysvrg, run_sweep)
from repro.core.asysvrg import (
    DELAY_IDS, SCHEME_IDS, _READERS, _delay_schedule_core,
    make_delay_schedule, read_dispatch)
from repro.data.libsvm import make_synthetic_libsvm


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def _assert_rows_match_sequential(obj, specs, res, epochs):
    for c, spec in enumerate(specs):
        seq = run_asysvrg(obj, epochs, spec.to_config(), seed=spec.seed,
                          delay_kind=spec.delay_kind)
        np.testing.assert_array_equal(
            np.asarray(seq.history, np.float32), res.histories[c],
            err_msg=f"history mismatch for {spec}")
        np.testing.assert_array_equal(
            np.asarray(seq.w, np.float32), res.final_w[c],
            err_msg=f"final iterate mismatch for {spec}")
        assert int(res.total_updates[c]) == seq.total_updates
        np.testing.assert_allclose(res.effective_passes[c],
                                   np.asarray(seq.effective_passes))


def test_sweep_bit_identical_to_sequential_table2_grid(obj):
    """Acceptance: the Table-2 scheme comparison (3 schemes × 3 seeds × 2
    step sizes) from ONE jit matches the per-run Python-loop driver
    bit-for-bit."""
    epochs = 2
    specs = make_grid(schemes=("consistent", "inconsistent", "unlock"),
                      seeds=(0, 1, 2), step_sizes=(0.5, 2.0), taus=(3,),
                      num_threads=4, inner_steps=25)
    res = run_sweep(obj, epochs, specs)
    assert res.histories.shape == (18, epochs + 1)
    _assert_rows_match_sequential(obj, specs, res, epochs)


def test_sweep_groups_heterogeneous_totals(obj):
    """Specs whose M̃ = pM differ compile as separate groups but still land
    bit-identical rows in input order (uniform delays + unlock drop model
    exercised too)."""
    epochs = 2
    specs = [
        SweepSpec(seed=3, scheme="unlock", step_size=1.0, tau=2,
                  num_threads=3, inner_steps=20, delay_kind="uniform"),
        SweepSpec(seed=4, scheme="inconsistent", step_size=0.5, tau=1,
                  num_threads=2, inner_steps=25),
        SweepSpec(seed=5, scheme="consistent", step_size=1.0, tau=0,
                  num_threads=1, inner_steps=40),
    ]
    assert len({3 * 20, 2 * 25, 1 * 40}) == 3   # three distinct M̃ groups
    res = run_sweep(obj, epochs, specs)
    _assert_rows_match_sequential(obj, specs, res, epochs)


def test_read_dispatch_matches_direct_readers():
    """lax.switch dispatch == the _READERS functions, same key, all schemes."""
    tau, dim = 4, 256
    buffer = jnp.tile(jnp.arange(tau + 1, dtype=jnp.float32)[:, None],
                      (1, dim))
    a, m = jnp.asarray(1), jnp.asarray(4)
    key = jax.random.PRNGKey(17)

    def slot_of(age):
        return jnp.mod(age, tau + 1)

    for scheme, reader in _READERS.items():
        want = reader(buffer, slot_of, a, m, key, dim)
        got = read_dispatch(jnp.int32(SCHEME_IDS[scheme]), buffer,
                            jnp.int32(tau), a, m, key, dim)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"scheme {scheme}")


def test_read_dispatch_under_vmap_matches_per_scheme():
    """One vmapped dispatch over all three scheme ids == three direct calls."""
    tau, dim = 3, 64
    buffer = jnp.tile(jnp.arange(tau + 1, dtype=jnp.float32)[:, None],
                      (1, dim))
    a, m = jnp.asarray(0), jnp.asarray(3)
    key = jax.random.PRNGKey(23)
    ids = jnp.asarray([0, 1, 2], jnp.int32)
    batched = jax.vmap(
        lambda sid: read_dispatch(sid, buffer, jnp.int32(tau), a, m, key,
                                  dim))(ids)
    for scheme, sid in SCHEME_IDS.items():
        direct = read_dispatch(jnp.int32(sid), buffer, jnp.int32(tau), a, m,
                               key, dim)
        np.testing.assert_array_equal(np.asarray(batched[sid]),
                                      np.asarray(direct),
                                      err_msg=f"scheme {scheme}")


def test_numeric_delay_dispatch_matches_string_api():
    """The numeric-select delay core == the public string API for every kind,
    including the τ=0 collapse to the zero schedule."""
    key = jax.random.PRNGKey(5)
    for tau in (0, 3, 7):
        for kind, did in DELAY_IDS.items():
            want = make_delay_schedule(kind, 50, tau, key)
            eff = DELAY_IDS["zero"] if tau == 0 else did
            got = _delay_schedule_core(jnp.int32(eff), 50, jnp.int32(tau), key)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=f"{kind} tau={tau}")


def test_sweep_rejects_bad_specs(obj):
    with pytest.raises(ValueError):
        run_sweep(obj, 1, [])
    with pytest.raises(ValueError):
        run_sweep(obj, 1, [SweepSpec(scheme="nope")])
    with pytest.raises(ValueError):
        run_sweep(obj, 1, [SweepSpec(delay_kind="nope")])
    with pytest.raises(ValueError):
        run_sweep(obj, 1, [SweepSpec(epochs=-1)])
    with pytest.raises(ValueError):
        run_sweep(obj, 0, [SweepSpec()])    # resolved epochs must be >= 1


# ---------------------------------------------------------------------------
# masked per-row epochs
# ---------------------------------------------------------------------------

def test_per_row_epochs_match_independent_shorter_runs(obj):
    """Rows with epochs ∈ {1,2,3} in ONE call: each is bit-identical to an
    independent run of its own length, the frozen tail repeats the final
    loss, and accounting (passes/updates) stops at the row's budget."""
    specs = [SweepSpec(scheme="inconsistent", step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25, seed=7, epochs=e)
             for e in (1, 2, 3)]
    res = run_sweep(obj, 3, specs)
    assert res.histories.shape == (3, 4)
    for c, spec in enumerate(specs):
        seq = run_asysvrg(obj, spec.epochs, spec.to_config(), seed=7)
        np.testing.assert_array_equal(
            np.asarray(seq.history, np.float32),
            res.histories[c, :spec.epochs + 1],
            err_msg=f"history mismatch for epochs={spec.epochs}")
        np.testing.assert_array_equal(np.asarray(seq.w, np.float32),
                                      res.final_w[c])
        assert np.all(res.histories[c, spec.epochs:]
                      == res.histories[c, spec.epochs])
        assert int(res.total_updates[c]) == seq.total_updates
        assert int(res.epochs_per_row[c]) == spec.epochs
        passes, hist = res.curve(c)
        assert len(hist) == spec.epochs + 1
        np.testing.assert_allclose(passes, np.asarray(seq.effective_passes))


def test_epochs_zero_inherits_call_default(obj):
    """epochs=0 rows inherit run_sweep's argument and mix freely with
    explicit budgets; the default row matches a default-length run."""
    specs = [SweepSpec(scheme="consistent", step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25, seed=1),
             SweepSpec(scheme="consistent", step_size=0.5, tau=3,
                       num_threads=4, inner_steps=25, seed=1, epochs=4)]
    res = run_sweep(obj, 2, specs)
    assert list(res.epochs_per_row) == [2, 4]
    seq2 = run_asysvrg(obj, 2, specs[0].to_config(), seed=1)
    seq4 = run_asysvrg(obj, 4, specs[1].to_config(), seed=1)
    np.testing.assert_array_equal(np.asarray(seq2.history, np.float32),
                                  res.histories[0, :3])
    np.testing.assert_array_equal(np.asarray(seq4.history, np.float32),
                                  res.histories[1])
    np.testing.assert_array_equal(np.asarray(seq2.w, np.float32),
                                  res.final_w[0])
    np.testing.assert_array_equal(np.asarray(seq4.w, np.float32),
                                  res.final_w[1])


# ---------------------------------------------------------------------------
# spec normalization + per-row compiled-shape pinning
# ---------------------------------------------------------------------------

def test_svrg_specs_normalized_to_what_executes(obj):
    """svrg rows execute consistent/zero-delay/τ=0; the result's specs (and
    row() records) must say so even when the input spec left the
    asysvrg-flavoured defaults in place."""
    res = run_sweep(obj, 1, [SweepSpec(algo="svrg", step_size=0.5,
                                       num_threads=1, inner_steps=30)])
    s = res.specs[0]
    assert (s.scheme, s.delay_kind, s.tau) == ("consistent", "zero", 0)
    rec = res.row(0)
    assert rec["scheme"] == "consistent" and rec["delay_kind"] == "zero"
    assert rec["epochs"] == 1


def test_svrg_contradictory_tau_raises(obj):
    with pytest.raises(ValueError, match="degenerate"):
        run_sweep(obj, 1, [SweepSpec(algo="svrg", tau=3)])


def test_result_specs_report_derived_tau_and_zero_delay(obj):
    """Convention sentinels are resolved in the result: asysvrg tau=0 means
    τ=p−1, and a genuinely zero-delay row reports delay_kind='zero'."""
    specs = [SweepSpec(scheme="inconsistent", step_size=0.5, tau=0,
                       num_threads=4, inner_steps=25),
             SweepSpec(scheme="consistent", step_size=0.5, tau=0,
                       num_threads=1, inner_steps=25)]
    res = run_sweep(obj, 1, specs)
    assert res.specs[0].tau == 3                       # derived p−1
    assert res.specs[0].delay_kind == "fixed"
    assert res.specs[1].tau == 0                       # p=1 -> genuinely 0
    assert res.specs[1].delay_kind == "zero"


def test_buf_len_pinned_per_row(obj):
    """Adding an unrelated high-τ row must not change another row's group
    key (= compiled program shape): buf_len comes from the row's own
    (τ, threads), not from whichever rows share the group."""
    a = SweepSpec(scheme="inconsistent", step_size=0.5, tau=3,
                  num_threads=4, inner_steps=25)
    b = SweepSpec(scheme="inconsistent", step_size=0.5, tau=50,
                  num_threads=4, inner_steps=25)
    p_alone = plan_sweep(obj, 2, [a])
    p_both = plan_sweep(obj, 2, [a, b])
    key_alone = next(k for k, v in p_alone.groups.items() if 0 in v)
    key_both = next(k for k, v in p_both.groups.items() if 0 in v)
    assert key_alone == key_both
    assert len(p_both.groups) == 2      # the τ=50 row got its own group
    # and the split groups still produce bit-identical rows
    res = run_sweep(obj, 2, [a, b])
    _assert_rows_match_sequential(obj, [a, b], res, 2)


def test_tau_axis_shares_one_group_at_fixed_thread_count(obj):
    """The frontier's τ axis (one thread count, τ ≤ p−1) must stay ONE
    compiled group — buf_len pinning pads to the thread count."""
    specs = [SweepSpec(scheme="inconsistent", step_size=0.5, tau=t,
                       num_threads=4, inner_steps=25) for t in (1, 2, 3)]
    plan = plan_sweep(obj, 2, specs)
    assert len(plan.groups) == 1
    (ofp, engine, total, option, buf_len, fused), = plan.groups
    assert fused is False               # default engine mode is vmap
    assert ofp == obj.fingerprint()
    assert buf_len == 4                 # p, not max(τ)+1 of the members
