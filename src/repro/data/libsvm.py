"""LibSVM-style binary-classification datasets for the paper's experiments.

The paper evaluates on rcv1 / real-sim / news20 (sparse bag-of-words, labels
in {-1,+1}). Offline we synthesize datasets with matched *statistical* shape
(instances, features, sparsity, label balance, separability) at reduced
feature dimension via feature hashing, plus a real ``parse_libsvm_file`` so
the true datasets can be dropped in unchanged.

Storage is dense (B, p) float32 — on TPU the MXU wants dense tiles; the CPU
original's CSR layout does not map (recorded in DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Tuple

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class LogRegDataset:
    X: np.ndarray          # (n, p) float32
    y: np.ndarray          # (n,) float32 in {-1, +1}
    name: str = "synthetic"
    l2_reg: float = 1e-4

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]

    def as_jax(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self.X), jnp.asarray(self.y)


# Matched to Table 1 of the paper (features reduced by hashing; density kept).
PAPER_DATASETS: Dict[str, Dict] = {
    "rcv1":     dict(n=20242, p=47236, p_reduced=2048, density=0.0016, l2=1e-4),
    "real-sim": dict(n=72309, p=20958, p_reduced=1024, density=0.0024, l2=1e-4),
    "news20":   dict(n=19996, p=1355191, p_reduced=4096, density=0.0003, l2=1e-4),
}


def make_synthetic_libsvm(
    name: str = "rcv1",
    seed: int = 0,
    scale: float = 1.0,
) -> LogRegDataset:
    """Synthesize a dataset with rcv1-like statistics.

    A ground-truth separator w* generates labels with ~8% label noise, so the
    optimum is interior (strongly convex via the L2 term) and the loss
    landscape matches the regime the paper's theory targets.
    """
    spec = PAPER_DATASETS[name]
    n = max(64, int(spec["n"] * scale))
    p = spec["p_reduced"]
    nnz_per_row = max(4, int(spec["density"] * spec["p"]))
    # crc32, NOT hash(): str hashing is salted per process (PYTHONHASHSEED),
    # which silently made "the same" dataset differ across processes — fatal
    # for pinned regressions and checkpoint-resume fingerprints.
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (2**31))

    X = np.zeros((n, p), dtype=np.float32)
    for i in range(n):
        idx = rng.choice(p, size=min(nnz_per_row, p), replace=False)
        X[i, idx] = rng.standard_normal(len(idx)).astype(np.float32)
    # tf-idf-like positive skew + row normalization (libsvm convention)
    X = np.abs(X) * np.sign(rng.standard_normal((n, p)) + 0.3).astype(np.float32)
    norms = np.linalg.norm(X, axis=1, keepdims=True)
    X = X / np.maximum(norms, 1e-8)

    w_star = rng.standard_normal(p).astype(np.float32) / np.sqrt(p)
    margins = X @ w_star
    y = np.sign(margins + 1e-12)
    flip = rng.random(n) < 0.08
    y = np.where(flip, -y, y).astype(np.float32)
    y[y == 0] = 1.0
    return LogRegDataset(X=X, y=y, name=name, l2_reg=spec["l2"])


def parse_libsvm_file(path: str, num_features: int) -> LogRegDataset:
    """Parse a real libsvm-format file into a dense LogRegDataset."""
    rows, ys = [], []
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            ys.append(1.0 if float(parts[0]) > 0 else -1.0)
            row = np.zeros(num_features, np.float32)
            for kv in parts[1:]:
                k, v = kv.split(":")
                j = int(k) - 1
                if 0 <= j < num_features:
                    row[j] = float(v)
            rows.append(row)
    return LogRegDataset(X=np.stack(rows), y=np.asarray(ys, np.float32),
                         name=path)
