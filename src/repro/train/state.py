"""TrainState + step builders (the functions the dry-run lowers).

`make_train_step(bundle, tcfg)` builds the steady-state inner step of
Algorithm 1 at LM scale: two fwd+bwd on the same minibatch (at w and at
w_snap), control variate v = g − g0 + g_snap, optimizer apply. With
optimizer != "svrg" the same builder emits the plain-SGD/Adam baseline step
(the Hogwild!-equivalent compute), so the roofline compares both.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.core.distributed import (
    SVRGState, init_svrg_state, snapshot_accumulate, snapshot_begin,
    snapshot_finalize, svrg_direction)
from repro.kernels.svrg_update import ops as svrg_ops
from repro.models.factory import ModelBundle
from repro.optim import clip_by_global_norm, make_optimizer, make_schedule
from repro.sharding.rules import ParamDef, init_from_defs
from repro.utils.tree import tree_zeros_like


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    svrg: Optional[SVRGState]
    step: jnp.ndarray


def init_train_state(key, bundle: ModelBundle, tcfg: TrainConfig) -> TrainState:
    params = init_from_defs(key, bundle.param_defs)
    opt = make_optimizer(tcfg)
    # w_snap must be a DISTINCT buffer from params or a donating step sees
    # the same buffer twice (see train/loop.refresh_snapshot)
    svrg = (init_svrg_state(jax.tree.map(jnp.array, params))
            if tcfg.optimizer == "svrg" else None)
    return TrainState(params=params, opt_state=opt.init(params), svrg=svrg,
                      step=jnp.zeros((), jnp.int32))


def make_train_state_defs(bundle: ModelBundle, tcfg: TrainConfig):
    """ParamDef pytree mirroring TrainState (dry-run structs + shardings)."""
    pdefs = bundle.param_defs
    scalar = ParamDef((), (), "zeros", dtype="int32")
    fscalar = ParamDef((), (), "zeros", dtype="float32")
    if tcfg.optimizer == "svrg":
        svrg = SVRGState(w_snap=pdefs, g_snap=pdefs, snap_step=scalar,
                         accum_count=scalar)
    else:
        svrg = None
    opt = make_optimizer(tcfg)
    if opt.name == "momentum":
        opt_state = {"m": pdefs}
    elif opt.name == "adamw":
        opt_state = {"m": pdefs, "v": pdefs}
    else:
        opt_state = {}
    return TrainState(params=pdefs, opt_state=opt_state, svrg=svrg,
                      step=scalar)


def make_train_step(bundle: ModelBundle, tcfg: TrainConfig,
                    use_fused_update: bool = False) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    With tcfg.microbatches > 1 the global batch is split and gradients are
    accumulated in a rematerialized scan — activation peak scales ~1/mb
    (the standard way the 104B/235B train_4k cells fit 16 GB/chip; the
    accumulator is one extra sharded param-sized f32 buffer)."""
    opt = make_optimizer(tcfg)
    schedule = make_schedule(tcfg)
    vgrad = jax.value_and_grad(bundle.loss_fn)
    is_svrg = tcfg.optimizer == "svrg"

    def grads_of(params, svrg, batch):
        loss, g = vgrad(params, batch)
        if is_svrg:
            _, g0 = vgrad(svrg.w_snap, batch)
            return loss, svrg_direction(g, g0, svrg.g_snap)
        return loss, g

    def accumulate(params, svrg, batch, mb: int):
        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        batches = jax.tree.map(split, batch)

        def body(carry, b):
            loss_acc, v_acc = carry
            loss, v = grads_of(params, svrg, b)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, v_acc, v)), None

        body = jax.checkpoint(body, prevent_cse=False)
        init = (jnp.zeros((), jnp.float32), tree_zeros_like(params))
        (loss_sum, v_sum), _ = jax.lax.scan(body, init, batches)
        inv = 1.0 / mb
        return loss_sum * inv, jax.tree.map(lambda x: x * inv, v_sum)

    def step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        if tcfg.microbatches > 1:
            loss, v = accumulate(state.params, state.svrg, batch,
                                 tcfg.microbatches)
        else:
            loss, v = grads_of(state.params, state.svrg, batch)
        v, vnorm = clip_by_global_norm(v, tcfg.grad_clip)
        lr = schedule(state.step)
        if is_svrg and use_fused_update and opt.name == "sgd":
            # Pallas fused control-variate apply (kernels/svrg_update)
            params = svrg_ops.apply_tree(state.params, g, g0,
                                         state.svrg.g_snap, lr,
                                         tcfg.weight_decay)
            opt_state = state.opt_state
        else:
            params, opt_state = opt.apply(v, state.opt_state, lr,
                                          state.params, state.step)
        new_state = state._replace(params=params, opt_state=opt_state,
                                   step=state.step + 1)
        metrics = {"loss": loss, "v_norm": vnorm, "lr": lr}
        return new_state, metrics

    return step


def make_snapshot_fns(bundle: ModelBundle, tcfg: TrainConfig):
    """(begin, accumulate, finalize) — the paper's partitioned full-gradient
    pass, jit-able separately from the inner step."""

    def begin(state: TrainState) -> TrainState:
        return state._replace(svrg=snapshot_begin(state.svrg))

    def accumulate(state: TrainState, batch) -> TrainState:
        return state._replace(
            svrg=snapshot_accumulate(bundle.loss_fn, state.params,
                                     state.svrg, batch))

    def finalize(state: TrainState) -> TrainState:
        return state._replace(
            svrg=snapshot_finalize(state.params, state.svrg, state.step))

    return begin, accumulate, finalize
