"""Pure-jnp oracle for causal (optionally windowed) attention.

Shapes: q [B, H, S, d], k/v [B, H, S, d] (GQA expansion happens in ops.py).
Softmax in float32. window=0 means global causal.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, window: int = 0):
    B, H, S, d = q.shape
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= pos_q >= pos_k
    if window > 0:
        ok &= (pos_q - pos_k) < window
    scores = jnp.where(ok[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), v)
