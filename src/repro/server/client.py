"""Python client for the sweep server's HTTP API (stdlib urllib only).

    client = SweepClient("http://127.0.0.1:8742")
    rid = client.submit(specs, tenant="team-a")     # returns immediately
    res = client.result(rid, timeout=60)            # long-polls the server
    # res is a SweepResult, bit-identical to run_sweep(obj, epochs, specs)

``result`` long-polls: each round the SERVER blocks up to its per-request
wait bound and answers 504/"pending" if the flush daemon hasn't run the
request yet; the client re-polls until its own ``timeout``. Submitting
never triggers execution — batching is entirely the server's policy —
except through :meth:`flush`, the explicit escape hatch.

Error mapping mirrors the service's in-process exceptions: 404 raises
KeyError, 410 raises `repro.service.ResultEvictedError`, 400 raises
ValueError, anything else `ServerError`.
"""
from __future__ import annotations

import json
import time
import urllib.error
import urllib.request
from typing import List, Optional, Sequence

from repro.core.sweep import SweepResult, SweepSpec
from repro.server.http import result_from_dict, spec_to_dict
from repro.service.api import ResultEvictedError


class ServerError(RuntimeError):
    """A non-2xx response that doesn't map to a standard exception."""

    def __init__(self, status: int, payload: dict):
        super().__init__(f"HTTP {status}: {payload.get('error', payload)}")
        self.status = status
        self.payload = payload


class SweepClient:
    def __init__(self, base_url: str, *, timeout: float = 30.0,
                 poll_s: float = 10.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout           # per-HTTP-call socket timeout
        self.poll_s = poll_s             # server-side wait per result poll

    # ------------------------------------------------------------ plumbing
    def _call(self, method: str, path: str,
              body: Optional[dict] = None) -> dict:
        data = None if body is None else json.dumps(body).encode()
        req = urllib.request.Request(
            self.base_url + path, data=data, method=method,
            headers={"Content-Type": "application/json"})
        try:
            # socket timeout must outlast the server-side result wait
            with urllib.request.urlopen(
                    req, timeout=self.timeout + self.poll_s) as resp:
                return json.loads(resp.read().decode())
        except urllib.error.HTTPError as e:
            try:
                payload = json.loads(e.read().decode())
            except (ValueError, OSError):
                payload = {"error": str(e)}
            raise self._map_error(e.code, payload) from None

    @staticmethod
    def _map_error(status: int, payload: dict) -> Exception:
        message = payload.get("error", f"HTTP {status}")
        if status == 404 and payload.get("status") == "unknown":
            return KeyError(message)
        if status == 410:
            return ResultEvictedError(message)
        if status == 504:
            return TimeoutError(message)
        if status == 400:
            return ValueError(message)
        return ServerError(status, payload)

    # ------------------------------------------------------------- the API
    def healthz(self) -> dict:
        return self._call("GET", "/healthz")

    def stats(self) -> dict:
        return self._call("GET", "/stats")

    def metrics(self) -> str:
        """The raw Prometheus text exposition from ``GET /metrics``."""
        req = urllib.request.Request(self.base_url + "/metrics")
        with urllib.request.urlopen(req, timeout=self.timeout) as resp:
            return resp.read().decode()

    def trace(self, trace_id: Optional[str] = None) -> dict:
        """Flight-recorder state: recent traces + last-error dump, or one
        request's full span tree when ``trace_id`` is given (KeyError once
        it has been evicted from the ring buffer)."""
        path = "/trace" if trace_id is None else f"/trace?id={trace_id}"
        return self._call("GET", path)

    def submit(self, specs: Sequence[SweepSpec],
               epochs: Optional[int] = None, *, tenant: str = "default",
               priority: int = 0) -> int:
        body = {"specs": [spec_to_dict(s) for s in specs],
                "tenant": tenant, "priority": priority}
        if epochs is not None:
            body["epochs"] = epochs
        return int(self._call("POST", "/submit", body)["request_id"])

    def flush(self) -> List[int]:
        """Force a flush now (the eager path; normally the server's flush
        daemon decides when to dispatch)."""
        return [int(i) for i in self._call("POST", "/flush")["completed"]]

    def result(self, request_id: int,
               timeout: Optional[float] = 60.0) -> SweepResult:
        """Long-poll until the request's result is served (TimeoutError
        after ``timeout`` seconds; None polls forever)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            remaining = (self.poll_s if deadline is None
                         else deadline - time.monotonic())
            if remaining <= 0:
                raise TimeoutError(
                    f"request {request_id} not served within {timeout}s")
            try:
                payload = self._call(
                    "GET", f"/result/{request_id}"
                    f"?timeout_s={min(self.poll_s, remaining):.3f}")
            except TimeoutError:
                continue                 # server said "pending": poll again
            return result_from_dict(payload)

    def sweep(self, specs: Sequence[SweepSpec],
              epochs: Optional[int] = None, *, tenant: str = "default",
              priority: int = 0,
              timeout: Optional[float] = 60.0) -> SweepResult:
        """submit + result in one call (still batched by server policy)."""
        return self.result(
            self.submit(specs, epochs, tenant=tenant, priority=priority),
            timeout=timeout)
