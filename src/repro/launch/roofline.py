"""Roofline extraction from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), all in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = Σ collective_bytes_per_device / link_bw

cost_analysis() provides per-device FLOPs and bytes-accessed. Collective
bytes are NOT in cost_analysis — they are parsed from the post-SPMD
compiled HLO: we sum the OPERAND sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (per-device shapes; for
all-gather the operand is the per-device contribution, matching ring-cost
intuition within a small factor).

MODEL_FLOPS is the analytic useful-work count (6·N·D train / 2·N·D decode,
N = active params, plus the causal-attention term) — the
MODEL_FLOPS/HLO_FLOPs ratio exposes remat recompute and SVRG's intrinsic
2x gradient cost.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple


from repro.config import HardwareSpec, ModelConfig, ShapeConfig, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[4096,1024]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# Jaxpr-level cost model (exact loop trip counts — XLA's cost_analysis visits
# while bodies ONCE, undercounting scan-over-layers programs by ~L)
# ---------------------------------------------------------------------------

def _aval_bytes(aval) -> int:
    n = 1
    for d in aval.shape:
        n *= int(d)
    return n * aval.dtype.itemsize


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs = eqn.invars[0].aval
    m = 1
    for i, d in enumerate(lhs.shape):
        if i in lc:
            m *= d        # contraction
        elif i in lb:
            m *= d        # batch
    out = 1
    for d in eqn.outvars[0].aval.shape:
        out *= d
    k = 1
    for i in lc:
        k *= lhs.shape[i]
    return 2.0 * out * k


_RECURSE_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")
_MATERIAL_PRIMS = {
    "dot_general", "conv_general_dilated", "gather", "scatter",
    "scatter-add", "scatter_add", "dynamic_slice", "dynamic_update_slice",
    "take", "sort", "top_k", "cumsum", "concatenate",
}


def jaxpr_cost(jaxpr) -> Dict[str, float]:
    """(flops, materialized bytes) of a ClosedJaxpr/Jaxpr, with scan bodies
    multiplied by their trip count. Bytes count only "materialization
    points" (matmul/gather/scan-boundary traffic) as an HBM-traffic proxy —
    pure elementwise chains are assumed fused."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    flops = 0.0
    bytes_ = 0.0
    for eqn in jx.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            n = eqn.params["length"]
            inner = jaxpr_cost(eqn.params["jaxpr"])
            flops += n * inner["flops"]
            bytes_ += n * inner["bytes"]
            # xs/ys slicing + carry read/write per iteration
            num_carry = eqn.params["num_carry"]
            carry_b = sum(_aval_bytes(v.aval)
                          for v in eqn.invars[eqn.params["num_consts"]:
                                              eqn.params["num_consts"] + num_carry])
            xs_b = sum(_aval_bytes(v.aval)
                       for v in eqn.invars[eqn.params["num_consts"] + num_carry:])
            ys_b = sum(_aval_bytes(v.aval) for v in eqn.outvars[num_carry:])
            bytes_ += xs_b + ys_b + 2.0 * n * carry_b
            continue
        if prim == "cond":
            branches = eqn.params["branches"]
            costs = [jaxpr_cost(b) for b in branches]
            flops += max(c["flops"] for c in costs)
            bytes_ += max(c["bytes"] for c in costs)
            continue
        recursed = False
        for pname in _RECURSE_PARAMS:
            if pname in eqn.params:
                inner = jaxpr_cost(eqn.params[pname])
                flops += inner["flops"]
                bytes_ += inner["bytes"]
                recursed = True
                break
        if recursed:
            continue
        if prim == "dot_general":
            flops += _dot_flops(eqn)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.invars) \
                + sum(_aval_bytes(v.aval) for v in eqn.outvars)
            continue
        # elementwise/reduction flop estimate: 1 flop per output element
        out_b = 0
        for v in eqn.outvars:
            if hasattr(v.aval, "shape"):
                n = 1
                for d in v.aval.shape:
                    n *= int(d)
                flops += n
                out_b += _aval_bytes(v.aval)
        if prim in _MATERIAL_PRIMS:
            bytes_ += out_b + sum(_aval_bytes(v.aval) for v in eqn.invars
                                  if hasattr(v.aval, "shape"))
    return {"flops": flops, "bytes": bytes_}


# ---------------------------------------------------------------------------
# Trip-count-aware collective parse of post-SPMD HLO
# ---------------------------------------------------------------------------

def _split_computations(hlo_text: str) -> Dict[str, str]:
    comps: Dict[str, List[str]] = {}
    name = None
    for line in hlo_text.splitlines():
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*{", line)
        if m and not line.lstrip().startswith("%"):
            name = m.group(1)
            comps[name] = []
        elif name is not None:
            comps[name].append(line)
            if line.strip() == "}":
                name = None
    return {k: "\n".join(v) for k, v in comps.items()}


def _while_trip_count(cond_text: str) -> int:
    """Estimate a while loop's trip count from its condition computation:
    the loop bound appears as the largest s32 constant compared against."""
    consts = [int(c) for c in re.findall(r"constant\((\d+)\)", cond_text)]
    return max(consts) if consts else 1


def collective_bytes_with_trips(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes, multiplying ops inside while bodies by
    the loop trip count (scan-over-layers puts one all-gather per layer
    INSIDE the loop — a flat parse undercounts by ~num_layers)."""
    comps = _split_computations(hlo_text)
    # multipliers: computation -> trip multiplier (propagated through calls)
    mult: Dict[str, float] = {}

    entry = None
    for name in comps:
        if ".clone" not in name and ("main" in name or entry is None):
            pass
    # find callee edges
    def edges(text):
        out = []
        for m in re.finditer(r"while\(.*?\)[^\n]*?condition=%?([\w.\-]+)[^\n]*?body=%?([\w.\-]+)", text):
            out.append(("while", m.group(1), m.group(2)))
        for m in re.finditer(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)", text):
            out.append(("call", None, m.group(1)))
        return out

    # BFS from every root (computations not referenced elsewhere)
    referenced = set()
    for text in comps.values():
        for m in re.finditer(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)", text):
            referenced.add(m.group(1))
    roots = [n for n in comps if n not in referenced] or list(comps)[:1]

    for r in roots:
        mult.setdefault(r, 1.0)
    work = list(roots)
    seen = set()
    while work:
        cur = work.pop()
        if cur in seen or cur not in comps:
            continue
        seen.add(cur)
        text = comps[cur]
        base = mult.get(cur, 1.0)
        for m in re.finditer(
                r"while\([^\n]*?condition=%?([\w.\-]+)[^\n]*?body=%?([\w.\-]+)",
                text):
            cond, body = m.group(1), m.group(2)
            trips = _while_trip_count(comps.get(cond, ""))
            mult[body] = max(mult.get(body, 0.0), base * trips)
            mult[cond] = max(mult.get(cond, 0.0), base * trips)
            work += [body, cond]
        for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", text):
            callee = m.group(1)
            mult[callee] = max(mult.get(callee, 0.0), base)
            work.append(callee)

    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0.0
    for name, text in comps.items():
        local = parse_collective_bytes(text)
        f = mult.get(name, 1.0)
        for k in _COLLECTIVES:
            out[k] += local[k] * f
        out["count"] += local["count"] * f
    return out


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+(all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(?:-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        if "-done" in line.split("(")[0]:
            continue          # count the -start, skip the -done
        # operand shapes: everything inside the call parens
        call = line.split("(", 1)[1]
        operands = call.rsplit(")", 1)[0]
        # operand list references %names — their shapes are not on this line;
        # use the OUTPUT shape as the proxy for a-r/r-s/a2a/c-p (same size),
        # and for all-gather divide by the group size parsed from
        # replica_groups (operand = output / group).
        out_bytes = _shape_bytes(m.group(1))
        if kind == "all-gather":
            g = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if g:
                out_bytes //= max(1, int(g.group(2)))
            else:
                g2 = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
                if g2:
                    out_bytes //= max(1, len(g2.group(1).split(",")))
        out[kind] += out_bytes
        out["count"] += 1
    return out


# ---------------------------------------------------------------------------
# Analytic useful-work FLOPs
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, defs) -> Tuple[int, int]:
    """(total, active) param counts from the ParamDef tree."""
    from repro.sharding.rules import is_param_def
    import jax

    total = 0
    active = 0
    frac = 1.0
    if cfg.num_experts > 0:
        frac = cfg.experts_per_token / cfg.num_experts

    def visit(path, d):
        nonlocal total, active
        n = 1
        for s in d.shape:
            n *= s
        total += n
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        if "moe" in key and "shared" not in key and "router" not in key:
            active += int(n * frac)
        else:
            active += n

    for path, d in jax.tree_util.tree_flatten_with_path(
            defs, is_leaf=is_param_def)[0]:
        visit(path, d)
    return total, active


def attention_flops(cfg: ModelConfig, S: int, B: int, decode: bool) -> float:
    """QK^T + AV flops (fwd). Window-aware; causal halves the full case."""
    if cfg.family == "ssm":
        return 0.0
    d_attn = cfg.num_heads * cfg.head_dim
    if cfg.family == "hybrid":
        G = cfg.num_layers // 3
        layers = G            # only attn layers
        window = min(cfg.local_window, S)
        keys = window if decode else window  # local
        eff = S * keys if not decode else keys
        return 4.0 * B * layers * d_attn * eff
    layers = cfg.num_layers
    if decode:
        keys = S
        per_layer = 4.0 * B * d_attn * keys      # one query
    else:
        if cfg.attn_pattern == "local_global":
            n_global = layers // cfg.global_every
            n_local = layers - n_global
            w = min(cfg.local_window, S)
            per_global = 4.0 * B * d_attn * S * S * 0.5
            per_local = 4.0 * B * d_attn * S * w
            return n_global * per_global + n_local * per_local
        per_layer = 4.0 * B * d_attn * S * S * 0.5
    total = layers * per_layer
    if cfg.family == "encdec" and not decode:
        total += cfg.encoder_layers * 4.0 * B * d_attn * cfg.encoder_seq ** 2
        total += layers * 4.0 * B * d_attn * S * cfg.encoder_seq
    if cfg.family == "vlm":
        n_cross = layers // 5
        total += n_cross * 4.0 * B * d_attn * (1 if decode else S) * cfg.num_image_tokens
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig, defs) -> float:
    total, active = count_params(cfg, defs)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens + 3.0 * attention_flops(
            cfg, shape.seq_len, shape.global_batch, decode=False)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens + attention_flops(
            cfg, shape.seq_len, shape.global_batch, decode=False)
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch + attention_flops(
        cfg, shape.seq_len, shape.global_batch, decode=True)


# ---------------------------------------------------------------------------
# Fused sweep-epoch megakernel: analytic intensity headroom
# ---------------------------------------------------------------------------

def sweep_epoch_roofline(*, rows: int, dim: int, total: int, epochs: int,
                         buf_len: int, hw: HardwareSpec = TPU_V5E,
                         dtype_bytes: int = 4) -> Dict:
    """Arithmetic-intensity headroom of the fused sweep-epoch megakernel
    over the vmap engine for one (rows × epochs × M̃) group.

    Both paths run the same FLOPs — per update, two component gradients
    (~2·2·dim each for the dot + axpy shape shared by the repo's
    objectives) plus the control-variate combine (~3·dim), ≈ 11·dim. What
    differs is HBM traffic per update:

      * vmap: the XLA scan carry — the iterate ``w``, the PRNG key + loss
        slot, and the ``buf_len``-deep delay ring — is read AND written
        through HBM every update, so bytes/update ≈ 2·(buf_len + 2)·dim·b
        plus the sampled data row.
      * fused: the carry lives in VMEM for the whole (row × epoch); only
        the sampled data row moves per update, with the per-row boundary
        I/O (w0 in, w_fin + history out) amortized over epochs·M̃ updates.

    The intensity ratio is the roofline-predicted speedup bound in the
    memory-bound regime (the AsySVRG inner loop's regime: intensity ~2
    flops/byte << every listed hw's ridge). Returns both paths' terms so
    benchmarks can log predicted vs measured side by side.
    """
    updates = float(rows) * epochs * total
    flops_per_update = 11.0 * dim
    flops = updates * flops_per_update
    row_bytes = dim * dtype_bytes                       # sampled data row
    carry_bytes = 2.0 * (buf_len + 2) * dim * dtype_bytes
    boundary = rows * dtype_bytes * (2.0 * dim + epochs + 1)

    out: Dict = {"rows": rows, "dim": dim, "total": total, "epochs": epochs,
                 "buf_len": buf_len, "flops": flops}
    for path, bytes_ in (("vmap", updates * (row_bytes + carry_bytes)
                          + boundary),
                         ("fused", updates * row_bytes + boundary)):
        t_compute = flops / hw.peak_flops_bf16
        t_memory = bytes_ / hw.hbm_bandwidth
        out[path] = {
            "bytes": bytes_,
            "intensity_flops_per_byte": flops / bytes_,
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "step_lower_bound_s": max(t_compute, t_memory),
            "dominant": "compute" if t_compute >= t_memory else "memory",
        }
    out["intensity_headroom"] = (
        out["fused"]["intensity_flops_per_byte"]
        / out["vmap"]["intensity_flops_per_byte"])
    out["predicted_speedup"] = (out["vmap"]["step_lower_bound_s"]
                                / out["fused"]["step_lower_bound_s"])
    return out


def attained_fraction(*, rows: int, dim: int, total: int, epochs: int,
                      buf_len: int, fused: bool, wall_s: float,
                      hw: HardwareSpec = TPU_V5E) -> Dict:
    """Attained-vs-roofline fraction for one MEASURED group dispatch.

    Selects the engine path (vmap or fused megakernel) of
    :func:`sweep_epoch_roofline` and divides its step lower bound by the
    measured wall time — the per-group "how close to the hardware are
    we" number the performance ledger (``repro.obs.ledger``) records and
    the multi-host fabric will route on. On a backend other than ``hw``
    (e.g. the CPU CI container vs the TPU_V5E default) the fraction is a
    cross-hardware comparison, not a utilization: still monotone in
    dispatch speed, so regressions show, but only meaningful in absolute
    terms when ``hw`` matches the machine.
    """
    rf = sweep_epoch_roofline(rows=rows, dim=dim, total=total,
                              epochs=epochs, buf_len=buf_len, hw=hw)
    path = rf["fused" if fused else "vmap"]
    return {
        "roofline_s": path["step_lower_bound_s"],
        "attained_frac": (path["step_lower_bound_s"] / wall_s
                          if wall_s > 0 else 0.0),
        "flops": rf["flops"],
        "bytes": path["bytes"],
        "dominant": path["dominant"],
    }


# ---------------------------------------------------------------------------
# Terms
# ---------------------------------------------------------------------------

def roofline_terms(record: Dict, hw: HardwareSpec = TPU_V5E) -> Dict:
    """record: one dry-run JSON.

    Sources, in order of trust:
      * flops/bytes: the jaxpr cost model (exact scan trip counts), global,
        divided by chip count. Falls back to cost_analysis (which visits
        while bodies once — undercounts scan programs by ~num_layers).
      * collectives: trip-count-multiplied HLO parse (per-device shapes).
    """
    chips = record["num_devices"]
    jc = record.get("jaxpr_cost")
    if jc:
        flops = jc["flops"] / chips
        bytes_acc = jc["bytes"] / chips
        source = "jaxpr"
    else:
        flops = record["cost"].get("flops", 0.0)
        bytes_acc = record["cost"].get("bytes accessed", 0.0)
        source = "hlo_cost_analysis"
    coll = record.get("collectives_trips") or record["collectives"]
    coll_bytes = sum(coll.get(k, 0) for k in _COLLECTIVES)
    t_compute = flops / hw.peak_flops_bf16
    t_memory = bytes_acc / hw.hbm_bandwidth
    t_coll = coll_bytes / hw.ici_bandwidth
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_compute, t_memory, t_coll)
    mf = record.get("model_flops", 0.0)
    hlo_total = flops * chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "mfu_upper_bound": (mf / (chips * hw.peak_flops_bf16)) / bound
        if bound else 0.0,
        "cost_source": source,
    }
