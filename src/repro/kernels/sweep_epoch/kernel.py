"""Pallas sweep-epoch megakernel: one launch per (group × run).

Why a kernel: the vmap engine dispatches the inner minibatch scan as
per-update XLA ops, so every one of the M̃·epochs updates streams the
iterate ``w``, the snapshot ``w̃`` (u0), the full-gradient anchor ``μ`` and
the delay ring buffer through HBM — the scan carry alone is
(buf_len + 2)·d floats read AND written per update. The paper's whole
argument is that the AsySVRG inner loop is cheap; fused, it is: this kernel
maps the config-row axis of a sweep group onto the Pallas grid and runs the
ENTIRE multi-epoch scan for one row inside a single kernel invocation, so
``w``, ``w̃``, ``μ`` and the ring buffer stay resident in VMEM for the whole
epoch and only the sampled data rows move. A merged service group is ONE
megakernel launch instead of M̃·epochs·rows op dispatches.

The kernel body executes the SAME per-row epochs-scan functions the vmap
engine batches (`repro.core.asysvrg._asysvrg_epochs_core` /
`repro.core.hogwild._hogwild_epochs_core`): under the Pallas interpreter
the body lowers to the identical XLA:CPU ops per row, and the engine's
vmap-bitwise-stable contract (vmap == per-row bits) closes the loop — the
fused path is BIT-IDENTICAL to the vmap path in interpret mode
(tests/test_kernel_sweep.py). Compiled (Mosaic) lowering targets TPU and is
NOT validated in this CPU container — see the ROADMAP real-accelerator
revalidation item.

Operand layout (built by `repro.kernels.sweep_epoch.ops`):

  * objective data args — full-array blocks, identical for every grid step
    (the index map is constant, so Pallas keeps them resident across rows);
    0-d scalars are lifted to (1, 1).
  * per-row arrays — row-blocked: scalar rows [C] are lifted to [C, 1] and
    blocked (1, 1); the PRNG key rows [C, 2] and the w0 rows [C, d] are
    blocked (1, ...) over the grid axis.
  * outputs — final iterates [C, d] and loss histories [C, epochs+1],
    row-blocked the same way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.compat import tpu_compiler_params


def _const_index_map(ndim: int):
    return lambda i: (0,) * ndim


def _row_index_map(ndim: int):
    return lambda i: (i,) + (0,) * (ndim - 1)


def sweep_epoch_call(row_fn, data, row_args, *, epochs: int, dim: int,
                     interpret: bool):
    """Launch ``row_fn`` over the config-row grid in ONE `pallas_call`.

    ``row_fn(data, *row_scalars) -> (w_fin [dim], losses [epochs+1])`` is
    the per-row epochs scan; ``data`` is the objective's `data_args` tuple
    (any shapes, replicated across rows) and ``row_args`` the row-leading
    arrays — every 1-D entry is treated as a scalar row, higher-rank
    entries ([C, 2] keys, [C, dim] w0) pass their per-row slice through.

    Returns (w_fin [C, dim], losses [C, epochs+1]).
    """
    rows = int(row_args[0].shape[0])

    # -- pack operands: lift 0-d data scalars and 1-d row arrays to 2-d ----
    data_ops, data_specs, data_scalar = [], [], []
    for arr in data:
        arr = jnp.asarray(arr)
        scalar = arr.ndim == 0
        if scalar:
            arr = arr.reshape(1, 1)
        data_ops.append(arr)
        data_scalar.append(scalar)
        data_specs.append(pl.BlockSpec(arr.shape,
                                       _const_index_map(arr.ndim)))

    row_ops, row_specs, row_scalar = [], [], []
    for arr in row_args:
        arr = jnp.asarray(arr)
        scalar = arr.ndim == 1
        if scalar:
            arr = arr[:, None]
        row_ops.append(arr)
        row_scalar.append(scalar)
        row_specs.append(pl.BlockSpec((1,) + arr.shape[1:],
                                      _row_index_map(arr.ndim)))

    w_dtype = row_ops[-1].dtype                 # w0 rows define the iterate

    def kernel(*refs):
        d_refs = refs[:len(data_ops)]
        r_refs = refs[len(data_ops):len(data_ops) + len(row_ops)]
        w_ref, hist_ref = refs[-2:]
        data_vals = tuple(r[0, 0] if s else r[...]
                          for r, s in zip(d_refs, data_scalar))
        row_vals = tuple(r[0, 0] if s else r[0]
                         for r, s in zip(r_refs, row_scalar))
        w_fin, losses = row_fn(data_vals, *row_vals)
        w_ref[0] = w_fin
        hist_ref[0] = losses

    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=data_specs + row_specs,
        out_specs=[
            pl.BlockSpec((1, dim), _row_index_map(2)),
            pl.BlockSpec((1, epochs + 1), _row_index_map(2)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, dim), w_dtype),
            jax.ShapeDtypeStruct((rows, epochs + 1), jnp.float32),
        ],
        # rows are independent: the grid axis is embarrassingly parallel
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*data_ops, *row_ops)
