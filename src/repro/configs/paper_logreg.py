"""The paper's own workload: L2-regularized logistic regression (paper §5).

Feature dim matches the hashed rcv1 synthesis (repro.data.libsvm); the
benchmark layer instantiates variants for real-sim/news20 statistics.
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="paper-logreg",
    family="logreg",
    num_layers=0,
    d_model=0,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=0,
    num_features=2048,
    l2_reg=1e-4,
))
