"""Paper Table 2: lock vs unlock — per-scheme speedup over 1 thread.

The whole (scheme × thread-count) grid — plus the 1-thread baseline — runs
as ONE vectorized sweep (repro.core.sweep): a single jit compiles the epoch
body once and every configuration advances in lockstep, instead of one
compile + epochs×dispatch per cell. The delay engine gives each cell's
converged iterate (statistical behaviour) and the measured-cost throughput
model (benchmarks.cost_model) gives wall time. speedup(p) = wall(1)/wall(p)
with epochs inflated when staleness slows statistical progress (matching the
paper's "time to suboptimal solution" definition).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.artifacts import write_bench_json
from benchmarks.cost_model import measure_primitives, wall_time
from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm

SCHEMES = ("consistent", "inconsistent", "unlock")


def epochs_to_gap(history, f_star, max_epochs, gap=1e-4):
    gaps = np.asarray(history) - f_star
    hit = np.nonzero(gaps < gap)[0]
    return int(hit[0]) if len(hit) else max_epochs


def run(scale=0.03, step=2.0, threads=(2, 4, 8, 10), quick=False):
    ds = make_synthetic_libsvm("rcv1", scale=scale)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    prim = measure_primitives(obj, iters=50 if quick else 200)
    max_epochs = 12 if quick else 25

    # row 0 = the 1-thread baseline; rows 1.. = the scheme × threads grid
    specs = [SweepSpec(seed=0, scheme="consistent", step_size=step,
                       num_threads=1)]
    specs += [SweepSpec(seed=0, scheme=scheme, step_size=step,
                        num_threads=p, tau=p - 1)
              for scheme in SCHEMES for p in threads]
    t0 = time.perf_counter()
    res = run_sweep(obj, max_epochs, specs)
    sweep_s = time.perf_counter() - t0

    e1 = epochs_to_gap(res.histories[0], f_star, max_epochs)
    upd1 = int(res.total_updates[0]) // max_epochs
    base_wall = wall_time("unlock", e1 * upd1, 1, prim)  # p=1: no contention

    rows = []
    for c in range(1, len(specs)):
        s = res.specs[c]
        e = epochs_to_gap(res.histories[c], f_star, max_epochs)
        updp = int(res.total_updates[c]) // max_epochs
        wall = wall_time(s.scheme, e * updp, s.num_threads, prim)
        rows.append({
            "scheme": s.scheme, "threads": s.num_threads,
            "epochs_to_1e-4": e, "wall_s": wall,
            "speedup": base_wall / wall,
        })
    return {"rows": rows, "primitives": prim, "baseline_wall_s": base_wall,
            "sweep_s": sweep_s, "grid_size": len(specs)}


def main(quick=True):
    out = run(quick=quick)
    write_bench_json("table2_schemes", out)
    print("name,us_per_call,derived")
    print(f"table2_sweep_engine,{out['sweep_s'] * 1e6:.1f},"
          f"configs={out['grid_size']};one_jit_grid")
    for r in out["rows"]:
        print(f"table2_{r['scheme']}_p{r['threads']},"
              f"{r['wall_s'] * 1e6:.1f},speedup={r['speedup']:.2f}x"
              f";epochs={r['epochs_to_1e-4']}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
