"""RL005 — Pallas kernel bodies are pure.

``src/repro/kernels/*/kernel.py`` holds the Pallas megakernel bodies and
their `pallas_call` builders. Those modules are imported inside
`runner_key` (the fused-mode facet) and traced inside jit; anything
effectful there is either silently dropped by tracing (prints), breaks
interpret/compiled parity (host callbacks), or makes the compiled program
depend on ambient process state that the cache key cannot see
(environment sniffing — the exact hazard `_fused_mode_key` exists to
prevent: mode decisions belong in `repro.kernels.dispatch`, resolved at
KEY time, never inside a kernel module).

Flagged anywhere in a ``kernels/**/kernel.py`` file:

  * ``print(...)`` / ``breakpoint()`` — debugging leftovers; use
    ``pl.debug_print`` behind interpret mode, outside the shipped body;
  * host-callback escapes: ``jax.debug.print``, ``jax.debug.callback``,
    ``io_callback``, ``pure_callback``, ``host_callback.*``;
  * environment sniffing: ``os.environ``, ``os.getenv``,
    ``os.environ.get`` — route through ``kernels/dispatch``;
  * file I/O: ``open(...)``.
"""
from __future__ import annotations

import ast
from pathlib import PurePath
from typing import List

from repro.analysis.astutil import call_name, dotted_name
from repro.analysis.diagnostics import Diagnostic

_BANNED_CALLS = {
    "print": "stray print is dropped by tracing (or spams per trace)",
    "breakpoint": "debugger hook in a kernel module",
    "open": "file I/O in a kernel module",
    "jax.debug.print": "host callback breaks interpret/compiled parity",
    "jax.debug.callback": "host callback breaks interpret/compiled parity",
    "jax.experimental.io_callback": "host callback in a kernel body",
    "io_callback": "host callback in a kernel body",
    "jax.pure_callback": "host callback in a kernel body",
    "pure_callback": "host callback in a kernel body",
    "os.getenv": "env sniffing — mode decisions live in kernels/dispatch "
                 "so the cache key sees them",
}
# os.environ covers os.environ.get/[...] via the attribute check
_BANNED_NAMES = {
    "os.environ": "env sniffing — mode decisions live in kernels/dispatch "
                  "so the cache key sees them",
}


def _in_scope(path: str) -> bool:
    p = PurePath(path)
    return p.name == "kernel.py" and "kernels" in p.parts


def check(path: str, tree: ast.AST, source: str) -> List[Diagnostic]:
    if not _in_scope(path):
        return []
    out: List[Diagnostic] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = call_name(node)
            why = _BANNED_CALLS.get(name or "")
            if why is not None:
                out.append(Diagnostic(
                    path, node.lineno, "RL005",
                    f"impure `{name}(...)` in a Pallas kernel module — "
                    f"{why}"))
        elif isinstance(node, ast.Attribute):
            name = dotted_name(node)
            why = _BANNED_NAMES.get(name or "")
            if why is not None:
                out.append(Diagnostic(
                    path, node.lineno, "RL005",
                    f"impure `{name}` in a Pallas kernel module — {why}"))
    return out
