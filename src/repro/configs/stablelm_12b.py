"""stablelm-12b [dense] — GQA kv=8.
[hf:stabilityai/stablelm-2-1_6b (family); unverified]

40L, d_model=5120, 32 heads (kv=8), d_ff=13824, vocab=100352.
StableLM-2 family: partial rotary (25%), LayerNorm without biases on
projections; we keep rmsnorm=False→layernorm and partial RoPE.
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    rope_style="partial",
    rope_fraction=0.25,
    norm="layernorm",
    activation="silu",
    glu=True,
))
