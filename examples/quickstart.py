"""Quickstart: AsySVRG on the paper's own workload (logistic regression).

Reproduces the core claim in ~30 seconds on CPU: AsySVRG (all three reading
schemes) converges linearly and beats Hogwild! per effective pass. EVERY
scenario here runs in ONE `run_sweep` call on the multi-algorithm sweep
engine (repro.core.sweep): the three AsySVRG schemes, the serial-SVRG
baseline (``algo="svrg"``, the τ=0 degenerate case on the same engine), AND
the Hogwild! baseline (``algo="hogwild"``, γ-decay inside the compiled
scan) — the Hogwild! row carries its own 3× per-row ``epochs`` budget (1
pass/epoch vs AsySVRG's ~3) via the masked-epoch axis, so equal effective
passes no longer need a second call. Adding a scenario is one more
SweepSpec row — no new compiles, no new driver code. On a multi-device
host, pass ``mesh=make_sweep_mesh()`` to shard the rows across devices.

Serving sweeps: re-running grids is as cheap as running them — every
dispatch goes through the persistent compiled-runner cache
(`repro.service.cache`), so a second same-shape sweep compiles nothing,
and `repro.service.SweepService` coalesces many clients' specs into
shared compiled groups (see the "serving sweeps" section below and
examples/sweep_service.py for the full multi-tenant + checkpoint-resume
demo).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (LogisticRegression, SweepSpec, make_grid, run_sweep,
                        svrg_sweep_spec)
from repro.data.libsvm import make_synthetic_libsvm
from repro.service import SweepService, cache_stats


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.05)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    print(f"dataset rcv1-like: n={obj.n} p={obj.p}  f*={f_star:.6f}\n")

    # AsySVRG × 3 schemes + serial SVRG + pass-matched Hogwild!, one call:
    # 6 epochs × ~3 passes for the SVRG family, 18 × 1 for Hogwild!
    specs = make_grid(schemes=("consistent", "inconsistent", "unlock"),
                      seeds=(0,), step_sizes=(2.0,), taus=(9,),
                      num_threads=10)
    specs += [svrg_sweep_spec(step_size=2.0)]
    specs += [SweepSpec(algo="hogwild", scheme="unlock", step_size=2.0,
                        num_threads=10, tau=9, epochs=18)]
    res = run_sweep(obj, 6, specs)

    print(f"{'method':28s} {'passes':>7s} {'final gap':>12s}")
    for c, spec in enumerate(res.specs):
        name = {"svrg": "SVRG-serial",
                "hogwild": f"Hogwild!-{spec.scheme}"}.get(
                    spec.algo, f"AsySVRG-{spec.scheme}")
        passes, hist = res.curve(c)
        gap = hist[-1] - f_star
        print(f"{name:28s} {passes[-1]:7.0f} {gap:12.3e}")

    print("\nAsySVRG reaches a much smaller gap at EQUAL effective passes —")
    print("the paper's Figure 1 (right) in one table, from one compile-set.")

    # ---- serving sweeps: the same shapes again, as a service would run
    # them. Two clients probe around the winner; their 2+1 rows coalesce
    # into ONE 3-row compiled group — the exact shape the 3-scheme grid
    # above already compiled — so the flush fetches the cached runner and
    # compiles NOTHING.
    base = cache_stats()
    svc = SweepService(obj, epochs=6)
    rid_a = svc.submit(make_grid(schemes=("inconsistent",), seeds=(1, 2),
                                 step_sizes=(2.0,), taus=(9,),
                                 num_threads=10))
    rid_b = svc.submit(make_grid(schemes=("unlock",), seeds=(3,),
                                 step_sizes=(1.0,), taus=(9,),
                                 num_threads=10))
    svc.flush()
    s = svc.stats()

    def best_gap(res):
        return min(res.curve(c)[1][-1] - f_star
                   for c in range(len(res.specs)))

    gap_a = best_gap(svc.result(rid_a))
    gap_b = best_gap(svc.result(rid_b))
    print(f"\nserving sweeps: 2 clients, {s.rows_submitted} rows -> "
          f"{s.groups_dispatched} compiled group(s), "
          f"{s.rows_coalesced} rows coalesced, "
          f"{cache_stats().since(base).compiles} new compile(s)")
    print(f"  client A best gap {gap_a:.3e}, client B best gap {gap_b:.3e}"
          "  (each bit-identical to its own run_sweep)")


if __name__ == "__main__":
    main()
