"""(step × τ) stability frontier on NONCONVEX objectives — the pluggable-
objective protocol exercised beyond the paper's convex workload.

The nonconvex async-SVRG analyses (Huo & Huang 1604.03584, Reddi et al.
1506.06840) predict the same qualitative frontier as Theorem 1: staleness
shrinks the admissible step region, convex or not. This benchmark maps it
empirically for the smoothly-clipped-penalty logistic objective
(`repro.core.NonconvexLogistic`) on a libsvm-shaped set — a grid over step
sizes × τ as ONE `run_sweep`, each cell classified stable/diverged from its
loss history, reported per τ as the largest still-converging step.

A small MLP language-model edge (`mlp_lm_objective` — pytree params through
the SAME engine) rides in the report as a convergence record: per-step
final losses at a fixed τ, demonstrating the nonconvex/deep path end-to-end
at benchmark scale. The MLP rows run as their own sweep call (one sweep,
one objective); the clipped-penalty grid is the frontier proper.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.artifacts import write_bench_json
from repro.core import (NonconvexLogistic, SweepSpec, mlp_lm_objective,
                        run_sweep)
from repro.data.libsvm import make_synthetic_libsvm

P = 10
STEPS = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
TAUS = (0, 1, 3, 7, 9)
MLP_STEPS = (0.05, 0.1, 0.2)


def classify(history, f0: float) -> str:
    """stable = finite history that ends below the starting loss."""
    h = np.asarray(history, np.float64)
    if not np.all(np.isfinite(h)):
        return "diverged"
    return "stable" if h[-1] < f0 else "diverged"


def run(dataset: str = "rcv1", scale: float = 0.03, lam: float = 1e-3,
        alpha: float = 10.0, steps=STEPS, taus=TAUS, epochs: int = 6,
        quick: bool = False):
    if quick:
        steps = tuple(steps)[1::2]
        taus = tuple(taus)[::2]
        epochs = 3
    ds = make_synthetic_libsvm(dataset, scale=scale)
    obj = NonconvexLogistic(ds.X, ds.y, lam=lam, alpha=alpha)
    f0 = float(obj.loss(np.zeros(obj.p)))

    specs = []
    for tau in taus:
        for step in steps:
            if tau == 0:
                specs.append(SweepSpec(algo="svrg", step_size=step,
                                       num_threads=1))
            else:
                specs.append(SweepSpec(scheme="inconsistent", step_size=step,
                                       tau=tau, num_threads=P))
    t0 = time.perf_counter()
    res = run_sweep(obj, epochs, specs)
    sweep_s = time.perf_counter() - t0

    cells = []
    for c, spec in enumerate(res.specs):
        _, h = res.curve(c)
        verdict = classify(h, f0)
        final = float(h[-1])
        cells.append({"tau": spec.tau if spec.algo != "svrg" else 0,
                      "algo": spec.algo, "step": spec.step_size,
                      "final_loss": final if np.isfinite(final) else None,
                      "verdict": verdict})

    frontier = {}
    for tau in taus:
        stable = [c["step"] for c in cells
                  if c["tau"] == tau and c["verdict"] == "stable"]
        frontier[tau] = max(stable) if stable else 0.0

    # MLP LM edge: pytree params through the same engine, fixed τ
    mlp = mlp_lm_objective(n=32 if quick else 64, vocab_size=16, seq_len=4,
                           d_model=8, d_hidden=16)
    mlp_f0 = float(mlp.loss(mlp.init_params()))
    mlp_specs = [SweepSpec(scheme="inconsistent", step_size=st, tau=2,
                           num_threads=4, inner_steps=mlp.n)
                 for st in MLP_STEPS]
    t0 = time.perf_counter()
    mlp_res = run_sweep(mlp, max(2, epochs // 2), mlp_specs)
    mlp_s = time.perf_counter() - t0
    mlp_cells = [{"step": s.step_size, "tau": s.tau,
                  "final_loss": float(mlp_res.histories[c, -1]),
                  "verdict": classify(mlp_res.curve(c)[1], mlp_f0)}
                 for c, s in enumerate(mlp_res.specs)]

    return {"dataset": dataset, "f0": f0, "lam": lam, "alpha": alpha,
            "epochs": epochs, "grid_size": len(specs), "sweep_s": sweep_s,
            "devices": jax.device_count(),
            "cells": cells, "frontier": frontier,
            "mlp": {"f0": mlp_f0, "n": mlp.n, "sweep_s": mlp_s,
                    "cells": mlp_cells}}


def main(quick: bool = True):
    out = run(quick=quick)
    write_bench_json("nonconvex_frontier", out)
    print("name,us_per_call,derived")
    print(f"nonconvex_frontier_sweep,{out['sweep_s'] * 1e6:.1f},"
          f"cells={out['grid_size']};one_call_grid")
    for tau, step in out["frontier"].items():
        print(f"nonconvex_frontier_tau{tau},0,max_stable_step={step}")
    print(f"nonconvex_mlp_sweep,{out['mlp']['sweep_s'] * 1e6:.1f},"
          f"cells={len(out['mlp']['cells'])};pytree_params")
    for cell in out["mlp"]["cells"]:
        print(f"nonconvex_mlp_step{cell['step']},0,"
              f"final_loss={cell['final_loss']:.6f};{cell['verdict']}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
