"""Public wrapper for the fused SVRG update: pytree + padding handling.

`apply_tree` flattens every leaf to (rows, 128) tiles (zero-padded), runs the
kernel per leaf, and restores shapes. Mode selection (compiled / interpret /
jnp reference) goes through `repro.kernels.dispatch.kernel_mode` — the one
policy all kernels share.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import kernel_mode
from repro.kernels.svrg_update.kernel import (
    BLOCK_ROWS, LANES, svrg_update_2d)
from repro.kernels.svrg_update.ref import svrg_update_ref


def apply_leaf(u, g, g0, gf, lr, wd: float = 0.0, interpret: bool = False,
               force_kernel: bool = False):
    mode = kernel_mode(interpret, force_kernel)
    if mode == "reference":
        return svrg_update_ref(u, g, g0, gf, lr, wd)
    interpret = mode == "interpret"
    n = u.size
    tile = BLOCK_ROWS * LANES
    rows = -(-n // tile) * BLOCK_ROWS
    pad = rows * LANES - n

    def prep(x):
        return jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, LANES)

    lr_arr = jnp.full((1, 1), lr, jnp.float32)
    out = svrg_update_2d(prep(u), prep(g), prep(g0), prep(gf), lr_arr,
                         wd=wd, interpret=interpret)
    return out.reshape(-1)[:n].reshape(u.shape)


def apply_tree(params, g, g0, gf, lr, wd: float = 0.0,
               interpret: bool = False, force_kernel: bool = False):
    return jax.tree.map(
        lambda u, a, b, c: apply_leaf(u, a, b, c, lr, wd,
                                      interpret=interpret,
                                      force_kernel=force_kernel),
        params, g, g0, gf)
