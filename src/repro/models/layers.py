"""Shared neural-net layers (pure-function style, explicit param dicts).

Everything here is jit/pjit-safe and shape-polymorphic over batch/seq. The
attention implementation has two paths:

  * full einsum for short sequences (<= chunk threshold)
  * a q-chunked lax.scan ("flash-style" online softmax is NOT needed since we
    keep the full key length per chunk; chunking bounds the [Cq, S] score
    block so 32k-prefill activations fit HBM)

GQA is native: q heads grouped over kv heads. Masks are computed from
position vectors per block — an explicit [S, S] mask is never materialized.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding.context import constrain, constrain_heads_or_seq


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def apply_norm(cfg: ModelConfig, x, p: Dict):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_table(positions, dim: int, theta: float):
    """positions [..., S] -> (sin, cos) [..., S, dim/2], f32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, positions, cfg: ModelConfig):
    """x [B, S, N, H]; neox-style rotate-half on the first
    rope_fraction*head_dim dims (chatglm '2d rope' = fraction 0.5)."""
    if cfg.rope_style == "none":
        return x
    hd = x.shape[-1]
    rot = int(hd * cfg.rope_fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    sin, cos = rope_table(positions, rot, cfg.rope_theta)   # [B, S, rot/2]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _mask_bias(pos_q, pos_k, causal: bool, window, dtype):
    """Additive mask [B, 1, 1, Q, S] from position vectors [B,Q], [B,S].

    `window` may be a python int or a traced int32 scalar (scan-over-layers
    passes the per-layer local window; 0 means global)."""
    ok = pos_k[:, None, :] >= 0          # negative key position = padding
    if causal:
        ok &= pos_q[:, :, None] >= pos_k[:, None, :]
    window = jnp.asarray(window, jnp.int32)
    dist = pos_q[:, :, None] - pos_k[:, None, :]
    ok &= jnp.where(window > 0, dist < window, True)
    bias = jnp.where(ok, 0.0, -1e30).astype(dtype)
    return bias[:, None, None, :, :]


def _attend_block(q, k, v, bias, softcap: float = 0.0):
    """q [B,Q,K,G,h], k/v [B,S,K,h], bias [B,1,1,Q,S] -> [B,Q,K,G,h]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = scores.astype(jnp.float32) + bias.astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def attention(q, k, v, pos_q, pos_k, *, causal: bool = True,
              window: int = 0, chunk_q: int = 2048, softcap: float = 0.0):
    """GQA attention. q [B,Q,N,h] with N = K*G heads; k/v [B,S,K,h].

    For Q > chunk_q the query dim is scanned in blocks so the peak score
    buffer is [B,K,G,chunk,S] — the 32k-prefill memory-fit path.
    """
    B, Q, N, h = q.shape
    K = k.shape[2]
    G = N // K
    if Q > 1:
        # shard the f32 score tensors: by heads when divisible, else by seq
        q = constrain_heads_or_seq(q, "heads")
        k = constrain_heads_or_seq(k, "kv_heads")
        v = constrain_heads_or_seq(v, "kv_heads")
    qg = q.reshape(B, Q, K, G, h)

    if Q <= chunk_q:
        bias = _mask_bias(pos_q, pos_k, causal, window, jnp.float32)
        out = _attend_block(qg, k, v, bias, softcap)
        return out.reshape(B, Q, N, h)

    assert Q % chunk_q == 0, (Q, chunk_q)
    nchunks = Q // chunk_q
    if nchunks <= 4:
        # UNROLLED q-chunk loop (train path, 2 chunks): a lax.scan here
        # stacks per-chunk f32 residuals for the backward pass
        # (+16 GiB/device on qwen3 train_4k).
        outs = []
        for i in range(nchunks):
            qc = qg[:, i * chunk_q:(i + 1) * chunk_q]
            pqc = pos_q[:, i * chunk_q:(i + 1) * chunk_q]
            bias = _mask_bias(pqc, pos_k, causal, window, jnp.float32)
            outs.append(_attend_block(qc, k, v, bias, softcap))
        out = jnp.concatenate(outs, axis=1)
        return out.reshape(B, Q, N, h)

    # SCANNED q-chunk loop (32k prefill, 16 chunks, inference-only): unrolled
    # chunks let the scheduler hold many score blocks live (+29 GiB/device on
    # chatglm prefill_32k); the scan serializes them.
    qs = qg.reshape(B, nchunks, chunk_q, K, G, h).transpose(1, 0, 2, 3, 4, 5)
    pq = pos_q.reshape(B, nchunks, chunk_q).transpose(1, 0, 2)

    def body(_, inp):
        qc, pqc = inp
        bias = _mask_bias(pqc, pos_k, causal, window, jnp.float32)
        return None, _attend_block(qc, k, v, bias, softcap)

    _, outs = jax.lax.scan(body, None, (qs, pq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Q, K, G, h)
    return out.reshape(B, Q, N, h)


def gqa_project(x, p: Dict, cfg: ModelConfig, use_bias: bool):
    """x [B,S,d] -> q [B,S,N,h], k/v [B,S,K,h]."""
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if use_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def attn_output(out, p: Dict, use_bias: bool):
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    if use_bias:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def mlp(x, p: Dict, cfg: ModelConfig):
    if cfg.glu:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = _act(cfg.activation, gate) * up
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        if cfg.use_bias:
            h = h + p["b_up"]
        h = _act(cfg.activation, h)
    h = constrain(h, ("batch", None, "mlp"))
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    if cfg.use_bias:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes full [B,S,V] logits in f32)
# ---------------------------------------------------------------------------

def lm_loss(hidden, embed, targets, mask, *, chunk: int = 512,
            softcap: float = 0.0):
    """Mean token cross-entropy; logits computed seq-chunk-wise inside a scan
    so peak logits memory is [B, chunk, V]. The chunk body is rematerialized
    (otherwise the backward saves every chunk's [B,chunk,V] f32 logits —
    observed +4 GiB/device on gemma3's 262k vocab)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S  # fallback: single block
    n = S // chunk
    hs = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        h, t, m = inp
        # constrain INSIDE the scan so the embedding-grad loop accumulator
        # inherits the vocab sharding (else it is a replicated f32 [V, D])
        emb = constrain(embed, ("vocab", None))
        logits = jnp.einsum("bsd,vd->bsv", h, emb).astype(jnp.float32)
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    body = jax.checkpoint(body, prevent_cse=False)

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)
