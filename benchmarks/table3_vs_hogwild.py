"""Paper Table 3: AsySVRG vs Hogwild! — time to gap < 1e-4 at 10 threads,
on the three (synthesized) paper datasets.

Both halves of every comparison now run on the multi-algorithm sweep
engine: per dataset, the two AsySVRG rows AND the two Hogwild! rows go into
ONE `run_sweep` call (one jit per M̃-group — the baseline no longer pays
N×compile in a per-config Python loop). `measure_baseline_speedup` times
exactly that: the Hogwild! baseline grid through the sweep vs the
per-config `run_hogwild` loop, and reports the wall-clock ratio
(acceptance: ≥ 4× on CPU).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.artifacts import write_bench_json
from benchmarks.cost_model import measure_primitives, wall_time
from repro.core import (LogisticRegression, SweepSpec, run_hogwild,
                        run_sweep)
from repro.data.libsvm import make_synthetic_libsvm

P = 10
GAP = 1e-4


def _wall_from_history(history, total_updates, f_star, prim, scheme,
                       max_epochs):
    gaps = np.asarray(history) - f_star
    hit = np.nonzero(gaps < GAP)[0]
    if len(hit) == 0:
        return float("inf"), max_epochs
    epochs = int(hit[0])
    upd = int(total_updates) // max_epochs
    return wall_time(scheme, epochs * upd, P, prim), epochs


def measure_baseline_speedup(obj: LogisticRegression, epochs: int = 3,
                             seeds=tuple(range(10)),
                             schemes=("inconsistent", "unlock")) -> dict:
    """Sweep-Hogwild! vs the per-config `run_hogwild` loop on one grid.

    Both paths compute bit-identical histories (test-enforced); the sweep
    pays ONE compile for the whole (scheme × seed) grid, the loop pays one
    per config — measured ~4.9× on a 20-config grid on CPU.
    """
    specs = [SweepSpec(algo="hogwild", seed=s, scheme=sc, step_size=2.0,
                       num_threads=P, tau=P - 1)
             for sc in schemes for s in seeds]
    t0 = time.perf_counter()
    run_sweep(obj, epochs, specs)
    sweep_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    for spec in specs:
        run_hogwild(obj, epochs, spec.step_size, num_threads=spec.num_threads,
                    scheme=spec.scheme, tau=spec.tau, seed=spec.seed)
    loop_s = time.perf_counter() - t0
    return {"configs": len(specs), "epochs": epochs, "sweep_s": sweep_s,
            "loop_s": loop_s, "speedup": loop_s / sweep_s}


def run(scale=0.03, quick=False):
    rows = []
    max_e = 10 if quick else 30
    obj_first = None
    for name in ("rcv1", "real-sim", "news20"):
        ds = make_synthetic_libsvm(name, scale=scale)
        obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
        obj_first = obj_first or obj
        _, f_star = obj.optimum(max_iter=3000)
        prim = measure_primitives(obj, iters=50 if quick else 100)

        # all four rows in one sweep call: 2 groups (asysvrg M̃=2n-ish,
        # hogwild M̃=(n//p)p), each ONE jit
        methods = {"asysvrg-lock": ("asysvrg", "inconsistent"),
                   "asysvrg-unlock": ("asysvrg", "unlock"),
                   "hogwild-lock": ("hogwild", "inconsistent"),
                   "hogwild-unlock": ("hogwild", "unlock")}
        specs = [SweepSpec(algo=algo, seed=0, scheme=scheme, step_size=2.0,
                           num_threads=P, tau=P - 1)
                 for algo, scheme in methods.values()]
        res = run_sweep(obj, max_e, specs)
        for c, kind in enumerate(methods):
            t, e = _wall_from_history(res.histories[c], res.total_updates[c],
                                      f_star, prim, specs[c].scheme, max_e)
            rows.append({"dataset": name, "method": kind,
                         "wall_s": t, "epochs": e})

    speedup = measure_baseline_speedup(obj_first, epochs=2 if quick else 3)
    return {"rows": rows, "baseline_grid_speedup": speedup}


def main(quick=True):
    out = run(quick=quick)
    write_bench_json("table3_vs_hogwild", out)
    print("name,us_per_call,derived")
    for r in out["rows"]:
        wall = r["wall_s"]
        print(f"table3_{r['dataset']}_{r['method']},"
              f"{(wall * 1e6 if np.isfinite(wall) else -1):.1f},"
              f"epochs={r['epochs']}")
    sp = out["baseline_grid_speedup"]
    print(f"table3_baseline_grid_sweep,{sp['sweep_s'] * 1e6:.1f},"
          f"configs={sp['configs']};speedup_vs_loop={sp['speedup']:.1f}x")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
