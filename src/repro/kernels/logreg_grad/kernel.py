"""Pallas TPU kernel: fused logistic-regression gradient — the paper's own
inner-loop hot spot, adapted from the CPU original's sparse CSR loop to
dense MXU tiles (DESIGN.md §8).

Two blocked passes over X (the only O(n·p) data):

  pass 1 (margins):  z_b = X[b,:] @ w        — grid (nB, nP), accumulate over
                     p-blocks into z scratch; on the last p-block apply the
                     elementwise σ to produce c_b = −y_b·σ(−y_b z_b)/B.
  pass 2 (gradient): g_p = Σ_b X[b,p]ᵀ c_b   — grid (nP, nB) accumulating
                     over batch blocks in VMEM scratch.

λw is added by ops.py (O(p), not worth a pass). Tiles (128, 512) keep each
operand block ≤ 256 KiB VMEM and feed the MXU 128-lane contractions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import tpu_compiler_params

BLOCK_B = 128
BLOCK_P = 512


def _margin_kernel(x_ref, w_ref, y_ref, c_ref, z_scr, *, np_blocks: int,
                   inv_b: float):
    pj = pl.program_id(1)

    @pl.when(pj == 0)
    def _init():
        z_scr[...] = jnp.zeros_like(z_scr)

    x = x_ref[...].astype(jnp.float32)            # [bB, bP]
    w = w_ref[...].astype(jnp.float32)            # [bP, 1]
    z_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pj == np_blocks - 1)
    def _finish():
        y = y_ref[...].astype(jnp.float32)        # [bB, 1]
        s = jax.nn.sigmoid(-y * z_scr[...])
        c_ref[...] = (-y * s * inv_b).astype(c_ref.dtype)


def margins(X, y, w, interpret: bool = False):
    """X [B, P], y [B, 1], w [P, 1] -> c [B, 1] with c = −y σ(−y Xw)/B."""
    B, P = X.shape
    assert B % BLOCK_B == 0 and P % BLOCK_P == 0, (B, P)
    nB, nP = B // BLOCK_B, P // BLOCK_P
    return pl.pallas_call(
        functools.partial(_margin_kernel, np_blocks=nP, inv_b=1.0 / B),
        grid=(nB, nP),
        in_specs=[
            pl.BlockSpec((BLOCK_B, BLOCK_P), lambda i, j: (i, j)),
            pl.BlockSpec((BLOCK_P, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_B, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BLOCK_B, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, w, y)


def _grad_kernel(x_ref, c_ref, g_ref, acc_scr, *, nb_blocks: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)            # [bB, bP]
    c = c_ref[...].astype(jnp.float32)            # [bB, 1]
    acc_scr[...] += jax.lax.dot_general(
        x, c, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(bi == nb_blocks - 1)
    def _finish():
        g_ref[...] = acc_scr[...].astype(g_ref.dtype)


def grad_accum(X, c, interpret: bool = False):
    """X [B, P], c [B, 1] -> g [P, 1] = Xᵀ c (blocked over batch)."""
    B, P = X.shape
    nB, nP = B // BLOCK_B, P // BLOCK_P
    return pl.pallas_call(
        functools.partial(_grad_kernel, nb_blocks=nB),
        grid=(nP, nB),
        in_specs=[
            pl.BlockSpec((BLOCK_B, BLOCK_P), lambda j, i: (i, j)),
            pl.BlockSpec((BLOCK_B, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_P, 1), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((P, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BLOCK_P, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, c)
