"""Paper Figure 1 (left column): speedup vs thread count for AsySVRG
(lock = inconsistent reading / unlock) under the measured-cost model."""
from __future__ import annotations

from benchmarks.table2_schemes import run as run_table2


def run(quick=False):
    out = run_table2(threads=(1, 2, 4, 6, 8, 10), quick=quick)
    return out


def main(quick=True):
    out = run(quick=quick)
    print("name,us_per_call,derived")
    for r in out["rows"]:
        print(f"fig1_speedup_{r['scheme']}_p{r['threads']},"
              f"{r['wall_s'] * 1e6:.1f},speedup={r['speedup']:.2f}x")


if __name__ == "__main__":
    main(quick=False)
