"""repro-lint checkers, one module per rule code."""
from repro.analysis.rules import (  # noqa: F401
    rl001_stability,
    rl002_trace,
    rl003_locks,
    rl004_keys,
    rl005_kernel,
    rl006_obs,
)

FILE_CHECKERS = (
    rl001_stability.check,
    rl002_trace.check,
    rl003_locks.check,
    rl005_kernel.check,
    rl006_obs.check,
)

PROJECT_CHECKERS = (
    rl004_keys.check_project,
)
