"""repro.obs — observability for the sweep stack.

Four stdlib-only pieces plus two numeric ones:

  * `repro.obs.trace` — the request-lifecycle flight recorder: bounded
    ring buffer of monotonic-clock span trees, one trace id per request,
    threaded submit -> plan -> coalesce -> pad -> dispatch -> execute ->
    demux -> result. Served at ``GET /trace``.
  * `repro.obs.metrics` — cumulative histograms (flush/request latency,
    rows-per-flush, pad-factor) the service records on every flush.
  * `repro.obs.prometheus` — text-exposition rendering of the existing
    ``/stats`` snapshot dict + the histograms, served at ``GET /metrics``.
  * `repro.obs.progress` — bounded live-progress bus: per-slice loss
    events published from ``run_job`` slice boundaries and completed
    flushes, consumed via ``GET /watch`` with cursor-based resume.
  * `repro.obs.telemetry` — opt-in per-row realized-staleness and
    update-norm series, recomputed OUTSIDE the jitted group fn from
    already-returned arrays (imports jax; import it explicitly, never
    from this package root, so the tracer stays importable in the
    stdlib-only repro-lint lane).
  * `repro.obs.watchdog` / `repro.obs.ledger` — divergence watchdog and
    per-group performance ledger (import numpy / the roofline model;
    import them explicitly for the same reason as telemetry).

House rule (repro-lint RL006): none of these APIs may be called inside a
``*_core`` jitted scope or a ``kernels/**/kernel.py`` module —
observability brackets compiled programs, it never runs inside them.
"""
from repro.obs.metrics import Histogram, ServiceHistograms
from repro.obs.progress import (
    ProgressBus,
    ProgressEvent,
    disable_progress,
    enable_progress,
    progress_bus,
    progress_enabled,
)
from repro.obs.trace import (
    Span,
    Tracer,
    disable_tracing,
    enable_tracing,
    tracer,
)

__all__ = [
    "Histogram",
    "ServiceHistograms",
    "ProgressBus",
    "ProgressEvent",
    "Span",
    "Tracer",
    "disable_progress",
    "disable_tracing",
    "enable_progress",
    "enable_tracing",
    "progress_bus",
    "progress_enabled",
    "tracer",
]
