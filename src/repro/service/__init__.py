"""Sweep service: persistent compiled-runner cache + request coalescing.

Three layers turn the one-jit-per-group sweep engine (`repro.core.sweep`)
from a benchmark harness into a multi-tenant sweep server:

  * `repro.service.cache` — module-level compiled-runner cache (the
    ROADMAP "sweep-group runner cache" item): runners keyed on the static
    group dims + data shape, hit/miss/compile counters, zero recompilation
    for repeated same-shape sweeps.
  * `repro.service.scheduler` — request coalescing: many clients' spec
    rows merged into shared compiled groups, demuxed bit-identically.
  * `repro.service.api` — the `SweepService` front-end (submit / flush /
    result, `ServiceStats`) plus checkpoint-resumable jobs.
"""
from repro.service.api import ResultEvictedError, ServiceStats, SweepService
from repro.service.cache import (
    CacheStats,
    cache_size,
    cache_stats,
    clear_cache,
    get_group_runner,
    scoped_counters,
    set_cache_limit,
)
from repro.service.scheduler import (
    CoalescedBatch,
    DispatchInfo,
    FlushSelector,
    SweepRequest,
    WidthPolicy,
    coalesce,
    dispatch,
)

__all__ = [
    "SweepService",
    "ServiceStats",
    "ResultEvictedError",
    "CacheStats",
    "cache_stats",
    "cache_size",
    "clear_cache",
    "set_cache_limit",
    "scoped_counters",
    "get_group_runner",
    "SweepRequest",
    "CoalescedBatch",
    "DispatchInfo",
    "FlushSelector",
    "WidthPolicy",
    "coalesce",
    "dispatch",
]
