"""Fused Pallas sweep-epoch megakernel parity suite.

Contract under test: with ``engine_mode="fused"`` the sweep engine runs
each group as ONE Pallas launch (config rows on the grid) executing the
SAME per-row epochs-scan functions the vmap engine batches — so under the
Pallas interpreter (every backend in this container) the fused path is
BIT-IDENTICAL to the vmap path: per row, per algo, across group widths,
masked per-row epoch budgets and pytree objectives, and all the way back
to the pre-refactor regression pin. Compiled Mosaic lowering (TPU) is NOT
covered here — see the ROADMAP real-accelerator revalidation item.

Also pins the plumbing that keeps the two engines from cross-serving each
other's programs: the group key carries the resolved engine mode (fused
LAST, key_[0] stays the objective fingerprint), the service runner cache
keys fused bodies separately, and ``REPRO_SWEEP_ENGINE`` /
``REPRO_KERNEL_MODE`` env selection validates and resolves as documented.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import (LogisticRegression, SweepSpec, mlp_lm_objective,
                        plan_sweep, run_asysvrg, run_sweep)
from repro.core.sweep import default_engine_mode
from repro.data.libsvm import make_synthetic_libsvm
from repro.kernels import dispatch
from repro.service.cache import runner_key

PIN_DIR = os.path.join(os.path.dirname(__file__), "data")
SCHEMES = ("consistent", "inconsistent", "unlock")


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def _fused(specs):
    return [dataclasses.replace(s, engine_mode="fused") for s in specs]


def _assert_same(res_a, res_b):
    np.testing.assert_array_equal(res_a.histories, res_b.histories)
    np.testing.assert_array_equal(res_a.final_w, res_b.final_w)
    np.testing.assert_array_equal(res_a.effective_passes,
                                  res_b.effective_passes)
    np.testing.assert_array_equal(res_a.total_updates, res_b.total_updates)
    np.testing.assert_array_equal(res_a.epochs_per_row, res_b.epochs_per_row)


# ------------------------------------------------------------- bit parity
def test_fused_matches_vmap_all_algos(obj):
    """Acceptance: fused == vmap bit-exact for every engine and read scheme
    in one mixed grid (asysvrg x 3 schemes, hogwild, serial svrg)."""
    specs = [SweepSpec(scheme=s, step_size=0.1, tau=2, num_threads=4,
                       inner_steps=20, seed=i)
             for i, s in enumerate(SCHEMES)]
    specs += [SweepSpec(algo="hogwild", scheme="consistent", step_size=0.1,
                        tau=2, num_threads=3, seed=3),
              SweepSpec(algo="svrg", step_size=0.1, inner_steps=25, seed=4)]
    _assert_same(run_sweep(obj, 2, specs), run_sweep(obj, 2, _fused(specs)))


@pytest.mark.parametrize("rows", [1, 3, 8])
def test_fused_group_widths(obj, rows):
    """One-row groups, odd widths and vector-width multiples all hit the
    same grid mapping: fused == vmap bit-exact at every group width."""
    specs = [SweepSpec(scheme=SCHEMES[c % 3], step_size=0.2, tau=3,
                       num_threads=4, inner_steps=15, seed=c)
             for c in range(rows)]
    _assert_same(run_sweep(obj, 2, specs), run_sweep(obj, 2, _fused(specs)))


def test_fused_masked_row_epochs_match_shorter_runs(obj):
    """Masked per-row epoch budgets inside one fused launch: each row is
    bit-equal to an independent sequential run of its own length (the same
    freeze contract the vmap engine pins)."""
    specs = [SweepSpec(scheme="inconsistent", step_size=0.2, tau=3,
                       num_threads=4, inner_steps=20, seed=7, epochs=e)
             for e in (1, 2, 3)]
    res = run_sweep(obj, 3, _fused(specs))
    for c, spec in enumerate(specs):
        seq = run_asysvrg(obj, spec.epochs, spec.to_config(), seed=7)
        np.testing.assert_array_equal(
            np.asarray(seq.history, np.float32),
            res.histories[c, :spec.epochs + 1])
        np.testing.assert_array_equal(np.asarray(seq.w, np.float32),
                                      res.final_w[c])


@pytest.mark.nonconvex
def test_fused_pytree_objective_matches_vmap():
    """The megakernel is objective-generic: the MLP LM pytree workload
    (multi-arg data tuple, flattened params) runs fused == vmap bit-exact,
    and `final_params` rebuilds the same tree."""
    mlp = mlp_lm_objective(n=16, vocab_size=16, seq_len=4, d_model=8,
                           d_hidden=8)
    specs = [SweepSpec(scheme=SCHEMES[c % 3], step_size=0.1, tau=2,
                       num_threads=3, inner_steps=10, seed=c)
             for c in range(3)]
    specs.append(SweepSpec(algo="hogwild", scheme="consistent",
                           step_size=0.1, tau=2, num_threads=3, seed=9))
    base = run_sweep(mlp, 2, specs)
    fused = run_sweep(mlp, 2, _fused(specs))
    _assert_same(base, fused)
    np.testing.assert_array_equal(
        np.asarray(mlp.as_flat(fused.final_params(0))), fused.final_w[0])


def test_fused_reproduces_prerefactor_regression_pin(obj, monkeypatch):
    """Acceptance (strongest parity statement): the fused path reproduces
    the PRE-refactor engine pin bit-for-bit — the same frozen numbers the
    vmap engine is held to, two engine generations back.

    The pin certifies the DEFAULT kernel config, so $REPRO_KERNEL_MODE is
    cleared for this test (the CI kernels-interpret job exports it, which
    would route the inner svrg-update op through the Pallas interpreter —
    ~1-ulp off the reference path the pin was frozen on) and the runner
    cache is dropped (vmap runner keys don't carry the kernel-mode env, so
    a runner traced under the exported env would otherwise be reused)."""
    from repro.service import clear_cache
    monkeypatch.delenv(dispatch.KERNEL_MODE_ENV, raising=False)
    clear_cache()
    with open(os.path.join(PIN_DIR, "sweep_regression_pin.json")) as fh:
        pin = json.load(fh)
    specs = _fused([SweepSpec(**d) for d in pin["specs"]])
    res = run_sweep(obj, pin["epochs"], specs)
    np.testing.assert_array_equal(
        res.histories, np.asarray(pin["histories"], np.float32))
    np.testing.assert_array_equal(
        res.final_w, np.asarray(pin["final_w"], np.float32))
    np.testing.assert_array_equal(
        res.effective_passes, np.asarray(pin["effective_passes"], np.float64))
    np.testing.assert_array_equal(
        res.total_updates, np.asarray(pin["total_updates"], np.int64))


# ------------------------------------------------- engine-mode selection
def test_engine_mode_validates_at_plan_time(obj):
    with pytest.raises(ValueError, match="engine_mode"):
        plan_sweep(obj, 2, [SweepSpec(engine_mode="bogus")])


def test_engine_mode_defaults_from_env(obj, monkeypatch):
    """Unset specs inherit $REPRO_SWEEP_ENGINE; explicit engine_mode wins;
    a bad env value raises rather than silently running vmap."""
    monkeypatch.setenv("REPRO_SWEEP_ENGINE", "fused")
    assert default_engine_mode() == "fused"
    plan = plan_sweep(obj, 2, [SweepSpec(inner_steps=10)])
    assert all(k[-1] for k in plan.groups)          # fused flag set
    assert plan.specs[0].engine_mode == "fused"
    plan = plan_sweep(obj, 2, [SweepSpec(inner_steps=10,
                                         engine_mode="vmap")])
    assert not any(k[-1] for k in plan.groups)
    monkeypatch.setenv("REPRO_SWEEP_ENGINE", "turbo")
    with pytest.raises(ValueError, match="REPRO_SWEEP_ENGINE"):
        default_engine_mode()


def test_fused_and_vmap_rows_split_groups(obj):
    """Mixed engine modes in one sweep plan into separate groups whose keys
    differ ONLY in the trailing fused flag — key_[0] (the objective
    fingerprint the service scheduler pools on) is unperturbed."""
    specs = [SweepSpec(inner_steps=10, seed=0, engine_mode="vmap"),
             SweepSpec(inner_steps=10, seed=1, engine_mode="fused")]
    plan = plan_sweep(obj, 2, specs)
    keys = sorted(plan.groups, key=lambda k: k[-1])
    assert len(keys) == 2
    assert keys[0][:-1] == keys[1][:-1]
    assert [k[-1] for k in keys] == [False, True]
    assert keys[0][0] == obj.fingerprint()
    # ...and the mixed plan still computes both rows bit-equal to vmap
    base = run_sweep(obj, 2, [dataclasses.replace(s, engine_mode="vmap")
                              for s in specs])
    _assert_same(base, run_sweep(obj, 2, specs))


def test_runner_cache_keys_fused_separately(obj):
    """The persistent runner cache can never serve a vmap body to a fused
    group (or vice versa), and the fused key pins the RESOLVED kernel
    mode so flipping REPRO_KERNEL_MODE mid-process re-keys."""
    common = dict(group_epochs=2, total=10, option=2, buf_len=4,
                  drop_prob=0.02, mesh=None, obj=obj)
    k_vmap = runner_key("asysvrg", **common)
    k_fused = runner_key("asysvrg", fused=True, **common)
    assert k_vmap != k_fused
    assert k_vmap[-1] is None
    assert k_fused[-1] == dispatch.fused_sweep_mode()


# ------------------------------------------------- unified kernel dispatch
def test_kernel_mode_env_override_wins(monkeypatch):
    """$REPRO_KERNEL_MODE beats flags and backend sniff for ALL kernels;
    the fused sweep mode degrades 'reference' to 'interpret' (the vmap
    engine is its reference); bad values raise."""
    monkeypatch.setenv(dispatch.KERNEL_MODE_ENV, "interpret")
    assert dispatch.kernel_mode() == "interpret"
    assert dispatch.kernel_mode(force_kernel=True) == "interpret"
    assert dispatch.fused_sweep_mode() == "interpret"
    monkeypatch.setenv(dispatch.KERNEL_MODE_ENV, "reference")
    assert dispatch.kernel_mode(interpret=True, force_kernel=True) \
        == "reference"
    assert dispatch.fused_sweep_mode() == "interpret"
    monkeypatch.setenv(dispatch.KERNEL_MODE_ENV, "warp")
    with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
        dispatch.kernel_mode()


def test_kernel_mode_historical_contract(monkeypatch):
    """Without the env var the unified helper reproduces the historical
    per-kernel behaviour: kernel body iff force_kernel or TPU backend,
    interpreter iff asked."""
    monkeypatch.delenv(dispatch.KERNEL_MODE_ENV, raising=False)
    monkeypatch.setattr(dispatch, "kernel_backend", lambda: "cpu")
    assert dispatch.kernel_mode() == "reference"
    assert dispatch.kernel_mode(interpret=True) == "reference"
    assert dispatch.kernel_mode(interpret=True, force_kernel=True) \
        == "interpret"
    assert dispatch.kernel_mode(force_kernel=True) == "compiled"
    assert dispatch.fused_sweep_mode() == "interpret"
    monkeypatch.setattr(dispatch, "kernel_backend", lambda: "tpu")
    assert dispatch.kernel_mode() == "compiled"
    assert dispatch.kernel_mode(interpret=True) == "interpret"
    assert dispatch.fused_sweep_mode() == "compiled"
