"""Quickstart: AsySVRG on the paper's own workload (logistic regression).

Reproduces the core claim in ~30 seconds on CPU: AsySVRG (all three reading
schemes) converges linearly and beats Hogwild! per effective pass. The three
scheme runs execute as ONE vectorized sweep — a single jit-compiled grid —
via repro.core.sweep; adding a scenario is one more SweepSpec row.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (LogisticRegression, make_grid, run_hogwild,
                        run_sweep)
from repro.data.libsvm import make_synthetic_libsvm


def main():
    ds = make_synthetic_libsvm("rcv1", scale=0.05)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    print(f"dataset rcv1-like: n={obj.n} p={obj.p}  f*={f_star:.6f}\n")

    specs = make_grid(schemes=("consistent", "inconsistent", "unlock"),
                      seeds=(0,), step_sizes=(2.0,), taus=(9,),
                      num_threads=10)
    res = run_sweep(obj, 6, specs)

    print(f"{'method':28s} {'passes':>7s} {'final gap':>12s}")
    for c, spec in enumerate(specs):
        gap = res.histories[c][-1] - f_star
        print(f"AsySVRG-{spec.scheme:20s} {res.effective_passes[c][-1]:7.0f} "
              f"{gap:12.3e}")

    hog = run_hogwild(obj, epochs=18, step_size=2.0, num_threads=10)
    gap = hog.history[-1] - f_star
    print(f"{'Hogwild!-unlock':28s} {hog.effective_passes[-1]:7.0f} "
          f"{gap:12.3e}")
    print("\nAsySVRG reaches a much smaller gap at EQUAL effective passes —")
    print("the paper's Figure 1 (right) in one table.")


if __name__ == "__main__":
    main()
