"""Model factory: dispatch ModelConfig.family -> family module, and build
uniform (loss_fn, prefill, decode_step, param_defs, cache_defs, input_specs)
bundles consumed by the train loop, serve loop and dry-run driver.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.config import ModelConfig, ShapeConfig
from repro.sharding.rules import batch_pspec


@dataclass
class ModelBundle:
    cfg: ModelConfig
    param_defs: Any                      # ParamDef pytree
    loss_fn: Callable                    # (params, batch) -> scalar
    prefill_fn: Optional[Callable]       # (params, batch, cache_len) -> (logits, cache)
    decode_fn: Optional[Callable]        # (params, cache, tokens, pos) -> (logits, cache)
    cache_defs: Optional[Callable]       # (batch, seq) -> ParamDef pytree
    input_specs: Callable                # (shape_cfg, mesh) -> batch of ShapeDtypeStructs
    make_inputs: Callable                # (shape_cfg, key) -> concrete small batch


def _lm_inputs(cfg: ModelConfig, b: int, s: int, mesh=None, concrete=False,
               key=None, extra: Dict = None):
    """Token batch (+ modality stubs) as ShapeDtypeStructs or concrete arrays."""
    def mk(shape, dtype, maxval=None):
        if concrete:
            if dtype == jnp.int32:
                return jax.random.randint(key, shape, 0, maxval or cfg.vocab_size)
            return jnp.ones(shape, dtype)
        sharding = None
        if mesh is not None:
            sharding = NamedSharding(mesh, batch_pspec(mesh))
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)

    batch = {
        "tokens": mk((b, s), jnp.int32),
        "targets": mk((b, s), jnp.int32),
        "mask": mk((b, s), jnp.float32),
    }
    for name, shape in (extra or {}).items():
        batch[name] = mk((b,) + shape, jnp.bfloat16 if not concrete else jnp.float32)
    return batch


def _modality_extra(cfg: ModelConfig) -> Dict:
    """Stub frontend tensors supplied by the input pipeline (see DESIGN §5)."""
    if cfg.family == "encdec":
        return {"enc_feats": (cfg.encoder_seq, cfg.encoder_feature_dim)}
    if cfg.family == "vlm":
        return {"image_embeds": (cfg.num_image_tokens, cfg.image_embed_dim)}
    return {}


def build_model(cfg: ModelConfig) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense",):
        from repro.models import transformer as mod
    elif fam == "moe":
        from repro.models import moe as mod
    elif fam == "encdec":
        from repro.models import encdec as mod
    elif fam == "vlm":
        from repro.models import vlm as mod
    elif fam == "hybrid":
        from repro.models import rglru as mod
    elif fam == "ssm":
        from repro.models import mamba as mod
    elif fam == "logreg":
        return _build_logreg(cfg)
    else:
        raise ValueError(f"unknown family {fam!r}")

    extra = _modality_extra(cfg)
    act_dtype = jnp.dtype(cfg.dtype)

    def _cast(params):
        """f32 master params -> activation-dtype compute copies (the cast is
        inside the grad, so gradients come back in f32)."""
        return jax.tree.map(
            lambda x: x.astype(act_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

    def loss_fn(params, batch):
        return mod.loss_fn(cfg, _cast(params), batch)

    def prefill_fn(params, batch, cache_len):
        params = _cast(params)
        if fam == "encdec":
            return mod.prefill(cfg, params, batch["enc_feats"],
                               batch["tokens"], cache_len)
        if fam == "vlm":
            return mod.prefill(cfg, params, batch["tokens"],
                               batch["image_embeds"], cache_len)
        return mod.prefill(cfg, params, batch["tokens"], cache_len)

    def decode_fn(params, cache, tokens, pos):
        return mod.decode_step(cfg, _cast(params), cache, tokens, pos)

    def cache_defs(batch, seq):
        return mod.cache_defs(cfg, batch, seq)

    def input_specs(shape_cfg: ShapeConfig, mesh=None):
        return _lm_inputs(cfg, shape_cfg.global_batch, shape_cfg.seq_len,
                          mesh=mesh, extra=extra)

    def make_inputs(shape_cfg: ShapeConfig, key):
        return _lm_inputs(cfg, shape_cfg.global_batch, shape_cfg.seq_len,
                          concrete=True, key=key, extra=extra)

    return ModelBundle(cfg=cfg, param_defs=mod.param_defs(cfg),
                       loss_fn=loss_fn, prefill_fn=prefill_fn,
                       decode_fn=decode_fn, cache_defs=cache_defs,
                       input_specs=input_specs, make_inputs=make_inputs)


# ---------------------------------------------------------------------------
# The paper's own workload as a "model": logistic regression
# ---------------------------------------------------------------------------

def _build_logreg(cfg: ModelConfig) -> ModelBundle:
    from repro.sharding.rules import ParamDef

    defs = {"w": ParamDef((cfg.num_features,), ("features",), "zeros")}

    def loss_fn(params, batch):
        w = params["w"]
        margins = batch["y"] * (batch["X"] @ w)
        return (jnp.mean(jnp.logaddexp(0.0, -margins))
                + 0.5 * cfg.l2_reg * jnp.vdot(w, w))

    def input_specs(shape_cfg: ShapeConfig, mesh=None):
        b = shape_cfg.global_batch
        sharding = None
        if mesh is not None:
            sharding = NamedSharding(mesh, batch_pspec(mesh))
        return {
            "X": jax.ShapeDtypeStruct((b, cfg.num_features), jnp.float32,
                                      sharding=sharding),
            "y": jax.ShapeDtypeStruct((b,), jnp.float32, sharding=sharding),
        }

    def make_inputs(shape_cfg: ShapeConfig, key):
        b = shape_cfg.global_batch
        return {"X": jax.random.normal(key, (b, cfg.num_features)),
                "y": jnp.sign(jax.random.normal(key, (b,)) + 0.1)}

    return ModelBundle(cfg=cfg, param_defs=defs, loss_fn=loss_fn,
                       prefill_fn=None, decode_fn=None, cache_defs=None,
                       input_specs=input_specs, make_inputs=make_inputs)
