from repro.sharding.rules import (
    ParamDef,
    DEFAULT_RULES,
    logical_to_pspec,
    defs_to_shardings,
    defs_to_shape_structs,
    init_from_defs,
    batch_pspec,
    act_sharding_constraint,
)

__all__ = [
    "ParamDef",
    "DEFAULT_RULES",
    "logical_to_pspec",
    "defs_to_shardings",
    "defs_to_shape_structs",
    "init_from_defs",
    "batch_pspec",
    "act_sharding_constraint",
]
