from repro.kernels.svrg_update import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
