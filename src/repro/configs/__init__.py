from repro.configs.registry import get_config, list_configs, reduced_config

__all__ = ["get_config", "list_configs", "reduced_config"]
