"""Paper Figure 1 (right column): objective gap vs effective passes —
AsySVRG (lock/unlock, 10 threads) vs Hogwild! (lock/unlock, 10 threads).

The two AsySVRG curves come from one vectorized sweep (repro.core.sweep)."""
from __future__ import annotations

import numpy as np

from repro.core import (LogisticRegression, SweepSpec, run_hogwild,
                        run_sweep)
from repro.data.libsvm import make_synthetic_libsvm

P = 10


def run(dataset="rcv1", scale=0.03, epochs=8, quick=False):
    if quick:
        epochs = 4
    ds = make_synthetic_libsvm(dataset, scale=scale)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    _, f_star = obj.optimum(max_iter=3000)
    curves = {}
    specs = [SweepSpec(seed=0, scheme=scheme, step_size=2.0, num_threads=P,
                       tau=P - 1)
             for scheme in ("inconsistent", "unlock")]
    res = run_sweep(obj, epochs, specs)
    for c, spec in enumerate(specs):
        curves[f"asysvrg-{spec.scheme}"] = (
            tuple(res.effective_passes[c]), tuple(res.histories[c]))
    for scheme in ("inconsistent", "unlock"):
        hog = run_hogwild(obj, 3 * epochs, 2.0, num_threads=P, scheme=scheme)
        curves[f"hogwild-{scheme}"] = (hog.effective_passes, hog.history)
    return {"f_star": f_star, "curves": curves}


def main(quick=True):
    out = run(quick=quick)
    print("name,us_per_call,derived")
    for name, (passes, hist) in out["curves"].items():
        final_gap = hist[-1] - out["f_star"]
        print(f"fig1_convergence_{name},0,"
              f"final_gap={final_gap:.3e};passes={passes[-1]:.0f}")
    # full curves as CSV comment rows for plotting
    for name, (passes, hist) in out["curves"].items():
        pts = ";".join(f"{p:.0f}:{h - out['f_star']:.3e}"
                       for p, h in zip(passes, hist))
        print(f"# curve {name}: {pts}")


if __name__ == "__main__":
    main(quick=False)
