"""Multi-algorithm sweep: Hogwild!/SVRG rows vs their sequential drivers.

(a) sweep-Hogwild! histories and final iterates are BIT-IDENTICAL to
    sequential `run_hogwild` for all three reading schemes at τ ∈ {0, p−1};
(b) the γ ← decay·γ schedule threaded through the compiled epochs-scan
    equals an explicit per-epoch `hogwild_epoch` loop with externally
    decayed γ;
(c) `algo="svrg"` routes through the zero-delay degenerate path of the
    AsySVRG engine (bit-identical to `run_asysvrg` at τ=0, p=1);
(d) `run_hogwild.total_updates` derives from the same (n // p)·p total the
    epoch scan executes; plus a frontier-grid smoke test.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SVRGConfig
from repro.core import (LogisticRegression, SweepSpec, run_asysvrg,
                        run_hogwild, run_sweep, svrg_sweep_spec)
from repro.core.hogwild import _resolve_hogwild_steps, hogwild_epoch
from repro.core.objective import loss_fixed_order
from repro.data.libsvm import make_synthetic_libsvm

SCHEMES = ("consistent", "inconsistent", "unlock")


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def _assert_hogwild_rows_match_sequential(obj, specs, res, epochs):
    for c, spec in enumerate(specs):
        seq = run_hogwild(obj, epochs, spec.step_size,
                          num_threads=spec.num_threads, decay=spec.decay,
                          scheme=spec.scheme, tau=spec.tau, seed=spec.seed,
                          delay_kind=spec.delay_kind)
        np.testing.assert_array_equal(
            np.asarray(seq.history, np.float32), res.histories[c],
            err_msg=f"history mismatch for {spec}")
        np.testing.assert_array_equal(
            np.asarray(seq.w, np.float32), res.final_w[c],
            err_msg=f"final iterate mismatch for {spec}")
        assert int(res.total_updates[c]) == seq.total_updates
        np.testing.assert_allclose(res.effective_passes[c],
                                   np.asarray(seq.effective_passes))


@pytest.mark.parametrize("tau", [0, 3])   # 3 = p − 1
def test_sweep_hogwild_bit_identical_all_schemes(obj, tau):
    """Acceptance: sweep-Hogwild! == sequential run_hogwild, bit-for-bit,
    for every reading scheme at zero and maximal bounded delay."""
    epochs, p = 3, 4
    specs = [SweepSpec(algo="hogwild", scheme=s, step_size=0.5, tau=tau,
                       num_threads=p, seed=seed)
             for s in SCHEMES for seed in (0, 1)]
    res = run_sweep(obj, epochs, specs)
    assert res.histories.shape == (6, epochs + 1)
    _assert_hogwild_rows_match_sequential(obj, specs, res, epochs)


def test_sweep_hogwild_decay_axis_in_one_group(obj):
    """Configs differing ONLY in decay batch into one group (decay is a
    dynamic input, not a compile-time constant) and still match."""
    epochs = 3
    specs = [SweepSpec(algo="hogwild", scheme="unlock", step_size=0.5,
                       tau=2, num_threads=3, seed=0, decay=d)
             for d in (0.9, 0.5, 1.0)]
    res = run_sweep(obj, epochs, specs)
    _assert_hogwild_rows_match_sequential(obj, specs, res, epochs)
    # sanity: decay actually changed the trajectories
    assert not np.array_equal(res.final_w[0], res.final_w[1])


def test_hogwild_decay_in_scan_matches_per_epoch_loop(obj):
    """The γ←0.9γ schedule inside the compiled epochs-scan == an explicit
    Python loop over `hogwild_epoch` with externally decayed f32 γ."""
    epochs, p, tau = 4, 4, 3
    step, decay = 0.5, 0.9
    res = run_hogwild(obj, epochs, step, num_threads=p, decay=decay,
                      scheme="inconsistent", tau=tau, seed=7)

    epoch_fn = jax.jit(lambda w, k, g: hogwild_epoch(
        obj, w, k, g, p, tau=tau, scheme="inconsistent"))
    loss_fn = jax.jit(lambda w: loss_fixed_order(obj.X, obj.y, obj.l2, w))

    w = jnp.zeros(obj.p)
    key = jax.random.PRNGKey(7)
    gamma = jnp.float32(step)
    history = [float(loss_fn(w))]
    for _ in range(epochs):
        key, sub = jax.random.split(key)
        w = epoch_fn(w, sub, gamma)
        gamma = gamma * jnp.float32(decay)   # the externally-decayed γ chain
        history.append(float(loss_fn(w)))

    np.testing.assert_array_equal(np.asarray(res.history, np.float32),
                                  np.asarray(history, np.float32))
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(w))


def test_run_hogwild_total_updates_derives_from_epoch_total(obj):
    """total_updates == epochs · (n // p)·p — the same expression the epoch
    scan executes, including when p does not divide n."""
    for p in (3, 7, 8):
        _, total, _ = _resolve_hogwild_steps(obj.n, p, -1)
        assert total == (obj.n // p) * p
        res = run_hogwild(obj, 2, 0.5, num_threads=p, seed=0)
        assert res.total_updates == 2 * total


def test_svrg_algo_routes_through_zero_delay_path(obj):
    """algo="svrg" == run_asysvrg at τ=0, p=1 (the degenerate case), from
    the same vmapped engine, bit-for-bit."""
    epochs = 2
    spec = svrg_sweep_spec(step_size=1.0, num_inner=60, seed=5)
    res = run_sweep(obj, epochs, [spec])
    ref = run_asysvrg(obj, epochs,
                      SVRGConfig(scheme="consistent", step_size=1.0,
                                 num_threads=1, tau=0, inner_steps=60),
                      seed=5)
    np.testing.assert_array_equal(np.asarray(ref.history, np.float32),
                                  res.histories[0])
    np.testing.assert_array_equal(np.asarray(ref.w, np.float32),
                                  res.final_w[0])


def test_mixed_algo_grid_single_call(obj):
    """asysvrg + hogwild + svrg specs in ONE run_sweep call land in their
    engine groups and each row matches its own sequential driver."""
    epochs = 2
    asy = SweepSpec(scheme="inconsistent", step_size=0.5, tau=2,
                    num_threads=3, inner_steps=20, seed=1)
    hog = SweepSpec(algo="hogwild", scheme="unlock", step_size=0.5, tau=2,
                    num_threads=3, seed=2)
    svrg = svrg_sweep_spec(step_size=0.5, num_inner=30, seed=3)
    res = run_sweep(obj, epochs, [asy, hog, svrg])

    ref_a = run_asysvrg(obj, epochs, asy.to_config(), seed=1)
    np.testing.assert_array_equal(np.asarray(ref_a.history, np.float32),
                                  res.histories[0])
    ref_h = run_hogwild(obj, epochs, 0.5, num_threads=3, scheme="unlock",
                        tau=2, seed=2)
    np.testing.assert_array_equal(np.asarray(ref_h.history, np.float32),
                                  res.histories[1])
    ref_s = run_asysvrg(obj, epochs,
                        SVRGConfig(scheme="consistent", step_size=0.5,
                                   num_threads=1, tau=0, inner_steps=30),
                        seed=3)
    np.testing.assert_array_equal(np.asarray(ref_s.history, np.float32),
                                  res.histories[2])


def test_sweep_rejects_bad_algo(obj):
    with pytest.raises(ValueError):
        run_sweep(obj, 1, [SweepSpec(algo="nope")])


def test_hogwild_per_row_epochs_match_shorter_runs(obj):
    """Hogwild! rows with different epoch budgets in one call: each equals
    an independent run of its own length (γ-decay freezes with the row)."""
    specs = [SweepSpec(algo="hogwild", scheme="unlock", step_size=0.5,
                       tau=2, num_threads=3, seed=2, epochs=e)
             for e in (2, 5)]
    res = run_sweep(obj, 5, specs)
    for c, spec in enumerate(specs):
        seq = run_hogwild(obj, spec.epochs, 0.5, num_threads=3,
                          scheme="unlock", tau=2, seed=2)
        np.testing.assert_array_equal(
            np.asarray(seq.history, np.float32),
            res.histories[c, :spec.epochs + 1])
        np.testing.assert_array_equal(np.asarray(seq.w, np.float32),
                                      res.final_w[c])
        assert int(res.total_updates[c]) == seq.total_updates
        assert np.all(res.histories[c, spec.epochs:]
                      == res.histories[c, spec.epochs])


def test_fig1_paired_epoch_budgets_single_call(obj):
    """Acceptance: Fig. 1's paired budgets — AsySVRG E epochs vs Hogwild!
    3E epochs (equal effective passes) — execute as ONE run_sweep call,
    bit-identical to the old two-call split."""
    E, p = 2, 4
    asy = [SweepSpec(scheme=s, step_size=0.5, num_threads=p, tau=p - 1,
                     epochs=E)                 # M̃ = 2n -> ~3 passes/epoch
           for s in ("inconsistent", "unlock")]
    hog = [SweepSpec(algo="hogwild", scheme=s, step_size=0.5,
                     num_threads=p, tau=p - 1, epochs=3 * E)
           for s in ("inconsistent", "unlock")]
    res = run_sweep(obj, E, asy + hog)
    assert res.histories.shape == (4, 3 * E + 1)

    res_asy = run_sweep(obj, E, asy)
    res_hog = run_sweep(obj, 3 * E, hog)
    for c in range(2):
        passes, hist = res.curve(c)
        np.testing.assert_array_equal(hist, res_asy.histories[c])
        np.testing.assert_allclose(passes, res_asy.effective_passes[c])
        assert len(hist) == E + 1
    for c in range(2):
        passes, hist = res.curve(2 + c)
        np.testing.assert_array_equal(hist, res_hog.histories[c])
        np.testing.assert_allclose(passes, res_hog.effective_passes[c])
        assert len(hist) == 3 * E + 1
    # equal effective-pass coverage is the point of the 3x pairing
    assert abs(res.curve(0)[0][-1] - res.curve(2)[0][-1]) <= 0.5


def test_frontier_grid_smoke(obj):
    """frontier_stability's one-call grid: shape, verdicts, a sane frontier
    (τ=0 admits at least as large a step as the largest τ), and the
    pass-matched Hogwild! edge (3× per-row epochs) riding the same call."""
    from benchmarks.frontier_stability import run as frontier_run
    out = frontier_run(scale=0.002, steps=(0.5, 8.0), taus=(0, 3),
                      epochs=2)
    assert out["grid_size"] == 6        # 4 async/svrg cells + 2 hogwild
    assert {c["verdict"] for c in out["cells"]} <= {"stable", "diverged"}
    assert set(out["frontier"]) == {0, 3}
    assert out["frontier"][0] >= out["frontier"][3]
    assert set(out["frontier_hogwild"]) == {3}
    hog_cells = [c for c in out["cells"] if c["algo"] == "hogwild"]
    assert len(hog_cells) == 2
    assert all(c["epochs"] == 6 for c in hog_cells)   # 3 x pass-matched


@pytest.mark.slow
def test_sweep_hogwild_bit_identical_heavy_grid(obj):
    """Heavy grid: schemes × seeds × steps × decays × delay kinds."""
    epochs = 3
    specs = [SweepSpec(algo="hogwild", scheme=s, step_size=step, tau=3,
                       num_threads=4, seed=seed, decay=d, delay_kind=kind)
             for s in SCHEMES for seed in (0, 1) for step in (0.25, 1.0)
             for d in (0.9, 1.0) for kind in ("fixed", "uniform")]
    res = run_sweep(obj, epochs, specs)
    assert res.histories.shape == (48, epochs + 1)
    _assert_hogwild_rows_match_sequential(obj, specs, res, epochs)
