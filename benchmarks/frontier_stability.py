"""(step × τ) stability frontier — paper §5 discussion, as ONE sweep call.

Theorem 1 ties the admissible step size to the staleness bound τ: more
staleness shrinks the stable step region. This benchmark maps that frontier
empirically: a grid over step sizes × τ values runs as a single
`run_sweep` (one jit per M̃-group), each cell is classified
stable / diverged from its loss history, and the report gives, per τ, the
largest step that still converges.

The τ=0 column is serial SVRG routed through the same engine
(``SweepSpec(algo="svrg")`` — the zero-delay degenerate case), so the
frontier's sequential edge and its asynchronous interior share the compiled
path and the comparison is apples-to-apples.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.artifacts import write_bench_json
from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm

P = 10
STEPS = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0)
TAUS = (0, 1, 3, 7, 9)


def classify(history, f0: float) -> str:
    """stable = finite history that ends below the starting loss."""
    h = np.asarray(history, np.float64)
    if not np.all(np.isfinite(h)):
        return "diverged"
    return "stable" if h[-1] < f0 else "diverged"


def run(dataset: str = "rcv1", scale: float = 0.03,
        steps=STEPS, taus=TAUS, epochs: int = 6, quick: bool = False):
    if quick:
        steps = tuple(steps)[1::2]
        taus = tuple(taus)[::2]
        epochs = 3
    ds = make_synthetic_libsvm(dataset, scale=scale)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    f0 = float(obj.loss(np.zeros(obj.p)))

    specs = []
    for tau in taus:
        for step in steps:
            if tau == 0:
                specs.append(SweepSpec(algo="svrg", step_size=step,
                                       num_threads=1))
            else:
                specs.append(SweepSpec(scheme="inconsistent", step_size=step,
                                       tau=tau, num_threads=P))
    t0 = time.perf_counter()
    res = run_sweep(obj, epochs, specs)
    sweep_s = time.perf_counter() - t0

    cells = []
    for c, spec in enumerate(specs):
        h = res.histories[c]
        verdict = classify(h, f0)
        final = float(h[-1])
        cells.append({"tau": spec.tau if spec.algo != "svrg" else 0,
                      "algo": spec.algo, "step": spec.step_size,
                      "final_loss": final if np.isfinite(final) else None,
                      "verdict": verdict})

    frontier = {}
    for tau in taus:
        stable = [c["step"] for c in cells
                  if c["tau"] == tau and c["verdict"] == "stable"]
        frontier[tau] = max(stable) if stable else 0.0

    return {"dataset": dataset, "f0": f0, "epochs": epochs,
            "grid_size": len(specs), "sweep_s": sweep_s,
            "cells": cells, "frontier": frontier}


def main(quick: bool = True):
    out = run(quick=quick)
    write_bench_json("frontier_stability", out)
    print("name,us_per_call,derived")
    print(f"frontier_sweep_engine,{out['sweep_s'] * 1e6:.1f},"
          f"cells={out['grid_size']};one_call_grid")
    for tau, step in out["frontier"].items():
        print(f"frontier_tau{tau},0,max_stable_step={step}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
