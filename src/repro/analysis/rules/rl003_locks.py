"""RL003 — lock discipline, guarded-by style.

The service/server tier shares mutable state between HTTP handler
threads, the flush daemon, and callers of ``flush_now()``. The house
pattern is coarse: one ``threading.RLock`` per object, every touch of
shared state inside ``with self._lock``. This checker makes the pattern
declarative and machine-enforced:

  * Declare guards either with a class-level mapping::

        _GUARDED_BY = {"_pending": "_lock", "stats": "_lock"}

    or inline, on the attribute's ``__init__`` assignment::

        self.stats = DaemonStats()  # guarded-by: _lock

  * Every ``self.<attr>`` access (read or write) of a declared attribute
    must then happen lexically inside ``with self._lock:`` — or inside a
    method annotated ``# holds: _lock`` on its ``def`` line, which
    asserts every caller already holds the lock.

  * ``threading.Condition(self._lock)`` aliases are understood:
    ``with self._done_cv:`` counts as holding ``_lock``.

  * ``__init__`` is exempt (the object is not yet shared), and nested
    functions restart with an empty held-set (a closure outlives the
    ``with`` block it was created in).

This is lexical, not a race detector: it cannot see aliasing through
locals (``s = self.stats``) or cross-object locking. It exists to catch
the easy, common mistake — the unlocked ``self.stats.x += 1`` hot-path
increment — mechanically, in CI, before a reviewer has to.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.analysis.astutil import FUNC_NODES, call_name, is_self_attr
from repro.analysis.diagnostics import Diagnostic
from repro.analysis.suppress import Comments, scan_comments


def _parse_guard_map(cls: ast.ClassDef) -> Dict[str, str]:
    """Class-level ``_GUARDED_BY = {"attr": "_lock", ...}`` declarations."""
    out: Dict[str, str] = {}
    for stmt in cls.body:
        if (isinstance(stmt, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "_GUARDED_BY"
                        for t in stmt.targets)
                and isinstance(stmt.value, ast.Dict)):
            for k, v in zip(stmt.value.keys, stmt.value.values):
                if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                        and isinstance(v, ast.Constant)
                        and isinstance(v.value, str)):
                    out[k.value] = v.value
    return out


def _init_of(cls: ast.ClassDef) -> Optional[ast.FunctionDef]:
    for stmt in cls.body:
        if isinstance(stmt, FUNC_NODES) and stmt.name == "__init__":
            return stmt
    return None


def _comment_guards(init: ast.FunctionDef,
                    comments: Comments) -> Dict[str, str]:
    """``self.x = ...  # guarded-by: _lock`` assignments in __init__."""
    out: Dict[str, str] = {}
    for stmt in ast.walk(init):
        if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            continue
        locks = comments.guarded_by.get(stmt.lineno)
        if not locks:
            continue
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for tgt in targets:
            if is_self_attr(tgt):
                out[tgt.attr] = locks[0]
    return out


def _condition_aliases(init: ast.FunctionDef) -> Dict[str, str]:
    """``self._done_cv = threading.Condition(self._lock)`` → cv aliases
    the lock: holding the Condition IS holding the lock."""
    out: Dict[str, str] = {}
    for stmt in ast.walk(init):
        if not isinstance(stmt, ast.Assign):
            continue
        val = stmt.value
        if (isinstance(val, ast.Call)
                and call_name(val) in ("threading.Condition", "Condition")
                and val.args and is_self_attr(val.args[0])):
            lock = val.args[0].attr
            for tgt in stmt.targets:
                if is_self_attr(tgt):
                    out[tgt.attr] = lock
    return out


def _held_locks(expr: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Canonical lock name acquired by a ``with`` context expr, if any."""
    if is_self_attr(expr):
        return aliases.get(expr.attr, expr.attr)
    return None


def _holds_annotation(fn: ast.AST, comments: Comments) -> Tuple[str, ...]:
    """Locks asserted held on entry (``# holds: _lock`` on the def line
    or anywhere in a multi-line signature)."""
    first_body = fn.body[0].lineno if fn.body else fn.lineno
    locks: List[str] = []
    for line in range(fn.lineno, first_body + 1):
        locks.extend(comments.holds.get(line, ()))
    return tuple(locks)


def _walk(node: ast.AST, held: FrozenSet[str], guards: Dict[str, str],
          aliases: Dict[str, str], method: str, path: str,
          out: List[Diagnostic]) -> None:
    if isinstance(node, (ast.With, ast.AsyncWith)):
        acquired = set(held)
        for item in node.items:
            lock = _held_locks(item.context_expr, aliases)
            if lock is not None:
                acquired.add(lock)
        for stmt in node.body:
            _walk(stmt, frozenset(acquired), guards, aliases, method, path,
                  out)
        return
    if isinstance(node, FUNC_NODES + (ast.Lambda,)):
        # a nested function may run after the with-block exits
        body = node.body if isinstance(node.body, list) else [node.body]
        for stmt in body:
            _walk(stmt, frozenset(), guards, aliases, method, path, out)
        return
    if is_self_attr(node):
        attr = node.attr
        lock = guards.get(attr)
        if lock is not None and lock not in held:
            out.append(Diagnostic(
                path, node.lineno, "RL003",
                f"`self.{attr}` is guarded by `{lock}` but accessed in "
                f"{method!r} without holding it — wrap in `with "
                f"self.{lock}:` or annotate the method `# holds: {lock}`"))
    for child in ast.iter_child_nodes(node):
        _walk(child, held, guards, aliases, method, path, out)


def check(path: str, tree: ast.AST, source: str) -> List[Diagnostic]:
    comments = scan_comments(source)
    out: List[Diagnostic] = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        guards = _parse_guard_map(cls)
        init = _init_of(cls)
        aliases: Dict[str, str] = {}
        if init is not None:
            guards.update(_comment_guards(init, comments))
            aliases = _condition_aliases(init)
        if not guards:
            continue
        for fn in cls.body:
            if not isinstance(fn, FUNC_NODES) or fn.name == "__init__":
                continue
            held = frozenset(aliases.get(name, name)
                             for name in _holds_annotation(fn, comments))
            for stmt in fn.body:
                _walk(stmt, held, guards, aliases, fn.name, path, out)
    return out
