"""Request-lifecycle flight recorder: bounded, structured, stdlib-only.

One process-global `Tracer` records monotonic-clock spans with explicit
parent ids into a ring buffer of recent traces. A trace id is minted at
`SweepService.submit` and threaded through the scheduler, the runner
cache, the flush daemon and the HTTP tier, so one request's life —

    submit -> plan -> coalesce -> pad -> dispatch -> execute -> demux
           -> result

— is retrievable as a span tree at ``GET /trace?id=...`` long after the
response went out. Design constraints, in order:

  * ZERO warm-path cost when disabled: tracing is opt-in
    (`enable_tracing()`); disabled, `new_trace()` returns ``""`` and every
    span call is a constant-time no-op returning a shared null handle.
    The obs-overhead benchmark gates the enabled cost too (<= 5%).
  * TRACE-SAFE by construction: nothing here is ever called from inside a
    jitted scope — spans bracket runner *calls*, not traced math — and
    repro-lint RL006 mechanically bans these APIs from `*_core` functions
    and kernel modules.
  * BOUNDED: at most ``max_traces`` recent traces, ``max_spans`` spans
    each; the last trace that recorded an error is retained separately so
    a crash dump survives the ring buffer.

Shared flush phases touch MANY requests at once (one coalesced dispatch
serves every pooled request), so `span_all` opens one span PER TRACE for
a phase and `span_active` / `annotate` address "whatever span group is
open on this thread" — that is how `service/cache.py` attributes a
cache hit/miss/compile to every request riding the dispatch without ever
learning their trace ids.

Stdlib-only on purpose: `repro.core` imports this module, and the
repro-lint CI lane (which installs nothing) imports nothing from here.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Span:
    """One timed phase of one request's life. ``parent_id`` is explicit —
    the dump is a tree, not a flat log — and ``tags`` carry the phase's
    attribution facts (group key, cache hit/miss, kernel mode, rows)."""
    trace_id: str
    span_id: int
    parent_id: Optional[int]
    name: str
    start_s: float
    end_s: Optional[float] = None
    tags: Dict[str, object] = dataclasses.field(default_factory=dict)
    error: Optional[str] = None

    def to_dict(self) -> dict:
        dur = (None if self.end_s is None
               else (self.end_s - self.start_s) * 1000.0)
        return {"span_id": self.span_id, "parent_id": self.parent_id,
                "name": self.name, "start_s": self.start_s,
                "duration_ms": dur, "tags": dict(self.tags),
                "error": self.error}


class _NullHandle:
    """The disabled-path span handle: a shared, reusable no-op context
    manager, so a tracer-off hot loop allocates nothing per span."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullHandle()


class _SpanHandle:
    """Context manager closing one GROUP of spans (one per trace sharing
    the phase). Opening pushes the group on the thread's stack so nested
    `span_active` / `annotate` calls can find it without knowing ids."""
    __slots__ = ("_tracer", "_spans")

    def __init__(self, tracer: "Tracer", spans: List[Span]):
        self._tracer = tracer
        self._spans = spans

    def __enter__(self) -> "_SpanHandle":
        self._tracer._push(self._spans)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._tracer._pop(self._spans, exc)
        return False


class Tracer:
    """The flight recorder. Use the module-level singleton via `tracer()`
    (plus `enable_tracing()` / `disable_tracing()`); instances exist for
    tests."""

    def __init__(self, max_traces: int = 256, max_spans: int = 512):
        self.max_traces = max_traces
        self.max_spans = max_spans
        self._enabled = False
        self._lock = threading.Lock()
        # trace id -> list of spans, insertion-ordered so the oldest trace
        # is evicted first; a trace's spans append in open order
        self._traces: "OrderedDict[str, List[Span]]" = OrderedDict()  # guarded-by: _lock
        self._last_error: Optional[dict] = None  # guarded-by: _lock
        self._ids = itertools.count(1)
        self._tls = threading.local()            # per-thread open-span stack

    # ------------------------------------------------------------ lifecycle
    def enable(self) -> None:
        with self._lock:
            self._enabled = True

    def disable(self, clear: bool = False) -> None:
        with self._lock:
            self._enabled = False
            if clear:
                self._traces.clear()
                self._last_error = None

    @property
    def enabled(self) -> bool:
        return self._enabled

    # ------------------------------------------------------------- recording
    def new_trace(self) -> str:
        """Mint a trace id (or ``""`` when disabled — the empty id threads
        through every span API as a no-op, so call sites never branch)."""
        if not self._enabled:
            return ""
        tid = f"t{next(self._ids):08x}"
        with self._lock:
            self._traces[tid] = []
            while len(self._traces) > self.max_traces:
                self._traces.popitem(last=False)
        return tid

    def span(self, trace_id: str, name: str, *,
             parent_name: Optional[str] = None, **tags):
        """Open one span in one trace (context manager)."""
        return self.span_all((trace_id,), name, parent_name=parent_name,
                             **tags)

    def span_all(self, trace_ids: Sequence[str], name: str, *,
                 parent_name: Optional[str] = None, **tags):
        """Open the SAME phase across many traces (one span each) — the
        shared flush phases (coalesce/pad/dispatch/demux) serve every
        pooled request at once. Unknown/empty ids are skipped, so a flush
        mixing traced and untraced requests records only the former."""
        if not self._enabled:
            return _NULL
        now = time.monotonic()
        spans: List[Span] = []
        with self._lock:
            for tid in dict.fromkeys(trace_ids):     # dedupe, keep order
                store = self._traces.get(tid) if tid else None
                if store is None or len(store) >= self.max_spans:
                    continue
                span = Span(trace_id=tid, span_id=next(self._ids),
                            parent_id=self._parent_id_locked(tid,
                                                             parent_name),
                            name=name, start_s=now, tags=dict(tags))
                store.append(span)
                spans.append(span)
        if not spans:
            return _NULL
        return _SpanHandle(self, spans)

    def span_active(self, name: str, **tags):
        """Open ``name`` as a child of every span in the innermost open
        group ON THIS THREAD — for layers (the runner call deep inside
        `_dispatch_group`) that never see trace ids but run inside a
        traced phase."""
        if not self._enabled:
            return _NULL
        stack = getattr(self._tls, "stack", None)
        if not stack:
            return _NULL
        now = time.monotonic()
        spans: List[Span] = []
        with self._lock:
            for parent in stack[-1]:
                store = self._traces.get(parent.trace_id)
                if store is None or len(store) >= self.max_spans:
                    continue
                span = Span(trace_id=parent.trace_id,
                            span_id=next(self._ids),
                            parent_id=parent.span_id, name=name,
                            start_s=now, tags=dict(tags))
                store.append(span)
                spans.append(span)
        if not spans:
            return _NULL
        return _SpanHandle(self, spans)

    def annotate(self, **tags) -> None:
        """Merge tags into every span of the innermost open group on this
        thread (no-op outside any span) — how the runner cache stamps
        hit/miss/compile attribution onto whatever dispatch is running."""
        if not self._enabled:
            return
        stack = getattr(self._tls, "stack", None)
        if not stack:
            return
        with self._lock:
            for span in stack[-1]:
                span.tags.update(tags)

    def record_error(self, trace_id: str, exc: BaseException) -> None:
        """Mark a trace failed and retain its dump as the last-error trace
        (survives ring-buffer eviction — the crash you debug tomorrow)."""
        if not self._enabled or not trace_id:
            return
        with self._lock:
            store = self._traces.get(trace_id)
            if store is None:
                return
            marker = Span(trace_id=trace_id, span_id=next(self._ids),
                          parent_id=store[0].span_id if store else None,
                          name="error", start_s=time.monotonic(),
                          end_s=time.monotonic(),
                          error=f"{type(exc).__name__}: {exc}")
            if len(store) < self.max_spans:
                store.append(marker)
            self._last_error = {
                "trace_id": trace_id,
                "error": marker.error,
                "spans": [s.to_dict() for s in store],
            }

    # ------------------------------------------------------------- retrieval
    def get(self, trace_id: str) -> Optional[dict]:
        """One trace's span tree as a JSON-safe dict (None if unknown or
        already evicted from the ring buffer)."""
        with self._lock:
            store = self._traces.get(trace_id)
            if store is None:
                return None
            return {"trace_id": trace_id,
                    "spans": [s.to_dict() for s in store]}

    def recent(self, n: int = 16) -> List[dict]:
        """Summaries of the n most recent traces, newest first."""
        with self._lock:
            items = list(self._traces.items())[-n:]
        out = []
        for tid, spans in reversed(items):
            root = spans[0] if spans else None
            out.append({
                "trace_id": tid,
                "spans": len(spans),
                "root": root.name if root else None,
                "tags": dict(root.tags) if root else {},
                "error": next((s.error for s in spans if s.error), None),
            })
        return out

    def last_error(self) -> Optional[dict]:
        with self._lock:
            return self._last_error

    # -------------------------------------------------------------- internal
    def _parent_id_locked(self, tid: str,
                          parent_name: Optional[str]) -> Optional[int]:  # holds: _lock
        """Explicit parent ids, resolved in priority order: a named parent
        (latest same-trace span with that name) > the innermost open
        same-trace span on this thread > the trace's root span."""
        store = self._traces.get(tid, [])
        if parent_name is not None:
            for span in reversed(store):
                if span.name == parent_name:
                    return span.span_id
        stack = getattr(self._tls, "stack", None)
        if stack:
            for group in reversed(stack):
                for span in group:
                    if span.trace_id == tid:
                        return span.span_id
        return store[0].span_id if store else None

    def _push(self, spans: List[Span]) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        stack.append(spans)

    def _pop(self, spans: List[Span],
             exc: Optional[BaseException]) -> None:
        now = time.monotonic()
        stack = getattr(self._tls, "stack", None)
        if stack and stack[-1] is spans:
            stack.pop()
        elif stack and spans in stack:       # defensive: unbalanced exits
            stack.remove(spans)
        with self._lock:
            for span in spans:
                span.end_s = now
                if exc is not None and span.error is None:
                    span.error = f"{type(exc).__name__}: {exc}"


# --------------------------------------------------------------- singleton
_TRACER = Tracer()


def tracer() -> Tracer:
    """The process-global flight recorder every layer records into."""
    return _TRACER


def enable_tracing(max_traces: Optional[int] = None,
                   max_spans: Optional[int] = None) -> Tracer:
    """Turn the flight recorder on (optionally re-bounding it). Tracing
    is process-global and OPT-IN: a service with tracing off mints no
    trace ids and pays a single boolean check per would-be span."""
    if max_traces is not None:
        _TRACER.max_traces = int(max_traces)
    if max_spans is not None:
        _TRACER.max_spans = int(max_spans)
    _TRACER.enable()
    return _TRACER


def disable_tracing(clear: bool = False) -> None:
    _TRACER.disable(clear=clear)
