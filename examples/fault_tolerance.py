"""Fault-tolerance demo: train, kill mid-run, auto-resume from the atomic
checkpoint, and verify the loss trajectory continues (not restarts).

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil
import tempfile

from repro.config import ModelConfig, SVRGConfig, TrainConfig
from repro.data.synthetic_lm import SyntheticLMDataset
from repro.models.factory import build_model
from repro.train.loop import train

CKDIR = tempfile.mkdtemp(prefix="repro_ft_")

cfg = ModelConfig(
    name="ft-demo", family="dense", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512, dtype="float32",
    param_dtype="float32", remat="none", tie_embeddings=True)


def tcfg(steps):
    return TrainConfig(steps=steps, optimizer="svrg", learning_rate=0.1,
                       warmup_steps=2, schedule="constant",
                       checkpoint_dir=CKDIR, checkpoint_every=10,
                       log_every=10,
                       svrg=SVRGConfig(snapshot_every=20, snapshot_batches=2))


def main():
    bundle = build_model(cfg)
    ds = SyntheticLMDataset(cfg.vocab_size, 64, 8)

    print("=== phase 1: run 25 steps, then 'crash' ===")
    train(bundle, tcfg(25), ds.batch_at)

    print("\n=== phase 2: relaunch — auto-resumes from step 20 ===")
    seen = []
    train(bundle, tcfg(60), ds.batch_at, hooks=lambda s, m: seen.append(s))
    assert min(seen) >= 20, "should have resumed, not restarted!"
    print(f"\nresumed at step {min(seen)}, finished at {max(seen)} — "
          "checkpoint/restart works.")
    shutil.rmtree(CKDIR, ignore_errors=True)


if __name__ == "__main__":
    main()
