"""Vectorized AsySVRG sweep engine: the whole experiment grid in ONE jit.

The paper's tables sweep (reading scheme × thread count × step size × seed);
the benchmark layer used to run each cell as its own `run_asysvrg` call —
one trace, one compile, and epochs × Python dispatches PER CELL. This module
turns the grid into data: every configuration becomes a row of scalar arrays
(seed, scheme-id, step-size, τ, delay-id), the epoch body is `vmap`-ed over
that row axis, and a `lax.scan` drives the epochs — so N×compile becomes
1×compile and the entire grid advances in lockstep through one XLA program.

Bit-exactness contract: per-config loss histories and final iterates are
BIT-IDENTICAL to sequential `run_asysvrg` calls with the same specs (see
tests/test_sweep.py). This is what makes the sweep a drop-in replacement for
the benchmark loops rather than a statistical approximation of them. The
contract holds because `_epoch_core` and `loss_fixed_order` only use
reductions whose bits survive vmap batching (see repro.core.objective).

Configurations may disagree on M̃ = pM (the inner-loop length is a static
scan bound): `run_sweep` groups specs by (M̃, option), compiles once per
group, and reassembles rows in input order. A grid over schemes / seeds /
steps / τ / delay-kinds is one group; adding thread counts usually stays at
one group too, since M = ⌊2n/p⌋ keeps pM ≈ 2n (e.g. any p dividing 2n).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SVRGConfig
from repro.core.asysvrg import (
    DELAY_IDS,
    SCHEME_IDS,
    _epoch_core,
    _resolve_steps,
)
from repro.core.objective import LogisticRegression, loss_fixed_order


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One grid cell: the knobs Tables 2–3 / Fig. 1 vary.

    ``num_threads``/``inner_steps`` fix M̃ = pM exactly as SVRGConfig does;
    ``tau=0`` means "derive τ = p−1" (SVRGConfig convention).
    """
    seed: int = 0
    scheme: str = "inconsistent"
    step_size: float = 0.1
    tau: int = 0
    delay_kind: str = "fixed"
    num_threads: int = 8
    inner_steps: int = 0
    option: int = 2

    def to_config(self) -> SVRGConfig:
        return SVRGConfig(scheme=self.scheme, step_size=self.step_size,
                          num_threads=self.num_threads, tau=self.tau,
                          inner_steps=self.inner_steps, option=self.option)


class SweepResult(NamedTuple):
    specs: Tuple[SweepSpec, ...]
    histories: np.ndarray         # [C, epochs+1] loss after each epoch
    effective_passes: np.ndarray  # [C, epochs+1] cumulative effective passes
    final_w: np.ndarray           # [C, p]
    total_updates: np.ndarray     # [C] updates applied over all epochs

    def row(self, c: int) -> Dict:
        """One config as a flat record (for CSV-ish reporting)."""
        s = self.specs[c]
        return {**dataclasses.asdict(s),
                "history": self.histories[c],
                "effective_passes": self.effective_passes[c],
                "total_updates": int(self.total_updates[c])}


def make_grid(schemes: Sequence[str] = ("consistent", "inconsistent", "unlock"),
              seeds: Sequence[int] = (0,),
              step_sizes: Sequence[float] = (0.1,),
              taus: Sequence[int] = (0,),
              delay_kinds: Sequence[str] = ("fixed",),
              num_threads: int = 8,
              inner_steps: int = 0,
              option: int = 2) -> List[SweepSpec]:
    """Cartesian grid over the paper's experiment axes, outermost-first."""
    return [
        SweepSpec(seed=seed, scheme=scheme, step_size=step, tau=tau,
                  delay_kind=kind, num_threads=num_threads,
                  inner_steps=inner_steps, option=option)
        for scheme in schemes
        for seed in seeds
        for step in step_sizes
        for tau in taus
        for kind in delay_kinds
    ]


def _resolve(obj: LogisticRegression, spec: SweepSpec):
    """(total, clamped τ, delay-id) — exactly run_asysvrg's resolution."""
    _, _, total, tau = _resolve_steps(obj, spec.to_config())
    if spec.delay_kind not in DELAY_IDS:
        raise ValueError(f"unknown delay schedule {spec.delay_kind!r}")
    if spec.scheme not in SCHEME_IDS:
        raise ValueError(f"unknown scheme {spec.scheme!r}")
    delay_id = DELAY_IDS["zero"] if tau == 0 else DELAY_IDS[spec.delay_kind]
    return total, tau, delay_id


def _group_runner(X, y, l2: float, epochs: int, total: int, buf_len: int,
                  option: int, drop_prob: float):
    """jit(vmap(per-config epochs-scan)) for one (M̃, option) group."""

    def per_config(key, eta, tau, scheme_id, delay_id, w0):
        loss0 = loss_fixed_order(X, y, l2, w0)

        def step(carry, _):
            w, key = carry
            key, sub = jax.random.split(key)
            w_next = _epoch_core(
                X, y, l2, w, sub, eta, tau, scheme_id, delay_id,
                total=total, buf_len=buf_len, option=option,
                drop_prob=drop_prob)
            return (w_next, key), loss_fixed_order(X, y, l2, w_next)

        (w_fin, _), losses = jax.lax.scan(step, (w0, key), None, length=epochs)
        return w_fin, jnp.concatenate([loss0[None], losses])

    return jax.jit(jax.vmap(per_config))


def run_sweep(obj: LogisticRegression, epochs: int,
              specs: Sequence[SweepSpec], *, w0=None,
              drop_prob: float = 0.02) -> SweepResult:
    """Run every spec for `epochs` outer iterations in one compiled program
    per (M̃, option) group. Histories/final iterates are bit-identical to
    per-spec `run_asysvrg` calls."""
    specs = tuple(specs)
    if not specs:
        raise ValueError("empty sweep")
    w_init = jnp.zeros(obj.p) if w0 is None else jnp.asarray(w0)

    resolved = [_resolve(obj, s) for s in specs]
    groups: Dict[Tuple[int, int], List[int]] = {}
    for c, (total, _, _) in enumerate(resolved):
        groups.setdefault((total, specs[c].option), []).append(c)

    C = len(specs)
    histories = np.zeros((C, epochs + 1), np.float32)
    final_w = np.zeros((C, obj.p), np.float32)
    passes = np.zeros((C, epochs + 1), np.float64)
    total_updates = np.zeros((C,), np.int64)

    for (total, option), members in groups.items():
        taus = [resolved[c][1] for c in members]
        buf_len = max(taus) + 1
        runner = _group_runner(obj.X, obj.y, obj.l2, epochs, total, buf_len,
                               option, drop_prob)
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.asarray([specs[c].seed for c in members]))
        w_fin, hist = runner(
            keys,
            jnp.asarray([specs[c].step_size for c in members], jnp.float32),
            jnp.asarray(taus, jnp.int32),
            jnp.asarray([SCHEME_IDS[specs[c].scheme] for c in members],
                        jnp.int32),
            jnp.asarray([resolved[c][2] for c in members], jnp.int32),
            jnp.tile(w_init[None, :], (len(members), 1)),
        )
        hist = np.asarray(hist)
        w_fin = np.asarray(w_fin)
        ppe = 1.0 + total / obj.n
        for row, c in enumerate(members):
            histories[c] = hist[row]
            final_w[c] = w_fin[row]
            acc = [0.0]
            for _ in range(epochs):        # same float accumulation order as
                acc.append(acc[-1] + ppe)  # run_asysvrg's Python loop
            passes[c] = acc
            total_updates[c] = epochs * total

    return SweepResult(specs=specs, histories=histories,
                       effective_passes=passes, final_w=final_w,
                       total_updates=total_updates)
