"""Paper Table 3: AsySVRG vs Hogwild! — time to gap < 1e-4 at 10 threads,
on the three (synthesized) paper datasets."""
from __future__ import annotations

import numpy as np

from repro.config import SVRGConfig
from repro.core import LogisticRegression, run_asysvrg, run_hogwild
from repro.data.libsvm import make_synthetic_libsvm
from benchmarks.cost_model import measure_primitives, wall_time

P = 10
GAP = 1e-4


def _time_to_gap(kind, obj, f_star, prim, step, max_epochs, seed=0):
    if kind.startswith("asysvrg"):
        scheme = "inconsistent" if kind.endswith("lock") else "unlock"
        res = run_asysvrg(obj, max_epochs,
                          SVRGConfig(scheme=scheme, step_size=step,
                                     num_threads=P, tau=P - 1), seed=seed)
        upd = res.total_updates // max_epochs
    else:
        scheme = "inconsistent" if kind.endswith("lock") else "unlock"
        res = run_hogwild(obj, max_epochs, step, num_threads=P,
                          scheme=scheme, seed=seed)
        upd = res.total_updates // max_epochs
    gaps = np.asarray(res.history) - f_star
    hit = np.nonzero(gaps < GAP)[0]
    if len(hit) == 0:
        return float("inf"), max_epochs
    epochs = int(hit[0])
    return wall_time(scheme, epochs * upd, P, prim), epochs


def run(scale=0.03, quick=False):
    rows = []
    max_e = 10 if quick else 30
    for name in ("rcv1", "real-sim", "news20"):
        ds = make_synthetic_libsvm(name, scale=scale)
        obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
        _, f_star = obj.optimum(max_iter=3000)
        prim = measure_primitives(obj, iters=50 if quick else 100)
        for kind in ("asysvrg-lock", "asysvrg-unlock",
                     "hogwild-lock", "hogwild-unlock"):
            t, e = _time_to_gap(kind, obj, f_star, prim, step=2.0,
                                max_epochs=max_e)
            rows.append({"dataset": name, "method": kind,
                         "wall_s": t, "epochs": e})
    return rows


def main(quick=True):
    rows = run(quick=quick)
    print("name,us_per_call,derived")
    for r in rows:
        wall = r["wall_s"]
        print(f"table3_{r['dataset']}_{r['method']},"
              f"{(wall * 1e6 if np.isfinite(wall) else -1):.1f},"
              f"epochs={r['epochs']}")


if __name__ == "__main__":
    main(quick=False)
