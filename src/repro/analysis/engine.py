"""The repro-lint driver: walk files, run checkers, apply suppressions.

Stdlib-only by design (ast/tokenize/pathlib): the CI lint lane runs
``python -m repro.analysis src tests benchmarks`` on a bare interpreter
with nothing installed — ``src/repro`` is a namespace package, so
importing ``repro.analysis`` never pulls jax.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, NamedTuple, Optional, Set

from repro.analysis.diagnostics import RULES, Diagnostic
from repro.analysis.files import SourceFile, load_file
from repro.analysis.rules import FILE_CHECKERS, PROJECT_CHECKERS
from repro.analysis.suppress import apply_suppressions, scan_comments

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".ruff_cache",
              "node_modules", ".hypothesis"}


def iter_python_files(paths: Iterable[str]) -> Iterator[Path]:
    """Yield .py files under the given files/dirs, sorted, skipping cache
    and VCS directories. A nonexistent path raises — a CI job pointing at
    a renamed directory must fail loudly, not lint nothing."""
    for raw in paths:
        p = Path(raw)
        if p.is_file():
            if p.suffix == ".py":
                yield p
            continue
        if not p.is_dir():
            raise FileNotFoundError(f"lint target does not exist: {raw}")
        for sub in sorted(p.rglob("*.py")):
            if not _SKIP_DIRS.intersection(sub.parts):
                yield sub


class LintResult(NamedTuple):
    files: List[SourceFile]
    diagnostics: List[Diagnostic]   # post-suppression, sorted
    suppressions: int               # total ignore-comments seen

    @property
    def rule_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for d in self.diagnostics:
            counts[d.code] = counts.get(d.code, 0) + 1
        return counts


def _finish(files: List[SourceFile], raw: List[Diagnostic],
            parse_failures: List[Diagnostic],
            select: Optional[Set[str]]) -> LintResult:
    """Suppress per-file, filter by --select, sort and dedup."""
    by_path: Dict[str, List[Diagnostic]] = {}
    for d in raw:
        by_path.setdefault(d.path, []).append(d)
    comments = {sf.path: sf.comments for sf in files}
    check_unused = select is None
    out: List[Diagnostic] = list(parse_failures)
    for path, diags in by_path.items():
        if path not in comments:
            # project checker reached a file outside the scanned set
            # (e.g. cache.py resolved from disk) — honor its suppressions
            try:
                comments[path] = scan_comments(
                    Path(path).read_text(encoding="utf-8"))
            except OSError:
                comments[path] = scan_comments("")
        out.extend(apply_suppressions(path, comments[path], diags,
                                      check_unused=check_unused))
    # files with ignore-comments but no raw findings still need hygiene
    # checks (a stale suppression in an otherwise-clean file)
    for sf in files:
        if sf.path not in by_path and sf.comments.suppressions:
            out.extend(apply_suppressions(sf.path, sf.comments, [],
                                          check_unused=check_unused))
    if select is not None:
        out = [d for d in out if d.code in select]
    suppressions = sum(len(sf.comments.suppressions) for sf in files)
    return LintResult(files, sorted(set(out)), suppressions)


def lint_paths(paths: Iterable[str],
               select: Optional[Set[str]] = None) -> LintResult:
    files: List[SourceFile] = []
    raw: List[Diagnostic] = []
    parse_failures: List[Diagnostic] = []
    for path in iter_python_files(paths):
        sf = load_file(path)
        if sf is None:
            parse_failures.append(Diagnostic(
                str(path), 1, "RL000",
                "file does not parse — fix the syntax error first"))
            continue
        files.append(sf)
        for checker in FILE_CHECKERS:
            raw.extend(checker(sf.path, sf.tree, sf.source))
    for project_checker in PROJECT_CHECKERS:
        raw.extend(project_checker(files))
    return _finish(files, raw, parse_failures, select)


def lint_source(source: str, path: str = "<memory>",
                select: Optional[Set[str]] = None) -> List[Diagnostic]:
    """Lint one in-memory module (the test-fixture entry point). Runs the
    per-file checkers AND the project checkers over the single file."""
    tree = ast.parse(source, filename=path)
    sf = SourceFile(path, source, tree, scan_comments(source))
    raw: List[Diagnostic] = []
    for checker in FILE_CHECKERS:
        raw.extend(checker(sf.path, sf.tree, sf.source))
    for project_checker in PROJECT_CHECKERS:
        raw.extend(project_checker([sf]))
    return _finish([sf], raw, [], select).diagnostics


def parse_select(spec: Optional[str]) -> Optional[Set[str]]:
    """Parse ``--select RL001,RL003`` (None → all rules)."""
    if spec is None:
        return None
    codes = {c.strip().upper() for c in spec.split(",") if c.strip()}
    unknown = codes - set(RULES)
    if unknown:
        raise ValueError(
            f"unknown rule code(s) {sorted(unknown)}; "
            f"known: {sorted(RULES)}")
    return codes
