"""Beyond-paper objectives on the pluggable protocol: an MLP language model
(pytree params, nonconvex) and a nonconvex-regularized logistic regression.

These are the ROADMAP's "nonconvex / deep workloads" onboarding: Huo & Huang
(1604.03584), Lian et al. (1506.08272) and Reddi et al. (1506.06840) show
that the AsySVRG/Hogwild! semantics this repo reproduces extend to nonconvex
objectives — the engine never assumed convexity, only the objective plumbing
did. Both classes obey the vmap-bitwise-stable contract documented in
`repro.core.objective`, so they inherit every engine guarantee the paper
workload has: sweep rows bit-identical across batch compositions, coalesced
service requests bit-identical to standalone runs, sharded == unsharded, and
bit-exact HTTP wire round-trips (tests/test_objective_protocol.py,
tests/test_sweep_sharded.py).

Stability-dictated formulations (see the prototype notes in the protocol
docstring): matmuls are broadcast-multiply + trailing-axis reduces, the
embedding lookup is a one-hot matmul (AD of a gather is a scatter-add whose
batched bit behaviour we do not control; AD of the one-hot matmul is another
stable matmul), and all scalar/sample accumulations run through
`_fixed_order_sum`.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import (
    Objective,
    _fixed_order_sum,
    _log1pexp,
    _margins_stable,
)
from repro.models.layers import _act, rmsnorm


def _stable_matmul(x, W):
    """``x @ W`` with bits stable under a leading vmap batch axis.

    x [..., A, D] @ W [D, B] -> [..., A, B] as an elementwise broadcast
    product reduced over the TRAILING axis — each output element sums its D
    terms in index order, which XLA:CPU keeps bitwise identical with and
    without extra leading batch axes (plain dot_general does not).
    """
    return jnp.sum(x[..., :, None, :] * W.T[None, :, :], axis=-1)


class MLPObjective(Objective):
    """Tiny MLP language model over a packed token corpus (pytree params).

    One sample = one packed sequence; the per-sample loss is the mean token
    cross-entropy of next-token prediction through

        one_hot(tokens) @ embed -> rmsnorm -> act(x @ w1 + b1) @ w2 -> CE

    with the rmsnorm/activation taken from `repro.models.layers`. Params
    are a flat dict pytree {embed, norm, w1, b1, w2}; gradients come from
    `jax.grad` of the stable forward, which keeps the whole objective
    vmap-bitwise-stable (pinned in tests). The loss is NONCONVEX — this is
    the workload class the nonconvex async-SVRG analyses cover.

    ``tokens``/``targets`` are [n, S] int32 arrays, e.g. a materialized
    slice of `repro.data.synthetic_lm.SyntheticLMDataset` (see
    :func:`mlp_lm_objective`).
    """

    def __init__(self, tokens, targets, vocab_size: int, *,
                 d_model: int = 16, d_hidden: int = 32,
                 activation: str = "relu", init_seed: int = 0,
                 init_scale: float = 0.1):
        tokens = np.asarray(tokens)
        targets = np.asarray(targets)
        if tokens.shape != targets.shape or tokens.ndim != 2:
            raise ValueError(
                f"tokens/targets must be matching [n, S] arrays, got "
                f"{tokens.shape} / {targets.shape}")
        if tokens.min() < 0 or tokens.max() >= vocab_size:
            raise ValueError("token ids out of range for vocab_size="
                             f"{vocab_size}")
        self.tokens = jnp.asarray(tokens, jnp.int32)
        self.targets = jnp.asarray(targets, jnp.int32)
        self.n, self.seq_len = tokens.shape
        self.vocab_size = int(vocab_size)
        self.d_model = int(d_model)
        self.d_hidden = int(d_hidden)
        self.activation = str(activation)
        self.init_seed = int(init_seed)
        self.init_scale = float(init_scale)

    # -- protocol ------------------------------------------------------------
    def data_args(self) -> Tuple:
        return (self.tokens, self.targets)

    def init_params(self) -> Dict:
        k_embed, k_w1, k_w2 = jax.random.split(
            jax.random.PRNGKey(self.init_seed), 3)
        s = self.init_scale
        return {
            "embed": s * jax.random.normal(
                k_embed, (self.vocab_size, self.d_model)),
            "norm": jnp.zeros((self.d_model,)),
            "w1": s * jax.random.normal(
                k_w1, (self.d_model, self.d_hidden)),
            "b1": jnp.zeros((self.d_hidden,)),
            "w2": s * jax.random.normal(
                k_w2, (self.d_hidden, self.vocab_size)),
        }

    def static_key(self) -> Tuple:
        return (self.vocab_size, self.d_model, self.d_hidden,
                self.activation, self.init_seed, self.init_scale)

    def _sample_loss(self, data, i, w):
        """Mean token CE of sequence i — every reduce trailing/fixed-order."""
        tokens, targets = data
        tok = tokens[i]
        tgt = targets[i]
        oh = jax.nn.one_hot(tok, self.vocab_size, dtype=jnp.float32)
        x = _stable_matmul(oh, w["embed"])            # [S, D]
        x = rmsnorm(x, w["norm"])
        h = _act(self.activation,
                 _stable_matmul(x, w["w1"]) + w["b1"])  # [S, H]
        logits = _stable_matmul(h, w["w2"])           # [S, V]
        lse = jax.nn.logsumexp(logits, axis=-1)       # trailing row-reduce
        gold = jnp.take_along_axis(logits, tgt[:, None], axis=-1)[:, 0]
        return _fixed_order_sum(lse - gold) / self.seq_len

    def loss_fixed_order(self, data, w):
        """f(w) = (1/n) Σ_i CE_i(w), accumulated strictly in sample order."""
        n = self.num_samples(data)

        def body(acc, i):
            return acc + self._sample_loss(data, i, w), None

        total, _ = jax.lax.scan(body, jnp.zeros(()), jnp.arange(n))
        return total / n

    def full_grad_stable(self, data, w):
        """∇f(w): per-sample grads accumulated in fixed sample order (a
        lax.scan of `jax.grad` calls — order-deterministic, so stable)."""
        n = self.num_samples(data)
        grad_i = jax.grad(lambda wi, i: self._sample_loss(data, i, wi))

        def body(acc, i):
            g = grad_i(w, i)
            return jax.tree.map(jnp.add, acc, g), None

        zeros = jax.tree.map(jnp.zeros_like, w)
        total, _ = jax.lax.scan(body, zeros, jnp.arange(n))
        return jax.tree.map(lambda g: g / n, total)

    def sample_grad_stable(self, data, i, w):
        return jax.grad(lambda wi: self._sample_loss(data, i, wi))(w)


def mlp_lm_objective(n: int = 64, *, vocab_size: int = 32, seq_len: int = 8,
                     d_model: int = 16, d_hidden: int = 32,
                     activation: str = "relu", seed: int = 0,
                     init_seed: int = 0) -> MLPObjective:
    """An `MLPObjective` over a materialized `SyntheticLMDataset` slice:
    ``n`` deterministic packed sequences (counter-based — same (seed, n)
    always yields the same corpus, restart- and process-independent)."""
    from repro.data.synthetic_lm import SyntheticLMDataset

    ds = SyntheticLMDataset(vocab_size=vocab_size, seq_len=seq_len,
                            global_batch=n, seed=seed)
    batch = ds.batch_at(0)
    return MLPObjective(batch["tokens"], batch["targets"], vocab_size,
                        d_model=d_model, d_hidden=d_hidden,
                        activation=activation, init_seed=init_seed)


class NonconvexLogistic(Objective):
    """Logistic loss + a smoothly-clipped (log-penalty style) NONCONVEX
    regularizer on the libsvm sets:

        f(w) = (1/n) Σ_i log(1 + exp(-y_i x_i·w)) + λ Σ_j α w_j² / (1 + α w_j²)

    The regularizer saturates at λ per coordinate (the "corrected"/clipped
    penalty family the nonconvex SVRG papers analyze — Reddi et al.
    1506.06840 §5; it is bounded, smooth, and nonconvex), so large weights
    stop being pushed toward zero — a sparsity-friendlier prior than ℓ2.
    Params are a single flat (p,) vector; like `LogisticRegression` the
    flat adapters run with zero ravel indirection. α controls the clip
    sharpness; α→0 with λ/α fixed recovers ridge.
    """

    def __init__(self, X, y, *, lam: float = 1e-3, alpha: float = 10.0):
        self.X = jnp.asarray(X)
        self.y = jnp.asarray(y)
        self.lam = float(lam)
        self.alpha = float(alpha)
        self.n, self.p = self.X.shape

    # -- protocol ------------------------------------------------------------
    def data_args(self) -> Tuple:
        return (self.X, self.y, jnp.float32(self.lam),
                jnp.float32(self.alpha))

    def init_params(self):
        return jnp.zeros(self.p)

    def static_key(self) -> Tuple:
        return ()

    def _penalty(self, lam, alpha, w):
        aw2 = alpha * w * w
        return lam * _fixed_order_sum(aw2 / (1.0 + aw2))

    def _penalty_grad(self, lam, alpha, w):
        den = 1.0 + alpha * w * w
        return lam * 2.0 * alpha * w / (den * den)

    def loss_fixed_order(self, data, w):
        X, y, lam, alpha = data
        t = _log1pexp(-_margins_stable(X, y, w))
        return (_fixed_order_sum(t) / X.shape[0]
                + self._penalty(lam, alpha, w))

    def full_grad_stable(self, data, w):
        X, y, lam, alpha = data
        n = X.shape[0]
        s = jax.nn.sigmoid(-_margins_stable(X, y, w))
        return (jnp.sum((-(y * s))[:, None] * X, axis=0) / n
                + self._penalty_grad(lam, alpha, w))

    def sample_grad_stable(self, data, i, w):
        X, y, lam, alpha = data
        x = X[i]
        yi = y[i]
        s = jax.nn.sigmoid(-yi * jnp.sum(x * w, axis=-1))
        return -yi * s * x + self._penalty_grad(lam, alpha, w)

    # flat == pytree for a (p,) parameter vector: skip the generic bridge
    flat_loss = loss_fixed_order
    flat_full_grad = full_grad_stable

    def flat_sample_grad(self, data, i, w_flat):
        return self.sample_grad_stable(data, i, w_flat)
