"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8.
[hf:Qwen/Qwen3-30B-A3B (family); hf]

94L, d_model=4096, 64 heads (kv=4), head_dim=128, per-expert d_ff=1536,
vocab=151936, 128 routed experts top-8, no shared experts, QK-norm.
~235B total / ~22B active — the roofline MODEL_FLOPS uses N_active.
"""
from repro.config import ModelConfig
from repro.configs.registry import register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,                    # all layers MoE
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    qk_norm=True,
    norm="rmsnorm",
    activation="silu",
    glu=True,
))
