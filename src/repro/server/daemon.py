"""Background flush daemon: size/deadline-triggered coalesced dispatch.

`SweepService` (PR 4) coalesces across tenants only when someone calls
``flush()`` — so a deployment either flushes eagerly on every submit (no
cross-tenant coalescing, drifting batch widths that retrace the runner
cache) or parks clients behind an explicit barrier. This module is the
async alternative, the serving-layer echo of the paper's thesis that
asynchronous scheduling beats synchronous coordination: submits return
immediately, a background thread triggers the coalesced dispatch when a
`FlushPolicy` says the batch is worth running, and results land through
the service's condition variable (``wait_result``) with no client-side
barrier anywhere.

Policy triggers (whichever fires first):

  * SIZE — pending rows ≥ ``max_rows``: the batch already fills a worthwhile
    dispatch; waiting longer only adds latency.
  * DEADLINE — the OLDEST queued request has waited ``max_delay_ms``: bounded
    worst-case queueing latency, however quiet the queue is.

``stable_widths=True`` installs a `WidthRegistry` on the service: merged
groups are padded up to previously-dispatched row widths, so the warm path
stays at 0 compiles even as tenant arrival patterns jitter the natural
batch width (the vmap row count is part of the traced shape — a new width
retraces even on a runner-cache hit). Pad rows repeat a real member and
are sliced off before demux; bits never change, only wasted FLOPs bounded
by ``max_pad_factor``.

Giant single requests can't be sliced by admission control (results are
per-request atomic), so the daemon time-slices them THROUGH the engine:
:meth:`ServeDaemon.submit_job` runs a sweep group-by-group via the
checkpointed ``SweepService.run_job(max_groups=…)`` between flushes — one
tenant's thousand-row grid proceeds a few compiled groups per turn while
everyone else's small requests keep flushing in between.
"""
from __future__ import annotations

import bisect
import dataclasses
import tempfile
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.checkpoint import Checkpointer
from repro.core.sweep import SweepResult, SweepSpec
from repro.server.fairness import FairShare
from repro.service.api import SweepService


@dataclasses.dataclass(frozen=True)
class FlushPolicy:
    """When the daemon dispatches, and how it shapes the batch.

    ``max_rows`` — size trigger: flush as soon as this many spec rows are
    queued. ``max_delay_ms`` — deadline trigger: flush once the oldest
    queued request has waited this long (the worst-case queueing latency a
    client sees on an idle server). ``stable_widths`` — pad merged groups
    to previously-compiled row widths (0 compiles on the warm path);
    ``max_pad_factor`` bounds the padding waste: a recorded width is only
    reused while pad rows ≤ (factor−1)× real rows, beyond that a new width
    is compiled and recorded. ``job_groups_per_slice`` — how many compiled
    groups one background-job turn may dispatch between flushes.
    ``heartbeat_stall_s`` — how stale the flush thread's per-iteration
    heartbeat may grow before ``/healthz`` reports the daemon STALLED
    (503): must comfortably exceed one flush's dispatch time, since the
    loop only stamps between turns.
    """
    max_rows: int = 64
    max_delay_ms: float = 50.0
    stable_widths: bool = True
    max_pad_factor: float = 2.0
    job_groups_per_slice: int = 1
    heartbeat_stall_s: float = 30.0

    def __post_init__(self):
        if self.max_rows < 1:
            raise ValueError(f"max_rows must be >= 1, got {self.max_rows}")
        if self.max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0, got "
                             f"{self.max_delay_ms}")
        if self.max_pad_factor < 1.0:
            raise ValueError("max_pad_factor must be >= 1.0, got "
                             f"{self.max_pad_factor}")
        if self.job_groups_per_slice < 1:
            raise ValueError("job_groups_per_slice must be >= 1, got "
                             f"{self.job_groups_per_slice}")
        if self.heartbeat_stall_s <= 0:
            raise ValueError("heartbeat_stall_s must be > 0, got "
                             f"{self.heartbeat_stall_s}")


class WidthRegistry:
    """Remembers the row widths each group shape has already been traced
    at; as a `repro.service.scheduler.WidthPolicy` it pads a group up to
    the smallest remembered width within ``max_pad_factor`` of the natural
    one, else records the natural width as newly compiled."""

    def __init__(self, max_pad_factor: float = 2.0):
        self.max_pad_factor = max_pad_factor
        self._widths: Dict[tuple, List[int]] = {}  # guarded-by: _lock
        self._lock = threading.Lock()

    def __call__(self, key: tuple, group_epochs: int, natural: int) -> int:
        with self._lock:
            widths = self._widths.setdefault((key, group_epochs), [])
            i = bisect.bisect_left(widths, natural)
            if i < len(widths) and widths[i] <= natural * self.max_pad_factor:
                return widths[i]
            widths.insert(i, natural)
            return natural

    def known_widths(self, key: tuple, group_epochs: int) -> Tuple[int, ...]:
        with self._lock:
            return tuple(self._widths.get((key, group_epochs), ()))


class JobHandle:
    """A time-sliced background job's future. ``result()`` blocks until the
    daemon has dispatched every group (or surfaces the job's error)."""

    def __init__(self, job_id: int, tenant: str,
                 specs: Tuple[SweepSpec, ...], epochs: Optional[int]):
        self.job_id = job_id
        self.tenant = tenant
        self.specs = specs
        self.epochs = epochs
        self._done = threading.Event()
        self._result: Optional[SweepResult] = None
        self._error: Optional[BaseException] = None
        self.slices = 0                  # run_job turns taken so far

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> SweepResult:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"job {self.job_id} not finished within {timeout}s "
                f"({self.slices} slices dispatched)")
        if self._error is not None:
            raise self._error
        return self._result

    def _finish(self, result: Optional[SweepResult],
                error: Optional[BaseException]) -> None:
        self._result, self._error = result, error
        self._done.set()


@dataclasses.dataclass
class DaemonStats:
    """What the daemon has done (exported by `repro.server.metrics`)."""
    size_flushes: int = 0
    deadline_flushes: int = 0
    forced_flushes: int = 0          # explicit flush_now() calls
    flush_errors: int = 0
    job_slices: int = 0
    jobs_completed: int = 0
    jobs_failed: int = 0


class ServeDaemon:
    """Owns the flush thread: watches the service queue, fires policy-
    triggered coalesced flushes (optionally through a `FairShare`
    selector), and time-slices background jobs in the gaps.

    One daemon per service; ``start()``/``stop()`` bracket the thread
    (``stop(drain=True)`` flushes whatever is still queued and finishes
    every submitted job before returning, so shutdown loses nothing).
    """

    _POLL_S = 0.25               # idle heartbeat; submits wake us early

    def __init__(self, service: SweepService,
                 policy: FlushPolicy = FlushPolicy(), *,
                 fairness: Optional[FairShare] = None,
                 spool_dir: Optional[str] = None):
        self.service = service
        self.policy = policy
        self.fairness = fairness
        # stats/last_error are mutated by the flush thread AND by HTTP
        # threads entering through flush_now(); every touch takes _lock
        # (readers go through stats_snapshot()/last_error_snapshot())
        self.stats = DaemonStats()  # guarded-by: _lock
        self.last_error: Optional[BaseException] = None  # guarded-by: _lock
        self._spool_dir = spool_dir
        self._widths = (WidthRegistry(policy.max_pad_factor)
                        if policy.stable_widths else None)
        self._jobs: List[Tuple[JobHandle, Checkpointer, bool]] = []  # guarded-by: _lock
        self._next_job_id = 0  # guarded-by: _lock
        # job-id -> handle registry for the HTTP tier (POST /job submits,
        # GET /job/<id> polls). FIFO-bounded like the service's result
        # store: finished handles of a long-lived server age out, and a
        # client polling an evicted id gets the same KeyError an unknown
        # one raises.
        self._handles: "OrderedDict[int, JobHandle]" = OrderedDict()  # guarded-by: _lock
        self._max_handles = 256
        # monotonic stamp the flush thread refreshes once per loop turn;
        # /healthz compares its age against policy.heartbeat_stall_s
        self._heartbeat: Optional[float] = None  # guarded-by: _lock
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._drain = True               # stop() overrides before _stop
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ServeDaemon":
        if self._thread is not None:
            raise RuntimeError("daemon already started")
        if self._widths is not None and self.service.width_policy is None:
            self.service.width_policy = self._widths
        self.service.add_submit_listener(self._wake.set)
        self._drain = True
        self._stop.clear()
        with self._lock:
            self._heartbeat = time.monotonic()   # liveness from t=0
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="sweep-flush-daemon")
        self._thread.start()
        return self

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Stop the flush thread. ``drain=True`` (default) first flushes
        whatever is queued and finishes every submitted job, so shutdown
        loses nothing; ``drain=False`` abandons queued work (it stays
        pending on the service). ``timeout=None`` waits for the drain to
        complete; with a finite timeout, an overrun raises and leaves the
        daemon installed so ``stop()`` can be retried."""
        if self._thread is None:
            return
        self._drain = drain
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise RuntimeError(
                f"flush daemon still draining after {timeout}s; call "
                "stop() again to keep waiting")
        self._thread = None
        self.service.remove_submit_listener(self._wake.set)
        if self.service.width_policy is self._widths:
            self.service.width_policy = None
        err = self.last_error_snapshot()
        if drain and self.service.pending() and err is not None:
            raise RuntimeError(
                f"drain left {self.service.pending()} request(s) queued "
                "after repeated dispatch failures; they remain pending on "
                "the service") from err

    def __enter__(self) -> "ServeDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ job lane
    def submit_job(self, specs: Sequence[SweepSpec],
                   epochs: Optional[int] = None, *,
                   tenant: str = "default",
                   checkpointer: Optional[Checkpointer] = None) -> JobHandle:
        """Queue a giant sweep for time-sliced execution: the daemon runs
        it ``job_groups_per_slice`` compiled groups per turn via
        ``SweepService.run_job``, between regular flushes, so it can't
        starve the request queue. Without an explicit ``checkpointer`` the
        job spools scratch checkpoints under a temp dir that is deleted on
        completion (crash-resume then needs an explicit one)."""
        owns_spool = checkpointer is None
        if owns_spool:
            checkpointer = Checkpointer(
                tempfile.mkdtemp(prefix="sweep-job-", dir=self._spool_dir))
        with self._lock:
            handle = JobHandle(self._next_job_id, tenant, tuple(specs),
                               epochs)
            self._next_job_id += 1
            self._jobs.append((handle, checkpointer, owns_spool))
            self._handles[handle.job_id] = handle
            while len(self._handles) > self._max_handles:
                self._handles.popitem(last=False)
        self._wake.set()
        return handle

    def job(self, job_id: int) -> JobHandle:
        """The registered handle for ``job_id`` (HTTP ``GET /job/<id>``);
        raises KeyError for an unknown or aged-out id."""
        with self._lock:
            return self._handles[job_id]

    def jobs_pending(self) -> int:
        with self._lock:
            return len(self._jobs)

    # ------------------------------------------------------------ snapshots
    def stats_snapshot(self) -> DaemonStats:
        """A consistent COPY of the counters. The live ``stats`` object is
        mutated concurrently by the flush thread and by HTTP threads inside
        ``flush_now``; exporters (`repro.server.metrics`) must read through
        here, never the live object."""
        with self._lock:
            return dataclasses.replace(self.stats)

    def last_error_snapshot(self) -> Optional[BaseException]:
        """The most recent dispatch failure (None once a flush succeeds)."""
        with self._lock:
            return self.last_error

    def running(self) -> bool:
        """True while the flush thread exists and is alive."""
        thread = self._thread
        return thread is not None and thread.is_alive()

    def heartbeat_age_s(self) -> Optional[float]:
        """Seconds since the flush thread last completed a loop turn (None
        before the first ``start()``). The loop stamps at least every
        ``_POLL_S`` while healthy; an age past
        ``policy.heartbeat_stall_s`` means a flush is wedged inside XLA or
        the thread died — ``/healthz`` turns 503 on either."""
        with self._lock:
            if self._heartbeat is None:
                return None
            return time.monotonic() - self._heartbeat

    # ------------------------------------------------------------ triggers
    def _flush_due(self) -> Optional[str]:
        """Which policy trigger (if any) says the queue should flush now."""
        rows = self.service.pending_rows()
        if rows == 0:
            return None
        if rows >= self.policy.max_rows:
            return "size"
        age = self.service.oldest_pending_age()
        if age is not None and age * 1000.0 >= self.policy.max_delay_ms:
            return "deadline"
        return None

    def _next_deadline_s(self) -> Optional[float]:
        """Seconds until the oldest queued request hits the deadline."""
        age = self.service.oldest_pending_age()
        if age is None:
            return None
        return max(0.0, self.policy.max_delay_ms / 1000.0 - age)

    def flush_now(self) -> List[int]:
        """Force one fair-share flush from the caller's thread (the HTTP
        /flush endpoint and the drain path)."""
        with self._lock:
            self.stats.forced_flushes += 1
        return self._flush_once()

    def _flush_once(self) -> List[int]:
        selector = self.fairness.select if self.fairness is not None else None
        try:
            done = self.service.flush(selector)   # dispatch runs unlocked
            with self._lock:
                self.last_error = None
            return done
        except Exception as e:             # requests were re-queued by the
            with self._lock:               # service; remember and back off
                self.stats.flush_errors += 1   # so a poisoned dispatch
                self.last_error = e            # cannot spin the daemon hot
            return []

    # ------------------------------------------------------------ main loop
    def _run(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                self._heartbeat = time.monotonic()
            err = self.last_error_snapshot()   # one coherent view per turn
            trigger = self._flush_due()
            if trigger is not None and err is None:
                with self._lock:
                    setattr(self.stats, f"{trigger}_flushes",
                            getattr(self.stats, f"{trigger}_flushes") + 1)
                self._flush_once()
                continue                   # fairness may have left a slice
            if err is None and self._job_slice():
                continue                   # more job groups may be waiting
            wait = self._next_deadline_s()
            if wait is not None and wait <= 0 and err is None:
                continue                   # deadline crossed since the
            #                                trigger check: re-check now
            if wait is None or err is not None:
                wait = self._POLL_S        # idle heartbeat / error backoff
            self._wake.wait(min(wait, self._POLL_S))
            self._wake.clear()
            with self._lock:
                if self.last_error is not None:
                    self.last_error = None  # one backoff period, then retry
        if self._drain:
            # "shutdown loses nothing": retry erroring flushes a few times
            # before giving up; a persistent failure is surfaced by stop()
            # (last_error + still-pending requests), not swallowed
            failures = 0
            while self.service.pending() and failures < 3:
                if self._flush_once():
                    failures = 0
                else:
                    failures += 1
            while self._job_slice():
                pass

    def _job_slice(self) -> bool:
        """Run ONE time-slice of the head background job; True if a slice
        was dispatched (the job rotates to the back of the lane so several
        giant jobs interleave fairly)."""
        with self._lock:
            if not self._jobs:
                return False
            handle, ckpt, owns_spool = self._jobs.pop(0)
        try:
            # tenant + progress channel ride along: each slice publishes a
            # live event on "job-<id>" when progress streaming is enabled,
            # and the watchdog (if configured) applies this tenant's policy
            result, done = self.service.run_job(
                handle.specs, handle.epochs, checkpointer=ckpt,
                max_groups=self.policy.job_groups_per_slice,
                tenant=handle.tenant,
                progress_id=f"job-{handle.job_id}")
        except Exception as e:
            with self._lock:
                self.stats.jobs_failed += 1
            handle._finish(None, e)
            if owns_spool:
                ckpt.delete()
            return True
        handle.slices += 1
        with self._lock:
            self.stats.job_slices += 1
            if done:
                self.stats.jobs_completed += 1
        if done:
            handle._finish(result, None)
            if owns_spool:
                ckpt.delete()
        else:
            with self._lock:
                self._jobs.append((handle, ckpt, owns_spool))
        return True
