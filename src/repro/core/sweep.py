"""Multi-algorithm sweep engine: the whole experiment grid in ONE jit.

The paper's tables and figures are *comparisons* — AsySVRG vs Hogwild! vs
serial SVRG over (reading scheme × thread count × step size × seed × τ).
The benchmark layer used to run each cell as its own `run_*` call — one
trace, one compile, and epochs × Python dispatches PER CELL. This module
turns the grid into data: every configuration becomes a row of scalar
arrays (seed, algo, scheme-id, step-size, τ, delay-id, decay), the epoch
body is `vmap`-ed over that row axis, and a `lax.scan` drives the epochs —
so N×compile becomes 1×compile and the entire grid advances in lockstep
through one XLA program.

The `algo` axis selects the epoch engine per row:

  * ``"asysvrg"`` — Algorithm 1 via `asysvrg._epoch_core` (the paper's
    contribution: SVRG control variate under bounded-delay reads);
  * ``"hogwild"`` — the baseline via `hogwild._hogwild_epochs_core`, same
    bounded-delay read semantics, no control variate, with the per-epoch
    γ ← decay·γ schedule threaded through the scan carry so decay lives
    inside the compiled program;
  * ``"svrg"``    — serial SVRG routed through the SAME asysvrg path as the
    zero-delay degenerate case (τ=0, zero delay schedule, consistent reads
    — "If τ=0, AsySVRG degenerates to the sequential version of SVRG").
    SVRG rows therefore ride in the same vmapped batch (same jit) as
    asysvrg rows whenever their M̃ and option agree.

Bit-exactness contract: per-config loss histories and final iterates are
BIT-IDENTICAL to sequential `run_asysvrg` / `run_hogwild` calls with the
same specs (tests/test_sweep.py, tests/test_sweep_hogwild.py). This is what
makes the sweep a drop-in replacement for the benchmark loops rather than a
statistical approximation of them. The contract holds because both epoch
cores and `loss_fixed_order` only use reductions whose bits survive vmap
batching (see repro.core.objective).

Configurations may disagree on M̃ (a static scan bound): `run_sweep` groups
specs by (engine, M̃, option), compiles once per group, and reassembles rows
in input order. A grid over schemes / seeds / steps / τ / delay-kinds is
one group per algo; adding thread counts usually stays at one group too,
since M = ⌊2n/p⌋ keeps pM ≈ 2n (e.g. any p dividing 2n).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SVRGConfig
from repro.core.asysvrg import (
    DELAY_IDS,
    SCHEME_IDS,
    _epoch_core,
    _resolve_steps,
)
from repro.core.hogwild import _hogwild_epochs_core, _resolve_hogwild_steps
from repro.core.objective import LogisticRegression, loss_fixed_order

ALGOS = ("asysvrg", "hogwild", "svrg")
# svrg rows run on the asysvrg engine (τ=0 degenerate case), so two engines
_ENGINE_ASYSVRG = "asysvrg"
_ENGINE_HOGWILD = "hogwild"


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One grid cell: the knobs Tables 2–3 / Fig. 1 vary.

    ``algo`` picks the epoch engine ("asysvrg" / "hogwild" / "svrg").
    τ conventions follow each algorithm's sequential driver:
      * asysvrg: ``tau=0`` means "derive τ = p−1" (SVRGConfig convention);
        ``num_threads``/``inner_steps`` fix M̃ = pM exactly as SVRGConfig.
      * hogwild: ``tau=-1`` derives τ = p−1 and ``tau=0`` is genuinely zero
        delay (`run_hogwild` convention); M̃ = (n // p)·p.
      * svrg: τ forced to 0 and reads forced consistent — the degenerate
        case; M̃ = ``inner_steps`` or 2n (`run_svrg` convention).
    ``decay`` is the per-epoch γ ← decay·γ factor (hogwild only).
    """
    seed: int = 0
    scheme: str = "inconsistent"
    step_size: float = 0.1
    tau: int = 0
    delay_kind: str = "fixed"
    num_threads: int = 8
    inner_steps: int = 0
    option: int = 2
    algo: str = "asysvrg"
    decay: float = 0.9

    def to_config(self) -> SVRGConfig:
        return SVRGConfig(scheme=self.scheme, step_size=self.step_size,
                          num_threads=self.num_threads, tau=self.tau,
                          inner_steps=self.inner_steps, option=self.option)


class SweepResult(NamedTuple):
    specs: Tuple[SweepSpec, ...]
    histories: np.ndarray         # [C, epochs+1] loss after each epoch
    effective_passes: np.ndarray  # [C, epochs+1] cumulative effective passes
    final_w: np.ndarray           # [C, p]
    total_updates: np.ndarray     # [C] updates applied over all epochs

    def row(self, c: int) -> Dict:
        """One config as a flat record (for CSV-ish reporting)."""
        s = self.specs[c]
        return {**dataclasses.asdict(s),
                "history": self.histories[c],
                "effective_passes": self.effective_passes[c],
                "total_updates": int(self.total_updates[c])}


def make_grid(schemes: Sequence[str] = ("consistent", "inconsistent", "unlock"),
              seeds: Sequence[int] = (0,),
              step_sizes: Sequence[float] = (0.1,),
              taus: Sequence[int] = (0,),
              delay_kinds: Sequence[str] = ("fixed",),
              num_threads: int = 8,
              inner_steps: int = 0,
              option: int = 2,
              algo: str = "asysvrg",
              decay: float = 0.9) -> List[SweepSpec]:
    """Cartesian grid over the paper's experiment axes, outermost-first.

    The ``taus`` axis uses ONE convention for every algo: 0 means "derive
    τ = p−1". For hogwild rows that is translated to the driver's ``-1``
    sentinel, so the default grid is a real asynchronous baseline, not the
    zero-delay degenerate one (build `SweepSpec(algo="hogwild", tau=0)`
    directly for genuinely zero delay).
    """
    if algo == "hogwild":
        taus = [-1 if t == 0 else t for t in taus]
    return [
        SweepSpec(seed=seed, scheme=scheme, step_size=step, tau=tau,
                  delay_kind=kind, num_threads=num_threads,
                  inner_steps=inner_steps, option=option, algo=algo,
                  decay=decay)
        for scheme in schemes
        for seed in seeds
        for step in step_sizes
        for tau in taus
        for kind in delay_kinds
    ]


class _Resolved(NamedTuple):
    engine: str          # "asysvrg" | "hogwild" (svrg routes to asysvrg)
    total: int           # M̃, the static inner-scan bound
    tau: int
    scheme_id: int
    delay_id: int
    option: int          # 0 for hogwild (engine has no option switch)
    passes_per_epoch: float


def _resolve(obj: LogisticRegression, spec: SweepSpec) -> _Resolved:
    """Per-spec resolution, delegating to each algorithm's own arithmetic."""
    if spec.algo not in ALGOS:
        raise ValueError(f"unknown algo {spec.algo!r}")
    if spec.delay_kind not in DELAY_IDS:
        raise ValueError(f"unknown delay schedule {spec.delay_kind!r}")
    if spec.scheme not in SCHEME_IDS:
        raise ValueError(f"unknown scheme {spec.scheme!r}")

    if spec.algo == "hogwild":
        _, total, tau = _resolve_hogwild_steps(obj.n, spec.num_threads,
                                               spec.tau)
        delay_id = DELAY_IDS["zero"] if tau == 0 else DELAY_IDS[spec.delay_kind]
        return _Resolved(_ENGINE_HOGWILD, total, tau,
                         SCHEME_IDS[spec.scheme], delay_id, 0, 1.0)

    if spec.algo == "svrg":
        # the zero-delay degenerate case on the asysvrg engine (paper §3)
        total = spec.inner_steps or 2 * obj.n
        return _Resolved(_ENGINE_ASYSVRG, total, 0,
                         SCHEME_IDS["consistent"], DELAY_IDS["zero"],
                         spec.option, 1.0 + total / obj.n)

    _, _, total, tau = _resolve_steps(obj, spec.to_config())
    delay_id = DELAY_IDS["zero"] if tau == 0 else DELAY_IDS[spec.delay_kind]
    return _Resolved(_ENGINE_ASYSVRG, total, tau, SCHEME_IDS[spec.scheme],
                     delay_id, spec.option, 1.0 + total / obj.n)


def _asysvrg_group_runner(X, y, l2: float, epochs: int, total: int,
                          buf_len: int, option: int, drop_prob: float):
    """jit(vmap(per-config epochs-scan)) for one asysvrg/svrg group."""

    def per_config(key, eta, tau, scheme_id, delay_id, w0):
        loss0 = loss_fixed_order(X, y, l2, w0)

        def step(carry, _):
            w, key = carry
            key, sub = jax.random.split(key)
            w_next = _epoch_core(
                X, y, l2, w, sub, eta, tau, scheme_id, delay_id,
                total=total, buf_len=buf_len, option=option,
                drop_prob=drop_prob)
            return (w_next, key), loss_fixed_order(X, y, l2, w_next)

        (w_fin, _), losses = jax.lax.scan(step, (w0, key), None, length=epochs)
        return w_fin, jnp.concatenate([loss0[None], losses])

    return jax.jit(jax.vmap(per_config))


def _hogwild_group_runner(X, y, l2: float, epochs: int, total: int,
                          buf_len: int, drop_prob: float):
    """jit(vmap(multi-epoch Hogwild! scan, γ-decay in the carry))."""

    def per_config(key, gamma0, decay, tau, scheme_id, delay_id, w0):
        return _hogwild_epochs_core(
            X, y, l2, w0, key, gamma0, decay, tau, scheme_id, delay_id,
            epochs=epochs, total=total, buf_len=buf_len,
            drop_prob=drop_prob)

    return jax.jit(jax.vmap(per_config))


def run_sweep(obj: LogisticRegression, epochs: int,
              specs: Sequence[SweepSpec], *, w0=None,
              drop_prob: float = 0.02) -> SweepResult:
    """Run every spec for `epochs` outer iterations in one compiled program
    per (engine, M̃, option) group. Histories/final iterates are bit-identical
    to per-spec `run_asysvrg` / `run_hogwild` calls."""
    specs = tuple(specs)
    if not specs:
        raise ValueError("empty sweep")
    w_init = jnp.zeros(obj.p) if w0 is None else jnp.asarray(w0)

    resolved = [_resolve(obj, s) for s in specs]
    groups: Dict[Tuple[str, int, int], List[int]] = {}
    for c, r in enumerate(resolved):
        groups.setdefault((r.engine, r.total, r.option), []).append(c)

    C = len(specs)
    histories = np.zeros((C, epochs + 1), np.float32)
    final_w = np.zeros((C, obj.p), np.float32)
    passes = np.zeros((C, epochs + 1), np.float64)
    total_updates = np.zeros((C,), np.int64)

    for (engine, total, option), members in groups.items():
        taus = [resolved[c].tau for c in members]
        buf_len = max(taus) + 1
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.asarray([specs[c].seed for c in members]))
        etas = jnp.asarray([specs[c].step_size for c in members],
                           jnp.float32)
        taus_a = jnp.asarray(taus, jnp.int32)
        scheme_ids = jnp.asarray([resolved[c].scheme_id for c in members],
                                 jnp.int32)
        delay_ids = jnp.asarray([resolved[c].delay_id for c in members],
                                jnp.int32)
        w0_rows = jnp.tile(w_init[None, :], (len(members), 1))

        if engine == _ENGINE_HOGWILD:
            runner = _hogwild_group_runner(obj.X, obj.y, obj.l2, epochs,
                                           total, buf_len, drop_prob)
            decays = jnp.asarray([specs[c].decay for c in members],
                                 jnp.float32)
            w_fin, hist = runner(keys, etas, decays, taus_a, scheme_ids,
                                 delay_ids, w0_rows)
        else:
            runner = _asysvrg_group_runner(obj.X, obj.y, obj.l2, epochs,
                                           total, buf_len, option, drop_prob)
            w_fin, hist = runner(keys, etas, taus_a, scheme_ids, delay_ids,
                                 w0_rows)

        hist = np.asarray(hist)
        w_fin = np.asarray(w_fin)
        for row, c in enumerate(members):
            histories[c] = hist[row]
            final_w[c] = w_fin[row]
            ppe = resolved[c].passes_per_epoch
            acc = [0.0]
            for _ in range(epochs):        # same float accumulation order as
                acc.append(acc[-1] + ppe)  # the sequential drivers' loops
            passes[c] = acc
            total_updates[c] = epochs * total

    return SweepResult(specs=specs, histories=histories,
                       effective_passes=passes, final_w=final_w,
                       total_updates=total_updates)
