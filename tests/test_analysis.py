"""repro-lint (repro.analysis) — per-rule fixtures and tree-level gates.

Each rule gets a known-bad fixture (must be diagnosed, with the right
code, on the right line) and a known-good twin (must stay silent): the
linter's job is to catch the seeded violation AND not cry wolf on the
sanctioned pattern. The capstone test pins the shipped tree clean — the
same invocation the CI repro-lint lane runs.

The linter is stdlib-only, so nothing here imports jax.
"""
import configparser
import re
import subprocess
import sys
from pathlib import Path

from repro.analysis import RULES, lint_paths, lint_source

REPO = Path(__file__).resolve().parent.parent


def codes(diags):
    return [d.code for d in diags]


# --------------------------------------------------------------------- RL001
BAD_RL001_AXISLESS = """\
import jax.numpy as jnp

def sample_grad_stable(x, w):
    return jnp.sum(x * w)
"""

BAD_RL001_MATMUL = """\
import jax.numpy as jnp

def loss_fixed_order(X, w):
    margins = X @ w
    return jnp.dot(margins, margins)
"""

GOOD_RL001 = """\
import jax.numpy as jnp

def sample_grad_stable(x, w):
    return jnp.sum(x * w, axis=-1)

def loss_fixed_order(X, w):
    return _fixed_order_sum(X * w[None, :])

def unstable_helper(X, w):
    return X @ w  # out of scope: not a *_stable / loss_fixed_order name
"""


def test_rl001_flags_axisless_reduce():
    diags = lint_source(BAD_RL001_AXISLESS)
    assert codes(diags) == ["RL001"]
    assert diags[0].line == 4
    assert "axis-less `jnp.sum`" in diags[0].message


def test_rl001_flags_matmul_and_dot():
    diags = lint_source(BAD_RL001_MATMUL)
    assert codes(diags) == ["RL001", "RL001"]
    assert [d.line for d in diags] == [4, 5]


def test_rl001_good_patterns_clean():
    assert lint_source(GOOD_RL001) == []


# --------------------------------------------------------------------- RL002
BAD_RL002_CAPTURE = """\
import jax
import jax.numpy as jnp

def driver(obj, w):
    data = obj.data_args()
    loss_fn = jax.jit(lambda w_: obj.flat_loss(data, w_))
    return loss_fn(w)
"""

BAD_RL002_TRACER_IF = """\
def _epoch_core(w, eta, *, drop_prob):
    if eta > 0:
        w = w * eta
    return w
"""

BAD_RL002_UNHASHABLE = """\
class Obj:
    def runner_static_key(self):
        return [self.n, self.p]
"""

GOOD_RL002 = """\
import jax
import jax.numpy as jnp

def driver(obj, w):
    data = obj.data_args()
    loss_fn = jax.jit(lambda d, w_: obj.flat_loss(d, w_))
    return loss_fn(data, w)

def _epoch_core(w, eta, *, drop_prob):
    if drop_prob > 0:          # kw-only param: static by convention
        w = w * eta
    if w.ndim == 2:            # shape probe: static under tracing
        w = w[0]
    return w

class Obj:
    def runner_static_key(self):
        return (self.n, tuple(sorted(self.names)))
"""


def test_rl002_flags_array_closure_capture():
    diags = lint_source(BAD_RL002_CAPTURE)
    assert codes(diags) == ["RL002"]
    assert diags[0].line == 6
    assert "closes over array-valued 'data'" in diags[0].message


def test_rl002_flags_python_if_on_tracer():
    diags = lint_source(BAD_RL002_TRACER_IF)
    assert codes(diags) == ["RL002"]
    assert diags[0].line == 2
    assert "'eta'" in diags[0].message


def test_rl002_flags_unhashable_static_key():
    diags = lint_source(BAD_RL002_UNHASHABLE)
    assert codes(diags) == ["RL002"]
    assert "unhashable" in diags[0].message


def test_rl002_good_patterns_clean():
    assert lint_source(GOOD_RL002) == []


# --------------------------------------------------------------------- RL003
BAD_RL003 = """\
import threading

class Daemon:
    def __init__(self):
        self._lock = threading.Lock()
        self.stats = 0  # guarded-by: _lock

    def bump(self):
        self.stats += 1
"""

GOOD_RL003 = """\
import threading

class Daemon:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.stats = 0  # guarded-by: _lock

    def bump(self):
        with self._lock:
            self.stats += 1

    def bump_via_cv(self):
        with self._cv:             # Condition(self._lock) aliases _lock
            self.stats += 1

    def _bump_locked(self):  # holds: _lock
        self.stats += 1
"""

BAD_RL003_ESCAPED_CLOSURE = """\
import threading

class Daemon:
    def __init__(self):
        self._lock = threading.Lock()
        self.stats = 0  # guarded-by: _lock

    def make_bumper(self):
        with self._lock:
            def bump():            # closure outlives the with-block
                self.stats += 1
            return bump
"""


def test_rl003_flags_unlocked_access():
    diags = lint_source(BAD_RL003)
    assert codes(diags) == ["RL003"]
    assert diags[0].line == 9
    assert "`self.stats` is guarded by `_lock`" in diags[0].message


def test_rl003_lock_condition_alias_and_holds_clean():
    assert lint_source(GOOD_RL003) == []


def test_rl003_nested_closure_does_not_inherit_lock():
    diags = lint_source(BAD_RL003_ESCAPED_CLOSURE)
    assert codes(diags) == ["RL003"]
    assert diags[0].line == 11


# --------------------------------------------------------------------- RL004
BAD_RL004_SWEEP = """\
from typing import NamedTuple

class _Resolved(NamedTuple):
    engine: str
    buf_len: int
    tau: int

def plan_sweep(resolved):
    groups = {}
    for c, r in enumerate(resolved):
        groups.setdefault((r.engine,), []).append(c)
    return groups

def _dispatch_group(resolved, members):
    return [resolved[c].tau for c in members]
"""

GOOD_RL004_SWEEP = """\
from typing import NamedTuple

class _Resolved(NamedTuple):
    engine: str
    buf_len: int
    tau: int

def plan_sweep(resolved):
    groups = {}
    for c, r in enumerate(resolved):
        groups.setdefault((r.engine, r.buf_len), []).append(c)
    return groups

def _dispatch_group(resolved, members):
    return [resolved[c].tau for c in members]
"""

BAD_RL004_CACHE = """\
def runner_key(engine, *, total, buf_len):
    return (engine, total)

def get_group_runner(engine, *, total, buf_len):
    key = runner_key(engine, total=total, buf_len=buf_len)
    return key
"""


def test_rl004_flags_unkeyed_resolved_field():
    diags = lint_source(BAD_RL004_SWEEP)
    assert codes(diags) == ["RL004"]
    assert diags[0].line == 5              # the buf_len field declaration
    assert "_Resolved.buf_len" in diags[0].message


def test_rl004_keyed_field_clean():
    assert lint_source(GOOD_RL004_SWEEP) == []


def test_rl004_flags_key_param_never_read():
    diags = lint_source(BAD_RL004_CACHE)
    assert codes(diags) == ["RL004"]
    assert "'buf_len'" in diags[0].message


# --------------------------------------------------------------------- RL005
KERNEL_IMPURE = """\
import os

def sweep_epoch_kernel(w_ref, o_ref):
    print("tracing")
    mode = os.environ.get("REPRO_KERNEL_MODE")
    o_ref[...] = w_ref[...]
"""


def test_rl005_flags_impurity_in_kernel_module_only():
    diags = lint_source(KERNEL_IMPURE,
                        path="src/repro/kernels/sweep/kernel.py")
    assert codes(diags) == ["RL005", "RL005"]
    assert [d.line for d in diags] == [4, 5]
    # identical code outside kernels/**/kernel.py is out of scope
    assert lint_source(KERNEL_IMPURE, path="src/repro/core/helper.py") == []


# --------------------------------------------------------------------- RL006
BAD_RL006_CORE = """\
import time

def epoch_core(w, key):
    t0 = time.perf_counter()
    tr = tracer()
    tr.annotate(started=t0)
    return w
"""

BAD_RL006_KERNEL = """\
import time

def sweep_body(w_ref, o_ref):
    t0 = time.monotonic_ns()
    hist.observe(t0)
    o_ref[...] = w_ref[...]
"""

GOOD_RL006_BRACKETS = """\
import time

def dispatch_group(runner, args):
    t0 = time.perf_counter()
    with tracer().span_active("execute"):
        out = runner(*args)
    hist.observe(time.perf_counter() - t0)
    return out
"""


def test_rl006_flags_obs_calls_inside_core_scopes():
    diags = lint_source(BAD_RL006_CORE)
    assert codes(diags) == ["RL006", "RL006", "RL006"]
    assert [d.line for d in diags] == [4, 5, 6]
    assert "bracket the compiled program" in diags[0].message


def test_rl006_flags_kernel_modules_wholesale():
    diags = lint_source(BAD_RL006_KERNEL,
                        path="src/repro/kernels/sweep/kernel.py")
    assert codes(diags) == ["RL006", "RL006"]
    # the same code outside kernels/**/kernel.py and outside *_core scopes
    # is exactly where obs calls belong
    assert lint_source(BAD_RL006_KERNEL,
                       path="src/repro/core/helper.py") == []


def test_rl006_allows_observability_at_the_dispatch_site():
    assert lint_source(GOOD_RL006_BRACKETS) == []


BAD_RL006_LIVE_OBS = """\
def sweep_core(w, hist):
    bus = progress_bus()
    bus.publish(kind="slice")
    enforce_group(wd, hist, w)
    led = ledger()
    led.record_dispatch(key=k)
    return w
"""


def test_rl006_flags_progress_watchdog_ledger_inside_core_scopes():
    """PR-10 surface: the live-progress bus, divergence watchdog and perf
    ledger are host-side by contract — any call inside a jitted scope is
    flagged, same as the tracer API."""
    diags = lint_source(BAD_RL006_LIVE_OBS)
    assert codes(diags) == ["RL006"] * 5
    assert [d.line for d in diags] == [2, 3, 4, 5, 6]
    assert any("progress-bus" in d.message for d in diags)
    assert any("watchdog" in d.message for d in diags)
    assert any("ledger" in d.message for d in diags)
    # the identical calls outside *_core scopes are exactly where they
    # belong (dispatch sites, services, HTTP handlers)
    assert lint_source(BAD_RL006_LIVE_OBS.replace(
        "sweep_core", "dispatch_site")) == []


# --------------------------------------------------------- suppression (RL000)
def test_suppression_with_reason_silences_finding():
    src = BAD_RL001_AXISLESS.replace(
        "return jnp.sum(x * w)",
        "return jnp.sum(x * w)  # repro-lint: ignore[RL001] x,w are 1-D here")
    assert lint_source(src) == []


def test_reasonless_suppression_is_reported():
    src = BAD_RL001_AXISLESS.replace(
        "return jnp.sum(x * w)",
        "return jnp.sum(x * w)  # repro-lint: ignore[RL001]")
    diags = lint_source(src)
    assert codes(diags) == ["RL000"]
    assert "no reason" in diags[0].message


def test_stale_suppression_is_reported():
    src = GOOD_RL001 + "\nX = 1  # repro-lint: ignore[RL001] nothing here\n"
    diags = lint_source(src)
    assert codes(diags) == ["RL000"]
    assert "unused suppression" in diags[0].message


def test_unknown_code_suppression_is_reported():
    src = "X = 1  # repro-lint: ignore[RL999] bogus code\n"
    diags = lint_source(src)
    assert codes(diags) == ["RL000"]
    assert "unknown rule code" in diags[0].message


def test_select_subsetting_skips_stale_check():
    src = BAD_RL002_TRACER_IF + "\nY = 1  # repro-lint: ignore[RL001] kept\n"
    diags = lint_source(src, select={"RL001"})
    assert diags == []                     # RL002 unselected, RL001 not stale
    assert codes(lint_source(src, select={"RL002"})) == ["RL002"]


def test_hash_inside_string_is_not_a_suppression():
    src = ('MSG = "use # repro-lint: ignore[RL001] sparingly"\n')
    assert lint_source(src) == []


# ------------------------------------------------------------- tree + CLI
def test_shipped_tree_is_clean():
    result = lint_paths([str(REPO / "src"), str(REPO / "tests"),
                         str(REPO / "benchmarks")])
    assert result.diagnostics == [], "\n".join(
        d.render() for d in result.diagnostics)
    assert len(result.files) > 100        # the walk actually found the tree


def test_cli_exits_zero_on_src(tmp_path):
    out = tmp_path / "BENCH_repro_lint.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "tests",
         "benchmarks", "--json-out", str(out)],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json
    payload = json.loads(out.read_text())
    assert payload["diagnostics"] == []
    assert payload["files"] > 100
    assert set(payload["rules"]) == set(RULES)


def test_cli_nonzero_on_seeded_violation(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_RL001_AXISLESS)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad)],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1
    assert "RL001" in proc.stdout


def test_cli_rejects_unknown_select():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--select", "RL042", "src"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 2
    assert "unknown rule code" in proc.stderr


# ------------------------------------------------------------- meta checks
_BUILTIN_MARKS = {"parametrize", "skip", "skipif", "xfail", "usefixtures",
                  "filterwarnings"}


def test_all_markers_registered():
    """Every pytest.mark.<name> used under tests/ is declared in pytest.ini
    (unregistered marks are typo-silent without --strict-markers)."""
    ini = configparser.ConfigParser()
    ini.read(REPO / "pytest.ini")
    registered = {line.split(":")[0].strip()
                  for line in ini["pytest"]["markers"].strip().splitlines()}
    used = set()
    for path in (REPO / "tests").glob("test_*.py"):
        used |= set(re.findall(r"pytest\.mark\.(\w+)", path.read_text()))
    unregistered = used - _BUILTIN_MARKS - registered
    assert not unregistered, (
        f"marks used but not registered in pytest.ini: {unregistered}")
