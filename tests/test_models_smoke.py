"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, asserting output shapes + no NaNs; plus
decode-vs-forward consistency for the cache-bearing families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig
from repro.configs import get_config, list_configs, reduced_config
from repro.models.factory import build_model
from repro.sharding.rules import init_from_defs

ARCHS = [a for a in list_configs() if a != "paper-logreg"]
SHAPE = ShapeConfig("smoke", "train", 16, 2)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch, key):
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params = init_from_defs(key, bundle.param_defs)
    batch = bundle.make_inputs(SHAPE, key)
    loss, grads = jax.value_and_grad(bundle.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch, key):
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params = init_from_defs(key, bundle.param_defs)
    batch = bundle.make_inputs(SHAPE, key)
    logits, cache = bundle.prefill_fn(params, batch, 32)
    assert logits.shape == (SHAPE.global_batch, cfg.vocab_size)
    tok = jnp.zeros((SHAPE.global_batch,), jnp.int32)
    logits2, cache2 = bundle.decode_fn(params, cache, tok,
                                       jnp.asarray(SHAPE.seq_len, jnp.int32))
    assert logits2.shape == (SHAPE.global_batch, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # cache structure is stable across steps (serve loop requirement)
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ["chatglm3-6b", "gemma3-4b",
                                  "falcon-mamba-7b", "recurrentgemma-2b"])
def test_decode_matches_full_forward(arch, key):
    """Greedy decode at position S must reproduce the full-forward logits —
    the KV-cache/state path is numerically equivalent to recomputation.

    MoE archs are excluded: capacity-dropping routes differently for a
    single decode token (Sg=1 groups) vs a grouped full forward — expected
    dropping-MoE semantics, not a cache bug (decode shape/finiteness is
    covered by test_prefill_decode_shapes)."""
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params = init_from_defs(key, bundle.param_defs)
    S = 16
    toks = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S - 1]}
    full_batch = dict(batch)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.ones((2, cfg.num_image_tokens,
                                          cfg.image_embed_dim))
    _, cache = bundle.prefill_fn(params, batch, S)
    logits_dec, _ = bundle.decode_fn(params, cache, toks[:, S - 1],
                                     jnp.asarray(S - 1, jnp.int32))

    from repro.models import transformer as tf
    if cfg.family == "moe":
        from repro.models import moe
        h, _ = moe.hidden_states(cfg, params, toks)
    elif cfg.family == "hybrid":
        from repro.models import rglru
        h = rglru.hidden_states(cfg, params, toks)
    elif cfg.family == "ssm":
        from repro.models import mamba
        h = mamba.hidden_states(cfg, params, toks)
    else:
        h = tf.hidden_states(cfg, params, toks)
    logits_full = jnp.einsum("bd,vd->bv", h[:, -1, :], tf.unembed(cfg, params))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), atol=5e-4, rtol=1e-3)


def test_full_configs_have_exact_published_dims():
    """The FULL configs carry the exact assigned dimensions."""
    expect = {
        "whisper-large-v3": dict(num_layers=32, d_model=1280, num_heads=20,
                                 num_kv_heads=20, d_ff=5120, vocab_size=51866),
        "chatglm3-6b": dict(num_layers=28, d_model=4096, num_heads=32,
                            num_kv_heads=2, d_ff=13696, vocab_size=65024),
        "stablelm-12b": dict(num_layers=40, d_model=5120, num_heads=32,
                             num_kv_heads=8, d_ff=13824, vocab_size=100352),
        "gemma3-4b": dict(num_layers=34, d_model=2560, num_heads=8,
                          num_kv_heads=4, d_ff=10240, vocab_size=262144),
        "command-r-plus-104b": dict(num_layers=64, d_model=12288,
                                    num_heads=96, num_kv_heads=8,
                                    d_ff=33792, vocab_size=256000),
        "qwen3-moe-235b-a22b": dict(num_layers=94, d_model=4096,
                                    num_heads=64, num_kv_heads=4,
                                    moe_d_ff=1536, vocab_size=151936,
                                    num_experts=128, experts_per_token=8),
        "deepseek-moe-16b": dict(num_layers=28, d_model=2048, num_heads=16,
                                 num_kv_heads=16, moe_d_ff=1408,
                                 vocab_size=102400, num_experts=64,
                                 experts_per_token=6, num_shared_experts=2),
        "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096,
                                     num_heads=32, num_kv_heads=8,
                                     d_ff=14336, vocab_size=128256),
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                                  num_kv_heads=1, d_ff=7680,
                                  vocab_size=256000, lru_width=2560),
        "falcon-mamba-7b": dict(num_layers=64, d_model=4096,
                                vocab_size=65024, ssm_state=16),
    }
    for name, dims in expect.items():
        cfg = get_config(name)
        for k, v in dims.items():
            assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)
