from repro.utils.tree import (
    tree_add,
    tree_axpy,
    tree_dot,
    tree_l2norm,
    tree_scale,
    tree_sub,
    tree_zeros_like,
    global_norm,
    tree_size,
    tree_bytes,
)
from repro.utils.misc import fmt_bytes, fmt_flops, Timer, log

__all__ = [
    "tree_add",
    "tree_axpy",
    "tree_dot",
    "tree_l2norm",
    "tree_scale",
    "tree_sub",
    "tree_zeros_like",
    "global_norm",
    "tree_size",
    "tree_bytes",
    "fmt_bytes",
    "fmt_flops",
    "Timer",
    "log",
]
