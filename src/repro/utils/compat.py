"""JAX cross-version compatibility shims — the single import point for APIs
that moved or were renamed between the JAX versions we support (0.4.3x LTS
through current).

Covered surfaces:

  * ``jax.sharding.AxisType`` + the ``axis_types=`` kwarg of
    ``jax.make_mesh`` — added after 0.4.37. :func:`make_mesh` requests
    ``Auto`` axis types when the installed JAX understands them and silently
    builds a plain mesh otherwise (``Auto`` is the pre-AxisType behaviour,
    so semantics are unchanged).
  * ``pallas.tpu.CompilerParams`` vs the older ``TPUCompilerParams`` name.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

try:  # jax >= 0.5-era
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # 0.4.x: only Auto semantics exist, implicitly
    AxisType = None

HAS_AXIS_TYPES = AxisType is not None


def auto_axis_types(num_axes: int):
    """``(AxisType.Auto,) * num_axes`` where expressible, else ``None``."""
    if HAS_AXIS_TYPES:
        return (AxisType.Auto,) * num_axes
    return None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None):
    """``jax.make_mesh`` with ``Auto`` axis types when supported.

    On JAX 0.4.x (no ``AxisType``, no ``axis_types=`` kwarg) this degrades to
    the plain call, which has identical semantics — every axis was
    implicitly Auto before the kwarg existed.
    """
    if HAS_AXIS_TYPES:
        try:
            return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                                 axis_types=auto_axis_types(len(axis_names)))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
    # pre-0.4.31: assemble the Mesh by hand
    import numpy as np
    from jax.sharding import Mesh
    devs = list(devices) if devices is not None else jax.devices()
    size = int(np.prod(axis_shapes))
    return Mesh(np.asarray(devs[:size]).reshape(tuple(axis_shapes)),
                tuple(axis_names))


def tpu_compiler_params(*, dimension_semantics: Optional[Sequence[str]] = None,
                        **kwargs):
    """Build Pallas-TPU compiler params under either class name.

    ``TPUCompilerParams`` (<= 0.4.x / 0.5.x) was renamed ``CompilerParams``;
    both accept ``dimension_semantics``.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(dimension_semantics=dimension_semantics, **kwargs)
