# Launch layer: mesh factory, multi-pod dry-run driver, roofline extractor,
# and the train/serve CLI entry points.
