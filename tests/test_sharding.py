"""Sharding rules: logical axes -> PartitionSpec, divisibility fallback,
struct building, layer-axes encoding."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.utils.compat import make_mesh
from repro.sharding.context import constrain, mesh_context
from repro.sharding.rules import (
    ParamDef, defs_to_shape_structs, defs_to_shardings, init_from_defs,
    layer_axes_strs, logical_to_pspec)


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def test_pspec_basic(mesh):
    spec = logical_to_pspec((16, 32), ("embed", "mlp"), mesh)
    assert spec == P("data", "model")


def test_pspec_divisibility_fallback(mesh):
    # dim 3 not divisible by... host mesh is 1x1 so everything divides;
    # build a fake 2-way check via rules on a (2,) mesh axis
    m = make_mesh((1, 1), ("data", "model"))
    spec = logical_to_pspec((3, 7), ("embed", "mlp"), m)
    assert spec == P("data", "model")   # 1-way always divides


def test_pspec_missing_axis_replicates(mesh):
    spec = logical_to_pspec((8,), ("pod_only_axis",), mesh)
    assert spec == P(None)


def test_defs_to_structs_no_allocation(mesh):
    defs = {"w": ParamDef((1024, 1024), ("embed", "mlp"))}
    structs = defs_to_shape_structs(defs, mesh)
    assert isinstance(structs["w"], jax.ShapeDtypeStruct)
    assert structs["w"].shape == (1024, 1024)
    assert structs["w"].sharding is not None


def test_init_matches_defs():
    defs = {"w": ParamDef((4, 8), ("embed", "mlp")),
            "b": ParamDef((8,), ("mlp",), "zeros")}
    params = init_from_defs(jax.random.PRNGKey(0), defs)
    assert params["w"].shape == (4, 8)
    assert float(jnp.sum(jnp.abs(params["b"]))) == 0.0


def test_layer_axes_strs_drops_layers():
    defs = {"w": ParamDef((12, 4, 8), ("layers", "embed", "mlp")),
            "s": ParamDef((12, 4), ("layers", None))}
    strs = layer_axes_strs(defs)
    assert strs["w"] == "embed|mlp"
    assert strs["s"] == ""


def test_constrain_noop_outside_mesh():
    x = jnp.ones((4, 4))
    assert constrain(x, ("embed", "mlp")) is x


def test_constrain_inside_mesh(mesh):
    x = jnp.ones((4, 4))
    with mesh_context(mesh):
        y = jax.jit(lambda a: constrain(a, ("embed", "mlp")))(x)
    assert y.shape == (4, 4)


def test_shardings_tree_structure(mesh):
    defs = {"a": ParamDef((4,), ("mlp",)),
            "nested": {"b": ParamDef((2, 2), (None, None))}}
    sh = defs_to_shardings(defs, mesh)
    assert set(sh) == {"a", "nested"}
