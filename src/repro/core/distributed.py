"""Distributed AsySVRG for TPU meshes (the paper's insight B, see DESIGN §2).

Three pieces:

1. ``SVRGState`` + ``svrg_direction`` — SVRG as a *gradient estimator* for
   arbitrary param pytrees: v = g(w) − g(w_snap) + g_snap. The train loop
   computes both grads on the same minibatch (the paper's inner loop, with
   minibatches instead of single instances) and any optimizer consumes v.

2. ``snapshot`` steps — the paper's partitioned full-gradient pass: every
   data-parallel worker accumulates grads over its shard of the reference
   batches; the mean is one all-reduce (φ_a semantics, verbatim).

3. ``bounded_staleness_epoch`` — the asynchronous inner loop mapped to SPMD:
   each worker on the `data` axis runs H local SVRG steps on its OWN replica
   (replica divergence carries the paper's coordinate-age mixing, Eq. 10),
   then replicas reconcile by averaging (Option 2) — optionally through a
   compressed collective (core.compression) whose per-worker
   ``ErrorFeedbackState`` is threaded IN AND OUT of the epoch, so the
   compression residual accumulates across epochs (Stich-style EF; a
   residual recreated per epoch would silently discard it). H is the
   staleness bound τ; H=1 is synchronous minibatch SVRG (the τ=0
   degenerate case).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.config import SVRGConfig
from repro.core.compression import ErrorFeedbackState, compressed_update
from repro.utils.tree import tree_add, tree_scale, tree_sub, tree_zeros_like


class SVRGState(NamedTuple):
    """Optimizer-agnostic SVRG snapshot state (lives beside params).

    g_snap doubles as the snapshot-gradient ACCUMULATOR during the epoch
    barrier (Algorithm 1 computes the full gradient with all workers before
    any inner step runs, so no separate buffer is needed — this keeps SVRG
    at exactly 2 extra param-sized states, which is what lets command-r-104b
    + SVRG fit 16 GB/chip)."""
    w_snap: Any        # snapshot parameters u_0
    g_snap: Any        # full gradient ∇f(u_0) (or in-progress accumulator)
    snap_step: jnp.ndarray   # step at which snapshot was taken
    accum_count: jnp.ndarray


def init_svrg_state(params) -> SVRGState:
    return SVRGState(
        w_snap=params,
        g_snap=tree_zeros_like(params),
        snap_step=jnp.zeros((), jnp.int32),
        accum_count=jnp.zeros((), jnp.int32),
    )


def svrg_direction(g, g0, g_snap):
    """v = g − g0 + g_snap (Algorithm 1, Eq. 2), leaf-wise on pytrees."""
    return jax.tree.map(lambda a, b, c: a - b + c, g, g0, g_snap)


def make_svrg_grad_fn(loss_fn: Callable):
    """Returns grad_fn(params, svrg_state, batch) -> (loss, v).

    Two fwd+bwd on the same batch — at w and at w_snap — then the control
    variate. This is the step the multi-pod dry-run lowers for `train_4k`.
    """
    vgrad = jax.value_and_grad(loss_fn)

    def grad_fn(params, svrg_state: SVRGState, batch):
        loss, g = vgrad(params, batch)
        _, g0 = vgrad(svrg_state.w_snap, batch)
        v = svrg_direction(g, g0, svrg_state.g_snap)
        return loss, v

    return grad_fn


# ---------------------------------------------------------------------------
# Snapshot pass (partitioned full gradient)
# ---------------------------------------------------------------------------

def snapshot_begin(svrg_state: SVRGState) -> SVRGState:
    """Start a snapshot pass: zero the accumulator (epoch barrier — no inner
    steps run until finalize, exactly Algorithm 1's structure)."""
    return svrg_state._replace(
        g_snap=tree_zeros_like(svrg_state.g_snap),
        accum_count=jnp.zeros((), jnp.int32),
    )


def snapshot_accumulate(loss_fn: Callable, params, svrg_state: SVRGState,
                        batch) -> SVRGState:
    """One reference-batch contribution to the snapshot gradient.

    Under pjit with the batch sharded over (pod, data), this IS the paper's
    φ_a partitioned pass — each device grads its shard; XLA's reduction over
    the batch dim is the single all-reduce."""
    g = jax.grad(loss_fn)(params, batch)
    return svrg_state._replace(
        g_snap=tree_add(svrg_state.g_snap, g),
        accum_count=svrg_state.accum_count + 1,
    )


def snapshot_finalize(params, svrg_state: SVRGState, step) -> SVRGState:
    """w_snap ← w; g_snap ← mean of accumulated reference grads."""
    cnt = jnp.maximum(svrg_state.accum_count, 1).astype(jnp.float32)
    return SVRGState(
        w_snap=params,
        g_snap=tree_scale(svrg_state.g_snap, 1.0 / cnt),
        snap_step=jnp.asarray(step, jnp.int32),
        accum_count=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Bounded-staleness local SVRG (shard_map over the data axis)
# ---------------------------------------------------------------------------

def init_worker_error_feedback(params, num_workers: int) -> ErrorFeedbackState:
    """Per-worker EF residuals: params-shaped zeros with a leading [W] axis
    (worker w's residual lives at index w, sharded over the `data` axis)."""
    return ErrorFeedbackState(jax.tree.map(
        lambda x: jnp.zeros((num_workers,) + x.shape, x.dtype), params))


def bounded_staleness_epoch(
    mesh: Mesh,
    loss_fn: Callable,                # loss_fn(params, batch) scalar
    params,
    svrg_state: SVRGState,
    local_batches,                    # pytree of arrays [W*H, ...] sharded W over 'data'
    step_size: float,
    cfg: SVRGConfig,
    rng: Optional[jax.Array] = None,
    ef: Optional[ErrorFeedbackState] = None,
):
    """H local SVRG steps per worker, then (optionally compressed) reconcile.

    Each of the W workers on the `data` mesh axis scans H minibatches from
    its own shard, updating a private replica — between reconciles, replica
    coordinates mix updates of different ages exactly as the paper's
    inconsistent/unlock reads do. The closing pmean is Option 2 averaging.

    Returns ``(new_params, new_ef)``. ``ef`` is each worker's PERSISTENT
    error-feedback state ([W]-leading residual tree; None = zeros, i.e. a
    fresh run): the compressor transmits compress(delta + residual) and the
    untransmitted remainder is carried to the NEXT epoch — pass the
    returned state back in. Recreating it every epoch would throw the
    residual away and forfeit the EF convergence guarantee.
    """
    grad_fn = jax.grad(loss_fn)
    w_snap, g_snap = svrg_state.w_snap, svrg_state.g_snap
    method = cfg.compression
    frac = cfg.compression_k
    if rng is None:
        rng = jax.random.PRNGKey(0)
    num_workers = mesh.shape.get("data", 1)
    if ef is None:
        ef = init_worker_error_feedback(params, num_workers)

    def worker(params_rep, w_snap_rep, g_snap_rep, batches, key, residual):
        # shard_map delivers [1, H, local_batch, ...]; drop the worker dim.
        batches = jax.tree.map(lambda x: x[0], batches)
        key = key[0]
        residual = jax.tree.map(lambda x: x[0], residual)

        def body(w, b):
            g = grad_fn(w, b)
            g0 = grad_fn(w_snap_rep, b)
            v = svrg_direction(g, g0, g_snap_rep)
            w = jax.tree.map(lambda wi, vi: wi - step_size * vi, w, v)
            return w, None

        w_local, _ = jax.lax.scan(body, params_rep, batches)
        # reconcile: average replicas (Option 2). With compression, transmit
        # only the compressed delta and re-add to the common base point; the
        # compression error joins this worker's carried residual.
        delta = tree_sub(w_local, params_rep)
        ef_local = ErrorFeedbackState(residual)
        if method != "none":
            delta, ef_local = compressed_update(delta, ef_local, method,
                                                frac, key)
        delta_mean = jax.lax.pmean(delta, "data")
        new_residual = jax.tree.map(lambda x: x[None], ef_local.residual)
        return tree_add(params_rep, delta_mean), new_residual

    keys = jax.random.split(rng, max(2, num_workers))[:num_workers]

    fn = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(), P(), P(), P("data"), P("data"), P("data")),
        out_specs=(P(), P("data")),
        check_rep=False,
    )
    new_params, new_residual = fn(params, w_snap, g_snap, local_batches,
                                  keys, ef.residual)
    return new_params, ErrorFeedbackState(new_residual)


def reshape_for_workers(batches, num_workers: int, local_steps: int):
    """[W*H, b, ...] -> [W, H, b, ...] worker-major (leaf-wise)."""
    def rs(x):
        assert x.shape[0] == num_workers * local_steps, (
            f"need {num_workers * local_steps} microbatches, got {x.shape[0]}")
        return x.reshape((num_workers, local_steps) + x.shape[1:])
    return jax.tree.map(rs, batches)
