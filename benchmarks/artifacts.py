"""BENCH_*.json artifact writer — the perf-trajectory record CI uploads.

Every benchmark `main()` dumps its structured result as ``BENCH_<name>.json``
(in $BENCH_DIR, default cwd) alongside the human-readable CSV on stdout. The
CI bench-smoke job runs the benchmarks with tiny epoch counts and uploads
these files as workflow artifacts, so every PR leaves a comparable record.

Payloads are sanitized to strict JSON: numpy scalars/arrays become Python
numbers/lists and non-finite floats become the string "inf"/"nan" (json's
native Infinity literal is not valid JSON and breaks downstream tooling).
"""
from __future__ import annotations

import json
import math
import os
from typing import Any

import numpy as np


def _sanitize(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {str(k): _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _sanitize(obj.tolist())
    if isinstance(obj, (np.integer, int)) and not isinstance(obj, bool):
        return int(obj)
    if isinstance(obj, (np.floating, float)):
        f = float(obj)
        if math.isnan(f):
            return "nan"
        if math.isinf(f):
            return "inf" if f > 0 else "-inf"
        return f
    return obj


def write_bench_json(name: str, payload: dict) -> str:
    """Write ``BENCH_<name>.json`` under $BENCH_DIR (default: cwd)."""
    out_dir = os.environ.get("BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as fh:
        json.dump(_sanitize(payload), fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path
