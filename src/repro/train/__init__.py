from repro.train.state import TrainState, make_train_state_defs, make_train_step
from repro.train.loop import train

__all__ = ["TrainState", "make_train_state_defs", "make_train_step", "train"]
