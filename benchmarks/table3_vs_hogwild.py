"""Paper Table 3: AsySVRG vs Hogwild! — time to gap < 1e-4 at 10 threads,
on the three (synthesized) paper datasets.

Both AsySVRG rows of each dataset run as one vectorized sweep
(repro.core.sweep); Hogwild! keeps its own sequential driver."""
from __future__ import annotations

import numpy as np

from repro.core import (LogisticRegression, SweepSpec, run_hogwild,
                        run_sweep)
from repro.data.libsvm import make_synthetic_libsvm
from benchmarks.cost_model import measure_primitives, wall_time

P = 10
GAP = 1e-4


def _wall_from_history(history, total_updates, f_star, prim, scheme,
                       max_epochs):
    gaps = np.asarray(history) - f_star
    hit = np.nonzero(gaps < GAP)[0]
    if len(hit) == 0:
        return float("inf"), max_epochs
    epochs = int(hit[0])
    upd = int(total_updates) // max_epochs
    return wall_time(scheme, epochs * upd, P, prim), epochs


def run(scale=0.03, quick=False):
    rows = []
    max_e = 10 if quick else 30
    for name in ("rcv1", "real-sim", "news20"):
        ds = make_synthetic_libsvm(name, scale=scale)
        obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
        _, f_star = obj.optimum(max_iter=3000)
        prim = measure_primitives(obj, iters=50 if quick else 100)

        # asysvrg-lock / asysvrg-unlock: one sweep, one compile
        schemes = {"asysvrg-lock": "inconsistent",
                   "asysvrg-unlock": "unlock"}
        specs = [SweepSpec(seed=0, scheme=s, step_size=2.0, num_threads=P,
                           tau=P - 1) for s in schemes.values()]
        res = run_sweep(obj, max_e, specs)
        for c, kind in enumerate(schemes):
            t, e = _wall_from_history(res.histories[c], res.total_updates[c],
                                      f_star, prim, specs[c].scheme, max_e)
            rows.append({"dataset": name, "method": kind,
                         "wall_s": t, "epochs": e})

        for kind in ("hogwild-lock", "hogwild-unlock"):
            scheme = "inconsistent" if kind.endswith("-lock") else "unlock"
            hog = run_hogwild(obj, max_e, 2.0, num_threads=P,
                              scheme=scheme, seed=0)
            t, e = _wall_from_history(hog.history, hog.total_updates,
                                      f_star, prim, scheme, max_e)
            rows.append({"dataset": name, "method": kind,
                         "wall_s": t, "epochs": e})
    return rows


def main(quick=True):
    rows = run(quick=quick)
    print("name,us_per_call,derived")
    for r in rows:
        wall = r["wall_s"]
        print(f"table3_{r['dataset']}_{r['method']},"
              f"{(wall * 1e6 if np.isfinite(wall) else -1):.1f},"
              f"epochs={r['epochs']}")


if __name__ == "__main__":
    main(quick=False)
