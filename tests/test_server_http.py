"""HTTP front-end suite: the serving loop over a real socket.

An in-process `SweepServer` (ThreadingHTTPServer + flush daemon) driven by
`SweepClient` over loopback: submit → deadline-triggered flush → result.
Pins the acceptance contracts — HTTP-served results BIT-IDENTICAL to
in-process `run_sweep` for every tenant, 0 compiles on a warm same-shape
request, and the error mapping (400 bad spec, 404 unknown id, 410
evicted, 504 pending)."""
import dataclasses
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.server import FairShare, FlushPolicy, SweepClient, SweepServer
from repro.server.http import result_from_dict, result_to_dict
from repro.service import ResultEvictedError, SweepService, cache_stats


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


@pytest.fixture()
def served(obj):
    """A started server (fast deadline flush) + client; stops after."""
    svc = SweepService(obj, epochs=1, max_results=8)
    server = SweepServer(svc, policy=FlushPolicy(max_rows=64,
                                                 max_delay_ms=25),
                         fairness=FairShare(quantum_rows=16)).start()
    try:
        yield svc, server, SweepClient(server.url, poll_s=5.0)
    finally:
        server.stop()


def _specs(seeds):
    return [SweepSpec(scheme="inconsistent", step_size=0.5, tau=3,
                      num_threads=4, inner_steps=25, seed=s)
            for s in seeds]


def _assert_same(got, want):
    np.testing.assert_array_equal(got.histories, want.histories)
    np.testing.assert_array_equal(got.final_w, want.final_w)
    np.testing.assert_array_equal(got.effective_passes,
                                  want.effective_passes)
    np.testing.assert_array_equal(got.total_updates, want.total_updates)
    np.testing.assert_array_equal(got.epochs_per_row, want.epochs_per_row)
    assert got.specs == want.specs


# ------------------------------------------------------------ acceptance
def test_http_served_results_bit_identical_multi_tenant(served, obj):
    """Three tenants over HTTP; the daemon's deadline policy flushes once;
    each tenant's result is bit-identical to in-process run_sweep — and a
    second same-shape request costs 0 compiles (warm path)."""
    svc, server, client = served
    tenants = {"team-a": _specs([0, 1]),
               "team-b": _specs([2]),
               "team-c": [SweepSpec(algo="svrg", step_size=0.5,
                                    num_threads=1, inner_steps=30, seed=4)]}
    rids = {name: client.submit(specs, tenant=name, priority=i)
            for i, (name, specs) in enumerate(tenants.items())}
    for name, specs in tenants.items():
        _assert_same(client.result(rids[name], timeout=180),
                     run_sweep(obj, 1, specs))
    stats = svc.stats()
    assert stats.requests_completed == 3
    assert stats.rows_coalesced >= 3          # a+b shared a compiled group

    base = cache_stats()
    rid = client.submit(_specs([7, 8]), tenant="team-a")
    _assert_same(client.result(rid, timeout=180),
                 run_sweep(obj, 1, _specs([7, 8])))
    assert cache_stats().since(base).compiles == 0, \
        "warm same-shape HTTP request recompiled"


def test_healthz_stats_and_flush_endpoints(served):
    svc, server, client = served
    health = client.healthz()
    assert health["status"] == "ok" and health["daemon_running"]
    rid = client.submit(_specs([10]))
    done = client.flush()                     # operator escape hatch
    assert rid in done
    stats = client.stats()
    assert stats["service"]["requests_completed"] >= 1
    assert stats["queue"]["depth_requests"] == 0
    assert stats["tenants"]["default"]["rows_submitted"] == 1
    assert {"count", "p50_ms", "p95_ms", "max_ms"} <= \
        set(stats["flush_latency"])
    assert "daemon" in stats and "fairness" in stats


def test_error_mapping(served):
    svc, server, client = served
    with pytest.raises(KeyError):
        client.result(10_000, timeout=5)      # never existed: 404
    with pytest.raises(ValueError):
        client.submit([])                     # empty: 400
    with pytest.raises(ValueError):           # unknown field: 400
        client._call("POST", "/submit",
                     {"specs": [{"algo": "asysvrg", "nope": 1}]})
    with pytest.raises(ValueError):           # invalid spec: 400
        client.submit([SweepSpec(scheme="bogus")])
    # evicted: overflow the FIFO bound (max_results=8) then ask again
    rid0 = client.submit(_specs([20]))
    client.result(rid0, timeout=180)
    for i in range(8):
        client.sweep(_specs([21 + i]), timeout=180)
    with pytest.raises(ResultEvictedError):
        client.result(rid0, timeout=5)        # 410, typed error
    # pending: a quiet queue under an hour-long deadline never flushes
    server.daemon.policy = dataclasses.replace(server.daemon.policy,
                                               max_delay_ms=3_600_000)
    rid = client.submit(_specs([40]))
    with pytest.raises(TimeoutError):
        client.result(rid, timeout=1.0)       # 504 pending -> client timeout
    server.daemon.policy = dataclasses.replace(server.daemon.policy,
                                               max_delay_ms=25)


def test_unknown_route_404(served):
    svc, server, client = served
    with urllib.request.urlopen(server.url + "/healthz") as resp:
        assert resp.status == 200
    try:
        urllib.request.urlopen(server.url + "/nope")
        assert False, "expected 404"
    except urllib.error.HTTPError as e:
        assert e.code == 404


def test_wire_codec_round_trips_bits(obj):
    """result -> JSON -> result is bitwise lossless (float32 histories,
    float64 passes, int64 counters) — the property HTTP bit-identity
    rests on."""
    res = run_sweep(obj, 1, _specs([0]))
    payload = json.loads(json.dumps(result_to_dict(7, res)))
    back = result_from_dict(payload)
    _assert_same(back, res)
    assert back.histories.dtype == np.float32
    assert back.effective_passes.dtype == np.float64
    assert back.total_updates.dtype == np.int64
    # diverged_rows: None round-trips as None, arrays as int64; payloads
    # from pre-watchdog servers (no key at all) decode too
    assert back.diverged_rows is None
    marked = res._replace(diverged_rows=np.asarray([2, -1], np.int64))
    wire = json.loads(json.dumps(result_to_dict(8, marked)))
    assert wire["diverged_rows"] == [2, -1]
    decoded = result_from_dict(wire)
    assert decoded.diverged_rows.dtype == np.int64
    np.testing.assert_array_equal(decoded.diverged_rows, [2, -1])
    del wire["diverged_rows"]
    assert result_from_dict(wire).diverged_rows is None


def test_submit_ticket_trace_id_round_trips(obj):
    """The satellite contract: ``submit`` surfaces the echoed X-Trace-Id
    (as ``SubmitTicket.trace_id``, still an int for old callers), the id
    resolves against ``/trace``, and ``result``/``watch`` accept it back
    as an outgoing correlation header without changing behavior."""
    from repro.obs.trace import disable_tracing, enable_tracing
    svc = SweepService(obj, epochs=1, max_results=8)
    enable_tracing()
    try:
        server = SweepServer(svc, policy=FlushPolicy(max_rows=64,
                                                     max_delay_ms=25)).start()
        try:
            client = SweepClient(server.url, poll_s=5.0)
            rid = client.submit(_specs([0, 1]), tenant="team-a")
            assert isinstance(rid, int)           # old call sites keep working
            assert rid.trace_id and rid.trace_id == svc.trace_id(rid)
            # the ticket's trace id is the SAME id /trace serves the span
            # tree under — the whole point of echoing it
            res = client.result(rid, timeout=180, trace_id=rid.trace_id)
            _assert_same(res, run_sweep(obj, 1, _specs([0, 1])))
            tree = client.trace(rid.trace_id)
            assert {"submit", "dispatch"} <= {s["name"] for s in tree["spans"]}
            # watch() takes the same correlation header; with the bus off
            # it answers instantly with no events and enabled=False
            got = client.watch(cursor=0, timeout_s=0.0,
                               trace_id=rid.trace_id)
            assert got["events"] == [] and got["enabled"] is False
        finally:
            server.stop()
    finally:
        disable_tracing(clear=True)
