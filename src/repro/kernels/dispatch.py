"""One place every Pallas kernel decides compiled / interpret / reference.

Before this module each ``kernels/*/ops.py`` carried its own ad-hoc
``_use_kernel()`` backend sniff plus ``interpret`` / ``force_kernel``
keyword plumbing, so the four kernels could (and did) drift in how they
picked an execution mode and tests had no uniform way to force one.
`kernel_mode` is now the single decision:

  * ``REPRO_KERNEL_MODE`` env var, when set, WINS — ``compiled`` /
    ``interpret`` / ``reference``. This is what the CI ``kernels-interpret``
    lane and local debugging use to force every kernel down one path.
  * Otherwise the caller's ``force_kernel`` / ``interpret`` flags and a
    backend sniff reproduce the historical per-kernel behaviour exactly:
    the Pallas body runs compiled on TPU (interpret-mode when asked),
    ``force_kernel=True`` opts non-TPU backends into the kernel body
    (tests pair it with ``interpret=True``), and everything else takes the
    jnp reference path.

Modes:
  ``compiled``  — ``pl.pallas_call(..., interpret=False)`` (real Mosaic
                  lowering; TPU/GPU only — NOT validated on this repo's
                  CPU CI, see the ROADMAP real-accelerator item).
  ``interpret`` — the kernel BODY executes under the Pallas interpreter
                  (plain XLA ops, any backend, bit-exact vs the same body
                  compiled only up to backend reduction order).
  ``reference`` — the kernel's jnp ``ref.py`` oracle (or, for the fused
                  sweep megakernel, the vmap engine) runs instead.
"""
from __future__ import annotations

import os

import jax

KERNEL_MODE_ENV = "REPRO_KERNEL_MODE"
_MODES = ("compiled", "interpret", "reference")


def env_mode() -> str:
    """The ``REPRO_KERNEL_MODE`` override, validated; "" when unset."""
    mode = os.environ.get(KERNEL_MODE_ENV, "").strip().lower()
    if mode and mode not in _MODES:
        raise ValueError(
            f"{KERNEL_MODE_ENV}={mode!r} — expected one of {_MODES}")
    return mode


def kernel_backend() -> str:
    """The backend the kernel dispatch sniffs (one place to monkeypatch)."""
    return jax.default_backend()


def kernel_mode(interpret: bool = False, force_kernel: bool = False) -> str:
    """'compiled' | 'interpret' | 'reference' for one kernel call.

    Env override first; else the historical contract shared by all
    kernels: the Pallas body runs iff ``force_kernel`` or the backend is
    TPU, in interpret mode iff ``interpret`` is set.
    """
    mode = env_mode()
    if mode:
        return mode
    if force_kernel or kernel_backend() == "tpu":
        return "interpret" if interpret else "compiled"
    return "reference"


def fused_sweep_mode() -> str:
    """'compiled' | 'interpret' for the fused sweep megakernel.

    The megakernel has no separate jnp reference — the vmap engine IS its
    reference — so 'reference' is not a meaningful mode here: auto picks
    compiled on TPU and interpret everywhere else (where the interpreter
    is bit-exact to the vmap path), and an env override of ``reference``
    degrades to interpret. ``compiled``/``interpret`` overrides win as
    usual.
    """
    mode = env_mode()
    if mode == "compiled":
        return "compiled"
    if mode in ("interpret", "reference"):
        return "interpret"
    return "compiled" if kernel_backend() == "tpu" else "interpret"


def mode_tags(fused: bool) -> dict:
    """Span tags describing HOW a group dispatch executes — stamped onto
    the tracer's ``execute`` spans by `repro.core.sweep._dispatch_group`
    so a trace answers "which lowering ran this request" without anyone
    re-deriving the mode later (it can change with the environment). The
    resolution mirrors `_fused_mode_key`: vmap bodies report the backend
    only; fused bodies add the resolved megakernel mode."""
    tags = {"engine_mode": "fused" if fused else "vmap",
            "backend": kernel_backend()}
    if fused:
        tags["kernel_mode"] = fused_sweep_mode()
    return tags


def use_pallas(interpret: bool = False, force_kernel: bool = False) -> bool:
    """True when the Pallas kernel body should run (either mode)."""
    return kernel_mode(interpret, force_kernel) != "reference"


def pallas_interpret(interpret: bool = False,
                     force_kernel: bool = False) -> bool:
    """The ``interpret=`` flag to hand ``pl.pallas_call`` once the body
    runs. Only meaningful when `use_pallas` returned True."""
    return kernel_mode(interpret, force_kernel) == "interpret"
