"""Data pipeline: determinism, restart-safety, libsvm parsing, paper stats."""

import numpy as np
import pytest

from repro.data.libsvm import (
    PAPER_DATASETS, make_synthetic_libsvm, parse_libsvm_file)
from repro.data.synthetic_lm import SyntheticLMDataset


def test_batch_at_is_restart_safe():
    """batch_at(step) is a pure function of step — the checkpoint/restart
    contract (the step number IS the data cursor)."""
    ds = SyntheticLMDataset(1000, 64, 8, seed=3)
    a = ds.batch_at(17)
    b = ds.batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_targets_are_shifted_tokens():
    ds = SyntheticLMDataset(1000, 32, 4)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (4, 32)
    assert b["targets"].shape == (4, 32)
    # learnable structure: next-token follows the bigram map often
    mapped = (b["tokens"] * 7 + 13) % 1000
    frac = (mapped == b["targets"]).mean()
    assert frac > 0.5, frac


@pytest.mark.parametrize("name", ["rcv1", "real-sim", "news20"])
def test_synthetic_libsvm_stats(name):
    ds = make_synthetic_libsvm(name, scale=0.02)
    spec = PAPER_DATASETS[name]
    assert ds.p == spec["p_reduced"]
    assert ds.l2_reg == spec["l2"]
    assert set(np.unique(ds.y)) <= {-1.0, 1.0}
    # rows are L2-normalized (libsvm convention used in the paper experiments)
    norms = np.linalg.norm(ds.X, axis=1)
    np.testing.assert_allclose(norms[norms > 0], 1.0, atol=1e-5)
    # labels are learnable: a linear model beats chance
    assert ds.n >= 64


def test_parse_libsvm_file(tmp_path):
    path = tmp_path / "toy.libsvm"
    path.write_text("+1 1:0.5 3:1.5\n-1 2:2.0\n+1 1:1.0 4:0.25\n")
    ds = parse_libsvm_file(str(path), num_features=4)
    assert ds.X.shape == (3, 4)
    np.testing.assert_allclose(ds.y, [1.0, -1.0, 1.0])
    np.testing.assert_allclose(ds.X[0], [0.5, 0.0, 1.5, 0.0])
    np.testing.assert_allclose(ds.X[1], [0.0, 2.0, 0.0, 0.0])
