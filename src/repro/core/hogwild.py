"""Hogwild! (Recht et al. 2011) — the paper's baseline, same delay engine.

Plain asynchronous SGD: v_m = ∇f_{i_m}(û_m) with NO control variate. Run
under the same bounded-delay read semantics so the comparison against
AsySVRG isolates exactly the paper's contribution (variance reduction under
asynchrony). Experiment settings follow the paper §5.1: each epoch runs n/p
iterations per thread (1 effective pass), constant step γ decayed by 0.9
per epoch ("These settings are the same as those in the experiments in
Hogwild!").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.asysvrg import AsyRunResult, _READERS, make_delay_schedule
from repro.core.objective import LogisticRegression


def hogwild_epoch(obj: LogisticRegression, w, key, step_size: float,
                  num_threads: int, tau: int = -1, scheme: str = "unlock",
                  drop_prob: float = 0.02):
    reader = _READERS[scheme]
    p_threads = max(1, num_threads)
    total = max(1, (obj.n // p_threads)) * p_threads     # n/p per thread
    tau = (p_threads - 1) if tau < 0 else tau
    tau = max(0, min(tau, total - 1))
    dim = obj.p

    k_idx, k_delay, k_scan = jax.random.split(key, 3)
    idx = jax.random.randint(k_idx, (total,), 0, obj.n)
    delays = make_delay_schedule("zero" if tau == 0 else "fixed",
                                 total, tau, k_delay)
    buf_len = tau + 1
    buffer = jnp.tile(w[None, :], (buf_len, 1))

    def slot_of(age):
        return jnp.mod(age, buf_len)

    def body(carry, inp):
        u, buffer = carry
        m, i, d, k = inp
        k_read, k_drop = jax.random.split(k)
        a = jnp.maximum(m - d, 0)
        u_read = reader(buffer, slot_of, a, m, k_read, dim)
        v = obj.sample_grad(u_read, i)
        if scheme == "unlock" and drop_prob > 0:
            keep = jax.random.bernoulli(k_drop, 1.0 - drop_prob, (dim,))
            v = v * keep
        u_next = u - step_size * v
        buffer = buffer.at[slot_of(m + 1)].set(u_next)
        return (u_next, buffer), None

    keys = jax.random.split(k_scan, total)
    ms = jnp.arange(total)
    (u_last, _), _ = jax.lax.scan(body, (w, buffer), (ms, idx, delays, keys))
    return u_last


def run_hogwild(obj: LogisticRegression, epochs: int, step_size: float,
                num_threads: int = 8, decay: float = 0.9,
                scheme: str = "unlock", tau: int = -1, seed: int = 0,
                w0=None) -> AsyRunResult:
    w = jnp.zeros(obj.p) if w0 is None else jnp.asarray(w0)
    key = jax.random.PRNGKey(seed)
    gamma = step_size

    epoch_fn = jax.jit(lambda w, k, g: hogwild_epoch(
        obj, w, k, g, num_threads, tau=tau, scheme=scheme))

    history = [float(obj.loss(w))]
    passes = [0.0]
    total_updates = 0
    for e in range(epochs):
        key, sub = jax.random.split(key)
        w = epoch_fn(w, sub, gamma)
        gamma = gamma * decay                     # paper: γ ← 0.9 γ per epoch
        history.append(float(obj.loss(w)))
        passes.append(passes[-1] + 1.0)           # 1 effective pass per epoch
        total_updates += max(1, obj.n // max(1, num_threads)) * num_threads
    return AsyRunResult(w=w, history=tuple(history),
                        effective_passes=tuple(passes),
                        total_updates=total_updates)
