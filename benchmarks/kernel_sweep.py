"""Fused Pallas sweep-epoch megakernel vs the vmap engine, self-gating.

For each group shape the SAME sweep runs twice — ``engine_mode="vmap"``
(the XLA-batched scan) and ``engine_mode="fused"`` (one Pallas launch per
group, rows on the grid) — and the benchmark ASSERTS the results match
before recording a single timing: in interpret mode the fused path must be
BIT-EXACT to the vmap path (the two bodies execute the same per-row
epochs-scan functions), so any drift is a correctness regression and this
benchmark fails the CI job rather than logging a delta. On a real
accelerator (compiled Mosaic lowering) the gate relaxes to allclose.

The artifact pairs measured times with the roofline-predicted intensity
headroom (`repro.launch.roofline.sweep_epoch_roofline`). Even under the
Pallas INTERPRETER on XLA:CPU the fused path wins (~2-3x on the CI
shapes): the grid loop executes one row's whole epochs-scan at a time, so
the working set is a single row's carry instead of the vmap path's
batched [rows, buf_len+2, d] carry streaming through memory every update
— a scaled-down preview of the VMEM-residency argument. The full
predicted headroom (~13x intensity) is what the compiled TPU path banks;
the real-accelerator revalidation item checks the prediction.

Writes ``BENCH_kernel_sweep.json`` (uploaded by the CI ``kernels-interpret``
job as ``bench-json-kernels``). ``--quick`` shrinks shapes for CI;
``--interpret`` pins ``REPRO_KERNEL_MODE=interpret`` so the run is
reproducible off-CI regardless of backend.
"""
from __future__ import annotations

import os
import sys
import time

import jax
import numpy as np

from benchmarks.artifacts import write_bench_json
from repro.core import LogisticRegression, SweepSpec, plan_sweep, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.kernels.dispatch import KERNEL_MODE_ENV, fused_sweep_mode
from repro.launch.roofline import sweep_epoch_roofline

_SCHEMES = ("consistent", "inconsistent", "unlock")


def _group_shapes(quick: bool):
    """(label, rows, inner_steps, epochs) — ≥2 shapes per run: one wide
    (many config rows, the service-coalescing regime) and one deep (few
    rows, long inner scans, the single-tenant convergence regime)."""
    if quick:
        return [("wide", 8, 20, 2), ("deep", 3, 60, 3)]
    return [("wide", 16, 100, 3), ("deep", 4, 400, 4)]


def _specs(rows: int, inner_steps: int, engine_mode: str):
    return [SweepSpec(scheme=_SCHEMES[c % 3], step_size=0.1, tau=2,
                      num_threads=4, inner_steps=inner_steps, seed=c,
                      engine_mode=engine_mode)
            for c in range(rows)]


def _time(fn, reps: int) -> float:
    fn()                                   # warm: compile + cache the runner
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(quick: bool = False):
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    mode = fused_sweep_mode()
    reps = 2 if quick else 3
    shapes = []
    for label, rows, inner, epochs in _group_shapes(quick):
        vmap_specs = _specs(rows, inner, "vmap")
        fused_specs = _specs(rows, inner, "fused")
        plan = plan_sweep(obj, epochs, fused_specs)
        (_, _, total, _, buf_len, fused_flag), = plan.groups
        assert fused_flag, "fused specs must plan onto the fused group key"

        r_vmap = run_sweep(obj, epochs, vmap_specs)
        r_fused = run_sweep(obj, epochs, fused_specs)
        # ---- the gate: parity BEFORE any timing is recorded -------------
        if mode == "interpret":
            np.testing.assert_array_equal(
                r_fused.histories, r_vmap.histories,
                err_msg=f"[{label}] fused histories diverged from vmap "
                        "(interpret mode must be bit-exact)")
            np.testing.assert_array_equal(
                r_fused.final_w, r_vmap.final_w,
                err_msg=f"[{label}] fused final iterates diverged from vmap")
        else:
            np.testing.assert_allclose(r_fused.histories, r_vmap.histories,
                                       rtol=1e-5, atol=1e-7)
            np.testing.assert_allclose(r_fused.final_w, r_vmap.final_w,
                                       rtol=1e-5, atol=1e-6)

        vmap_s = _time(lambda: run_sweep(obj, epochs, vmap_specs), reps)
        fused_s = _time(lambda: run_sweep(obj, epochs, fused_specs), reps)
        roof = sweep_epoch_roofline(rows=rows, dim=obj.flat_dim, total=total,
                                    epochs=epochs, buf_len=buf_len)
        shapes.append({
            "label": label, "rows": rows, "inner_steps": total,
            "epochs": epochs, "dim": obj.flat_dim, "buf_len": buf_len,
            "vmap_s": vmap_s, "fused_s": fused_s,
            "measured_speedup": vmap_s / fused_s,
            "parity": "bit-exact" if mode == "interpret" else "allclose",
            "roofline": roof,
        })
    return {
        "backend": jax.default_backend(),
        "fused_mode": mode,
        "shapes": shapes,
    }


def main(quick: bool = True, interpret: bool = False):
    if interpret:
        os.environ[KERNEL_MODE_ENV] = "interpret"
    out = run(quick=quick)
    write_bench_json("kernel_sweep", out)
    print("name,us_per_call,derived")
    for s in out["shapes"]:
        tag = f"kernel_sweep_{s['label']}_{s['rows']}x{s['inner_steps']}"
        print(f"{tag}_vmap,{s['vmap_s'] * 1e6:.1f},parity={s['parity']}")
        print(f"{tag}_fused,{s['fused_s'] * 1e6:.1f},"
              f"mode={out['fused_mode']};"
              f"measured_speedup={s['measured_speedup']:.3f};"
              f"roofline_headroom="
              f"{s['roofline']['intensity_headroom']:.1f};"
              f"roofline_speedup="
              f"{s['roofline']['predicted_speedup']:.2f}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv, interpret="--interpret" in sys.argv)
