"""Pallas kernel sweeps: shapes x dtypes vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.svrg_update import ops as svrg_ops
from repro.kernels.svrg_update.ref import svrg_update_ref
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.logreg_grad import ops as logreg_ops
from repro.kernels.logreg_grad.ref import logreg_grad_ref


# ---------------------------------------------------------------------------
# svrg_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64,), (1000,), (129, 7), (8, 64, 33),
                                   (8192,), (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_svrg_update_matches_ref(shape, dtype):
    keys = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 4)
    u, g, g0, gf = [jax.random.normal(k, shape).astype(dtype) for k in keys]
    out = svrg_ops.apply_leaf(u, g, g0, gf, 0.07, wd=0.01,
                              interpret=True, force_kernel=True)
    ref = svrg_update_ref(u, g, g0, gf, 0.07, 0.01)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_svrg_update_tree():
    tree = {"a": jnp.ones((33,)), "b": {"c": jnp.full((4, 5), 2.0)}}
    zeros = jax.tree.map(jnp.zeros_like, tree)
    out = svrg_ops.apply_tree(tree, tree, zeros, zeros, 0.5, 0.0,
                              interpret=True, force_kernel=True)
    # v = g - 0 + 0 = tree; u' = u - 0.5 u = 0.5 u
    np.testing.assert_allclose(np.asarray(out["a"]), 0.5 * np.ones(33),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), np.ones((4, 5)),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,bq,bk", [(128, 64, 64), (256, 64, 128),
                                     (256, 128, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
def test_flash_attention_matches_ref(S, bq, bk, causal, window):
    key = jax.random.PRNGKey(S + bq + window)
    ks = jax.random.split(key, 3)
    BH, d = 4, 32
    q, k, v = [jax.random.normal(kk, (BH, S, d)) for kk in ks]
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q[None], k[None], v[None],
                        causal=causal, window=window)[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("N,K", [(4, 4), (4, 2), (8, 1)])
def test_gqa_flash_wrapper(N, K):
    key = jax.random.PRNGKey(N * 17 + K)
    B, S, h = 2, 128, 16
    q = jax.random.normal(key, (B, S, N, h))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, h))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, h))
    out = flash_ops.gqa_flash(q, k, v, causal=True, interpret=True,
                              force_kernel=True, bq=64, bk=64)
    # oracle via jnp path
    ref = flash_ops.gqa_flash(q, k, v, causal=True, force_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (2, 128, 32)).astype(dtype)
    out = flash_attention(q, q, q, causal=True, bq=64, bk=64, interpret=True)
    ref = attention_ref(q[None], q[None], q[None], causal=True)[0]
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# logreg grad (the paper's workload)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,P", [(128, 512), (200, 300), (64, 1024),
                                 (300, 1)])
def test_logreg_grad_matches_ref(B, P):
    key = jax.random.PRNGKey(B + P)
    X = jax.random.normal(key, (B, P))
    y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (B,)) + 0.2)
    w = jax.random.normal(jax.random.fold_in(key, 2), (P,)) * 0.1
    out = logreg_ops.logreg_grad(X, y, w, 1e-4, interpret=True,
                                 force_kernel=True)
    ref = logreg_grad_ref(X, y, w, 1e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_logreg_grad_is_true_gradient():
    """Kernel output == autodiff gradient of the objective: validates
    against jax.grad, not just the hand-written ref."""
    key = jax.random.PRNGKey(4)
    B, P = 128, 256
    X = jax.random.normal(key, (B, P))
    y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (B,)) + 0.2)
    w = jax.random.normal(jax.random.fold_in(key, 2), (P,)) * 0.1

    def loss(w):
        return jnp.mean(jnp.logaddexp(0.0, -y * (X @ w))) \
            + 0.5e-4 * 2 * 0.5 * jnp.sum(w * w)

    g_auto = jax.grad(loss)(w)
    g_kern = logreg_ops.logreg_grad(X, y, w, 1e-4, interpret=True,
                                    force_kernel=True)
    np.testing.assert_allclose(np.asarray(g_kern), np.asarray(g_auto),
                               atol=1e-5)
