"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs.

Each assigned architecture lives in its own module with the exact published
dimensions; ``reduced_config`` shrinks any of them to a CPU-smoke-testable
size of the SAME family (fewer/narrower layers, tiny vocab, few experts)
without changing the code path exercised.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.config import ModelConfig

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded():
    from repro.configs import (  # noqa: F401
        whisper_large_v3, chatglm3_6b, stablelm_12b, gemma3_4b,
        command_r_plus_104b, qwen3_moe_235b, deepseek_moe_16b,
        llama32_vision_11b, recurrentgemma_2b, falcon_mamba_7b,
        paper_logreg,
    )


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def reduced_config(name: str) -> ModelConfig:
    """Same-family miniature for CPU smoke tests (one fwd/train step)."""
    cfg = get_config(name)
    kw = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads)),
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        dtype="float32",
        param_dtype="float32",
        remat="none",
    )
    if cfg.family == "moe":
        kw.update(num_experts=8, experts_per_token=min(2, cfg.experts_per_token),
                  moe_d_ff=64,
                  num_shared_experts=cfg.num_shared_experts and 1,
                  first_dense_layers=min(1, cfg.first_dense_layers),
                  d_ff=0)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2, encoder_seq=16, encoder_feature_dim=24)
    if cfg.family == "vlm":
        kw.update(num_layers=5, cross_attn_every=5, num_image_tokens=8,
                  image_embed_dim=48)
    if cfg.family == "hybrid":
        kw.update(num_layers=5, lru_width=128, num_heads=4, local_window=8)
    if cfg.family == "ssm":
        kw.update(num_layers=4, ssm_state=4, expand=2, dt_rank=8,
                  num_heads=1, num_kv_heads=1, head_dim=1, d_ff=0)
    if cfg.attn_pattern == "local_global":
        kw.update(local_window=8, global_every=min(3, cfg.global_every))
    if cfg.family == "logreg":
        kw = dict(num_features=64)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
