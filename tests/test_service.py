"""Sweep service suite: persistent runner cache + request coalescing.

Pins the three contracts the `repro.service` subsystem introduces:

  * COMPILE-COUNTER REGRESSION — a second same-shape sweep (direct
    `run_sweep` or through `SweepService`) performs ZERO new compiles: the
    group bodies close over hashable statics only, so the module-level
    runner cache hands back the previous call's jitted program. The counter
    increments at trace time, so it exactly counts (re)compilations.
  * COALESCING BIT-IDENTITY — rows from many requests merged into shared
    compiled groups demux back bit-identical to standalone `run_sweep`
    calls, for all three algos and mixed per-row epoch budgets.
  * CHECKPOINT-RESUME — a preempted `run_job` resumes from the newest
    checkpoint, re-runs only unfinished groups, and the final result is
    bit-identical to one `run_sweep` call.
"""
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.service import (
    ResultEvictedError,
    SweepService,
    cache_size,
    cache_stats,
    clear_cache,
    coalesce,
)
from repro.service.cache import runner_key


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def _grid_a():
    return [SweepSpec(scheme="inconsistent", step_size=0.5, tau=3,
                      num_threads=4, inner_steps=25, seed=s)
            for s in range(2)]


def _grid_mixed():
    """All three algos + mixed per-row epoch budgets in one request."""
    return [SweepSpec(scheme="unlock", step_size=0.25, tau=3, num_threads=4,
                      inner_steps=25, seed=7, epochs=1),
            SweepSpec(scheme="consistent", step_size=0.5, tau=3,
                      num_threads=4, inner_steps=25, seed=8, epochs=3),
            SweepSpec(algo="hogwild", scheme="consistent", step_size=0.5,
                      tau=2, num_threads=3, seed=1),
            SweepSpec(algo="svrg", step_size=0.5, num_threads=1,
                      inner_steps=30, seed=2)]


def _assert_same(got, want):
    np.testing.assert_array_equal(got.histories, want.histories)
    np.testing.assert_array_equal(got.final_w, want.final_w)
    np.testing.assert_array_equal(got.effective_passes,
                                  want.effective_passes)
    np.testing.assert_array_equal(got.total_updates, want.total_updates)
    np.testing.assert_array_equal(got.epochs_per_row, want.epochs_per_row)
    assert got.specs == want.specs


# --------------------------------------------------------------- cache layer
def test_second_same_shape_sweep_compiles_nothing(obj):
    """Acceptance: repeated `run_sweep` with the same static group dims and
    data shapes performs zero new traces — the ROADMAP runner-cache item."""
    specs = _grid_a()
    clear_cache()
    first = run_sweep(obj, 2, specs)
    cold = cache_stats()
    assert cold.misses >= 1 and cold.compiles >= 1
    second = run_sweep(obj, 2, specs)
    warm = cache_stats().since(cold)
    assert warm.compiles == 0, "second same-shape sweep recompiled"
    assert warm.misses == 0 and warm.hits >= 1
    _assert_same(second, first)


def test_service_second_sweep_compiles_nothing(obj):
    """The acceptance criterion through the service front-end."""
    svc = SweepService(obj, epochs=2)
    svc.sweep(_grid_a())
    base = cache_stats()
    svc.sweep(_grid_a())
    assert cache_stats().since(base).compiles == 0
    stats = svc.stats()
    assert stats.flushes == 2 and stats.cache_hit_rate > 0


def test_cache_keys_separate_static_dims(obj):
    """Different epochs-bound / drop_prob / objective key different
    runners; identical dims (even via a different Mesh-less path) share."""
    k = dict(group_epochs=2, total=100, option=2, buf_len=4,
             drop_prob=0.02, mesh=None, obj=obj)
    base = runner_key("asysvrg", **k)
    assert runner_key("asysvrg", **k) == base
    assert runner_key("hogwild", **k) != base
    assert runner_key("asysvrg", **{**k, "group_epochs": 3}) != base
    assert runner_key("asysvrg", **{**k, "drop_prob": 0.0}) != base
    assert runner_key("asysvrg", **{**k, "buf_len": 8}) != base
    # same static key, same data shapes, DIFFERENT instance: shares a runner
    obj2 = LogisticRegression(obj.X, obj.y, l2_reg=obj.l2)
    assert runner_key("asysvrg", **{**k, "obj": obj2}) == base


def test_clear_cache_resets(obj):
    run_sweep(obj, 1, _grid_a()[:1])
    assert cache_size() >= 1
    clear_cache()
    assert cache_size() == 0
    assert cache_stats().misses == 0


# ----------------------------------------------------------- scheduler layer
def test_coalesce_merges_compatible_rows_across_requests(obj):
    """Rows with equal static group dims pool into ONE group across
    requests; incompatible rows (different M̃) stay separate."""
    svc = SweepService(obj, epochs=2)
    svc.submit(_grid_a())                      # M̃ = 4*25
    svc.submit([SweepSpec(scheme="unlock", step_size=1.0, tau=3,
                          num_threads=4, inner_steps=25, seed=9)])
    svc.submit([SweepSpec(scheme="unlock", step_size=1.0, tau=2,
                          num_threads=3, inner_steps=20, seed=9)])  # M̃ = 60
    batch = coalesce(obj, tuple(svc._pending))
    sizes = sorted(len(m) for m in batch.groups.values())
    assert sizes == [1, 3]                     # 2+1 merged, 1 alone
    svc.flush()
    assert svc.stats().rows_coalesced == 3
    assert svc.stats().groups_merged == 1


def test_multi_request_coalescing_bit_identical(obj):
    """Acceptance: every request's demuxed result equals a standalone
    `run_sweep` of that request — all three algos, mixed per-row epochs,
    different per-request default budgets, one flush."""
    svc = SweepService(obj, epochs=2)
    reqs = {svc.submit(_grid_a()): (_grid_a(), 2),
            svc.submit(_grid_mixed()): (_grid_mixed(), 2),
            svc.submit(_grid_a()[:1], epochs=3): (_grid_a()[:1], 3)}
    done = svc.flush()
    assert sorted(done) == sorted(reqs)
    for rid, (specs, epochs) in reqs.items():
        _assert_same(svc.result(rid), run_sweep(obj, epochs, specs))
    assert svc.stats().rows_coalesced > 0


def test_result_flushes_implicitly_and_unknown_id_raises(obj):
    svc = SweepService(obj, epochs=1)
    rid = svc.submit(_grid_a()[:1])
    assert svc.pending() == 1
    res = svc.result(rid)                      # implicit flush
    assert svc.pending() == 0
    _assert_same(res, run_sweep(obj, 1, _grid_a()[:1]))
    with pytest.raises(KeyError):
        svc.result(10_000)


def test_empty_submissions_rejected(obj):
    svc = SweepService(obj)
    with pytest.raises(ValueError):
        svc.submit([])
    assert svc.flush() == []                   # nothing pending is a no-op


def test_invalid_spec_rejected_at_submit_not_flush(obj):
    """A bad spec raises to ITS client at submit time and can never wedge
    a shared flush: the other tenant's request still completes."""
    svc = SweepService(obj, epochs=1)
    rid = svc.submit(_grid_a()[:1])
    with pytest.raises(ValueError):
        svc.submit([SweepSpec(algo="svrg", tau=3)])      # svrg is τ=0
    with pytest.raises(ValueError):
        svc.submit([SweepSpec(scheme="nope")])
    with pytest.raises(ValueError):
        svc.submit(_grid_a()[:1], epochs=0)              # resolves to 0
    with pytest.raises(ValueError):                      # resolves M̃ < 1,
        svc.submit([SweepSpec(algo="svrg", num_threads=1,  # would only blow
                              inner_steps=-1)])          # up at trace time
    assert svc.pending() == 1                  # queue not poisoned
    _assert_same(svc.result(rid), run_sweep(obj, 1, _grid_a()[:1]))


def test_results_retention_bound_and_discard(obj):
    """Completed results are FIFO-bounded (a long-lived server must not
    hold every tenant's histories forever) and releasable via discard."""
    svc = SweepService(obj, epochs=1, max_results=2)
    rids = [svc.submit(_grid_a()[:1]) for _ in range(3)]
    svc.flush()
    with pytest.raises(KeyError):              # oldest evicted
        svc.result(rids[0])
    _assert_same(svc.result(rids[2]), run_sweep(obj, 1, _grid_a()[:1]))
    svc.discard(rids[2])
    with pytest.raises(KeyError):
        svc.result(rids[2])
    svc.discard(rids[2])                       # idempotent


def test_eviction_never_drops_actively_awaited_result(obj):
    """One wide flush completing more requests than ``max_results`` must
    not evict a result whose consumer is already parked in wait_result —
    eviction skips watched ids and drops an unwatched one instead."""
    import threading
    import time

    svc = SweepService(obj, epochs=1, max_results=1)
    r1 = svc.submit(_grid_a()[:1])
    r2 = svc.submit(_grid_a()[1:2])
    got = {}
    waiter = threading.Thread(
        target=lambda: got.update(res=svc.wait_result(r1, timeout=120)))
    waiter.start()
    for _ in range(500):                       # let the waiter park
        if r1 in svc._watched:
            break
        time.sleep(0.01)
    assert r1 in svc._watched
    svc.flush()                                # completes BOTH requests
    waiter.join()
    _assert_same(got["res"], run_sweep(obj, 1, _grid_a()[:1]))
    with pytest.raises(ResultEvictedError):    # the unwatched one paid
        svc.result(r2)


def test_tenant_accounting_is_bounded(obj):
    """Tenant tags are arbitrary client strings: the per-tenant row map is
    FIFO-bounded so tag-churning clients can't grow the service."""
    svc = SweepService(obj, epochs=1, max_tenants=2)
    for t in ("a", "b", "c"):
        svc.submit(_grid_a()[:1], tenant=t)
    rows = svc.tenant_rows()
    assert len(rows) == 2 and "a" not in rows


def test_evicted_ids_distinguished_from_unknown(obj):
    """An id whose result fell off the `max_results` FIFO raises the typed
    `ResultEvictedError` (naming the bound, so a client of a busy server
    knows to re-submit or raise the bound); an id that NEVER existed stays
    a bare KeyError. `wait_result` mirrors the distinction."""
    svc = SweepService(obj, epochs=1, max_results=1)
    old = svc.submit(_grid_a()[:1])
    svc.flush()
    newer = svc.submit(_grid_a()[:1])
    svc.flush()                                # evicts `old`
    with pytest.raises(ResultEvictedError, match="max_results=1"):
        svc.result(old)
    with pytest.raises(ResultEvictedError):
        svc.wait_result(old, timeout=0.1)
    # a phantom id is NOT reported as evicted
    with pytest.raises(KeyError) as ei:
        svc.result(10_000)
    assert not isinstance(ei.value, ResultEvictedError)
    with pytest.raises(KeyError) as ei:
        svc.wait_result(10_000, timeout=0.1)
    assert not isinstance(ei.value, ResultEvictedError)
    svc.result(newer)                          # the live one still serves


def test_flush_selector_must_partition_queue(obj):
    """A selector that drops or duplicates a request is a lost-request bug
    waiting to happen; flush() rejects it and keeps the queue intact."""
    svc = SweepService(obj, epochs=1)
    rid = svc.submit(_grid_a()[:1])
    with pytest.raises(ValueError, match="partition"):
        svc.flush(lambda pending: ((), ()))            # dropped
    with pytest.raises(ValueError, match="partition"):
        svc.flush(lambda pending: (pending, pending))  # duplicated
    assert svc.pending() == 1                  # queue untouched
    _assert_same(svc.result(rid), run_sweep(obj, 1, _grid_a()[:1]))


def test_concurrent_services_cache_attribution_exact(obj):
    """Cache counters are credited at the LOOKUP site through a thread-
    scoped sink: a WARM service flushing concurrently with a COLD one
    (new compiled shape) must report 0 compiles of its own, even though
    the process-global counters moved under it. The old window-absorption
    accounting raced exactly here."""
    import threading

    clear_cache()
    warm_specs = _grid_a()
    run_sweep(obj, 2, warm_specs)              # pre-compile the warm shape
    svc_warm = SweepService(obj, epochs=2)
    svc_cold = SweepService(obj, epochs=5)     # new epochs-bound: compiles
    svc_warm.submit(warm_specs)
    svc_cold.submit(warm_specs)
    barrier = threading.Barrier(2)
    errs = []

    def flush(svc):
        try:
            barrier.wait()                     # force the windows to overlap
            svc.flush()
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=flush, args=(s,))
               for s in (svc_warm, svc_cold)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    warm, cold = svc_warm.stats(), svc_cold.stats()
    assert warm.compiles == 0, \
        "warm service charged for a concurrent service's compile"
    assert cold.compiles >= 1
    assert warm.cache_hits >= 1 and warm.cache_misses == 0
    # the per-service sinks jointly account for the global movement
    total = cache_stats()
    assert warm.compiles + cold.compiles <= total.compiles


def test_concurrent_submits_mint_unique_ids(obj):
    """submit() from many tenant threads never duplicates request ids or
    drops a queued request."""
    import threading

    svc = SweepService(obj, epochs=1)
    ids, errs = [], []

    def client():
        try:
            ids.append(svc.submit(_grid_a()[:1]))
        except Exception as e:                 # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=client) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(set(ids)) == 16
    assert svc.pending() == 16


def test_result_waits_for_inflight_flush(obj):
    """result() called while ANOTHER thread's flush has the request in
    flight blocks until the result lands instead of raising KeyError."""
    import threading

    svc = SweepService(obj, epochs=1)
    rid = svc.submit(_grid_a()[:1])
    flusher = threading.Thread(target=svc.flush)
    flusher.start()
    try:
        res = svc.result(rid)                  # races the flush window
    finally:
        flusher.join()
    _assert_same(res, run_sweep(obj, 1, _grid_a()[:1]))


def test_cache_lru_bound(obj):
    """The runner cache is LRU-bounded: distinct keys beyond the limit
    evict the least recently used entry instead of growing forever."""
    from repro.service import set_cache_limit

    clear_cache()
    prev = set_cache_limit(2)
    try:
        for e in (1, 2, 3):                    # 3 distinct epoch-bound keys
            run_sweep(obj, e, _grid_a()[:1])
        assert cache_size() == 2
        base = cache_stats()
        run_sweep(obj, 3, _grid_a()[:1])       # most recent: still cached
        assert cache_stats().since(base).compiles == 0
        run_sweep(obj, 1, _grid_a()[:1])       # evicted: rebuilt + retraced
        assert cache_stats().since(base).misses == 1
    finally:
        set_cache_limit(prev)
        clear_cache()


# ------------------------------------------------------- checkpointed jobs
def test_run_job_checkpoint_resume_bit_identical(obj, tmp_path):
    """A job preempted after each group (max_groups=1) resumes to the same
    bits as one uninterrupted `run_sweep`; finished groups never re-run."""
    specs = _grid_mixed()
    svc = SweepService(obj, epochs=2)
    calls = 0
    res, done = None, False
    while not done:
        res, done = svc.run_job(specs, checkpointer=Checkpointer(str(tmp_path)),
                                max_groups=1)
        calls += 1
        assert calls < 20
    assert calls >= 3                          # >=3 groups -> real resumes
    _assert_same(res, run_sweep(obj, 2, specs))


def test_run_job_rejects_foreign_checkpoint(obj, tmp_path):
    svc = SweepService(obj, epochs=1)
    ckpt = Checkpointer(str(tmp_path))
    _, done = svc.run_job(_grid_a()[:1], checkpointer=ckpt, max_groups=1)
    with pytest.raises(ValueError, match="different job"):
        svc.run_job(_grid_mixed(), checkpointer=Checkpointer(str(tmp_path)))


def test_run_job_rejects_different_w0_resume(obj, tmp_path):
    """The job fingerprint pins the numeric inputs too: a resume from a
    different initial iterate must not blend with checkpointed groups."""
    specs = _grid_a()[:1] + [SweepSpec(algo="svrg", step_size=0.5,
                                       num_threads=1, inner_steps=30)]
    svc = SweepService(obj, epochs=1)
    _, done = svc.run_job(specs, checkpointer=Checkpointer(str(tmp_path)),
                          max_groups=1)
    assert not done
    svc_b = SweepService(obj, epochs=1, w0=np.full(obj.p, 0.1, np.float32))
    with pytest.raises(ValueError, match="different job"):
        svc_b.run_job(specs, checkpointer=Checkpointer(str(tmp_path)))
