"""`repro.server.metrics.snapshot` contract: one JSON-safe dict, always.

The snapshot backs three consumers with different parsers — ``/stats``
(json.dumps), ``/metrics`` (the Prometheus walker, which float()s every
leaf) and operator scripts — so the contract is structural: every
configuration (± daemon, ± fairness) serializes with the stock JSON
encoder, the top-level sections are stable, and NO numpy scalar ever
leaks into a leaf (np.float64 survives json.dumps by accident of
subclassing, np.int64 raises, and both break strict consumers — the walk
below rejects every non-builtin type).
"""
import json

import pytest

from repro.core import LogisticRegression, SweepSpec
from repro.data.libsvm import make_synthetic_libsvm
from repro.server import FairShare, FlushPolicy, ServeDaemon, snapshot
from repro.service import SweepService

_BUILTIN_LEAVES = (str, bool, int, float, type(None))


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=11, scale=0.002)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def _specs(seeds):
    return [SweepSpec(scheme="inconsistent", step_size=0.5, tau=3,
                      num_threads=4, inner_steps=25, seed=s)
            for s in seeds]


def _worked_service(obj):
    """A service with real accounting: latencies, tenants, cache counters."""
    svc = SweepService(obj, epochs=1)
    for tenant, seed in (("team-a", 1), ("team-b", 2)):
        svc.submit(_specs([seed]), tenant=tenant)
    svc.flush()
    svc.submit(_specs([3]))                     # leave the queue non-empty
    return svc


def _assert_builtin_tree(node, path="$"):
    """Reject numpy scalars (and any other non-builtin) at every leaf.
    ``type() in`` on purpose: np.float64 IS-A float, np.bool_ is not a
    bool — isinstance would wave the first through."""
    if isinstance(node, dict):
        for key, child in node.items():
            assert type(key) is str, f"non-str key {key!r} at {path}"
            _assert_builtin_tree(child, f"{path}.{key}")
    elif isinstance(node, (list, tuple)):
        for i, child in enumerate(node):
            _assert_builtin_tree(child, f"{path}[{i}]")
    else:
        assert type(node) in _BUILTIN_LEAVES, \
            f"non-builtin leaf {type(node).__name__} at {path}: {node!r}"


def test_snapshot_service_only_round_trips_and_has_all_sections(obj):
    svc = _worked_service(obj)
    snap = snapshot(svc)
    assert set(snap) == {"service", "queue", "tenants", "flush_latency",
                         "request_latency", "runner_cache"}
    _assert_builtin_tree(snap)
    assert json.loads(json.dumps(snap)) == snap
    assert snap["service"]["flushes"] == 1
    assert snap["queue"]["depth_requests"] == 1
    assert snap["queue"]["oldest_age_ms"] > 0
    assert set(snap["tenants"]) == {"team-a", "team-b", "default"}
    assert snap["tenants"]["team-a"] == {"rows_submitted": 1,
                                         "rows_completed": 1}
    assert snap["flush_latency"]["count"] == 1
    assert snap["flush_latency"]["p95_ms"] >= 0.0
    assert snap["request_latency"]["count"] == 2


def test_snapshot_with_daemon_and_fairness_blocks(obj):
    svc = _worked_service(obj)
    fairness = FairShare(quantum_rows=16)
    daemon = ServeDaemon(svc, FlushPolicy(max_delay_ms=10),
                         fairness=fairness)
    with daemon:
        snap = snapshot(svc, daemon, fairness)
        assert set(snap) == {"service", "queue", "tenants", "flush_latency",
                             "request_latency", "runner_cache", "daemon",
                             "fairness"}
        _assert_builtin_tree(snap)
        assert json.loads(json.dumps(snap)) == snap
        assert snap["daemon"]["running"] is True
        assert snap["daemon"]["heartbeat_age_s"] >= 0.0
        assert snap["daemon"]["policy"]["heartbeat_stall_s"] == 30.0
        assert snap["fairness"]["quantum_rows"] == 16
    # after stop(): still JSON-safe, and liveness reads False/stale
    snap = snapshot(svc, daemon, fairness)
    _assert_builtin_tree(snap)
    assert snap["daemon"]["running"] is False


def test_snapshot_leaves_survive_the_prometheus_walker(obj):
    """The /metrics renderer float()s every numeric leaf it keeps; the
    snapshot must never hand it something that changes value under
    float() (i.e. only real numbers, bools, strings, None)."""
    from repro.obs.prometheus import render
    svc = _worked_service(obj)
    text = render(snapshot(svc), histograms=svc.histograms.as_dict())
    assert text.endswith("\n") and "repro_service_rows_submitted" in text


def test_prometheus_escapes_malicious_tenant_labels(obj):
    """Regression pin for the 0.0.4 label-escaping rules: a tenant name
    carrying backslashes, quotes and newlines must come out as ONE valid
    sample line with ``\\\\``, ``\\"`` and ``\\n`` escapes — an unescaped
    quote ends the label value early and an unescaped newline injects a
    whole forged sample into the scrape."""
    from repro.obs.prometheus import render
    evil = 'team"a\\b\nrepro_forged_metric 1'
    svc = SweepService(obj, epochs=1)
    svc.submit(_specs([1]), tenant=evil)
    svc.flush()
    text = render(snapshot(svc))
    expected = 'tenant="team\\"a\\\\b\\nrepro_forged_metric 1"'
    assert expected in text
    # no forged series: the newline never reached the exposition raw
    assert not any(ln.startswith("repro_forged_metric")
                   for ln in text.splitlines())
    # every line still parses as 0.0.4 (comment/blank/sample)
    import re
    prom_line = re.compile(
        r"^(#.*|[a-zA-Z_:][a-zA-Z0-9_:]*(\{.*\})?\s[^\s]+)$")
    bad = [ln for ln in text.splitlines() if ln and not prom_line.match(ln)]
    assert not bad, bad


def test_snapshot_ledger_section_is_opt_in(obj):
    """The exact default section set (pinned above) must not grow when
    the ledger is off; enabling it adds one ``ledger`` section whose
    groups render as ``repro_ledger_*{group=...}`` series."""
    from repro.obs.ledger import disable_ledger, enable_ledger
    from repro.obs.prometheus import render
    svc = _worked_service(obj)
    assert "ledger" not in snapshot(svc)
    enable_ledger().clear()
    try:
        svc.submit(_specs([9]))
        svc.flush()
        snap = snapshot(svc)
        assert set(snap) == {"service", "queue", "tenants", "flush_latency",
                             "request_latency", "runner_cache", "ledger"}
        _assert_builtin_tree(snap)
        assert json.loads(json.dumps(snap)) == snap
        assert len(snap["ledger"]) >= 1
        entry = next(iter(snap["ledger"].values()))
        assert {"dispatches", "compile_s", "flops",
                "attained_frac"} <= set(entry)
        text = render(snap)
        assert 'repro_ledger_dispatches{group="' in text
        assert 'repro_ledger_attained_frac{group="' in text
    finally:
        disable_ledger(clear=True)
    assert "ledger" not in snapshot(svc)
