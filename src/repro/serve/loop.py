"""Batched serving loop: prefill + greedy/temperature decode.

The decode step is a single jit'd function over (params, cache, token, pos)
— the same function the decode_* dry-run cells lower at pod scale. The
session object owns the cache and position; `generate` drives a fixed batch
of requests (continuous batching with per-request positions is left as the
documented extension point; the cache layout already supports it since
positions enter as data).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ServeConfig
from repro.models.factory import ModelBundle


class ServeSession:
    def __init__(self, bundle: ModelBundle, params, cache_len: int,
                 scfg: Optional[ServeConfig] = None):
        self.bundle = bundle
        self.params = params
        self.cache_len = cache_len
        self.scfg = scfg or ServeConfig()
        self._prefill = jax.jit(
            lambda p, b: bundle.prefill_fn(p, b, cache_len))
        self._decode = jax.jit(bundle.decode_fn, donate_argnums=(1,))
        self.cache = None
        self.pos = 0

    def prefill(self, batch):
        logits, self.cache = self._prefill(self.params, batch)
        self.pos = batch["tokens"].shape[1]
        return logits

    def decode(self, tokens):
        logits, self.cache = self._decode(
            self.params, self.cache, tokens, jnp.asarray(self.pos, jnp.int32))
        self.pos += 1
        return logits


def _sample(logits, temperature: float, key):
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def generate(bundle: ModelBundle, params, batch, max_new_tokens: int,
             cache_len: int, temperature: float = 0.0, seed: int = 0):
    """Prefill `batch` then decode max_new_tokens greedily; returns
    [B, max_new_tokens] int32 tokens."""
    sess = ServeSession(bundle, params, cache_len)
    key = jax.random.PRNGKey(seed)
    logits = sess.prefill(batch)
    outs = []
    tok = _sample(logits, temperature, key)
    outs.append(tok)
    for i in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        logits = sess.decode(tok)
        tok = _sample(logits, temperature, sub)
        outs.append(tok)
    return jnp.stack(outs, axis=1)
