"""Public fused sweep-epoch op: engine row functions + group-fn builder.

`fused_group_fn` returns a function with EXACTLY the calling convention of
the vmap group bodies in `repro.core.sweep` (``group(*data_args,
*row_args) -> (w_fin [C, d], hist [C, epochs+1])``), so the engine's
dispatch, the service runner cache and the `shard_map` wrapper all treat
the megakernel as a drop-in engine: `run_sweep` selects it per group via
``SweepSpec.engine_mode`` and nothing above `core.sweep` changes.

Mode selection goes through `repro.kernels.dispatch.fused_sweep_mode` —
interpret everywhere except TPU (compiled Mosaic lowering is unvalidated
off-TPU; the interpret path is bit-exact to the vmap engine, which is this
kernel's reference oracle).
"""
from __future__ import annotations

from repro.core.asysvrg import _asysvrg_epochs_core
from repro.core.hogwild import _hogwild_epochs_core
from repro.kernels.sweep_epoch.kernel import sweep_epoch_call


def fused_group_fn(obj, num_data: int, *, engine: str, epochs: int,
                   total: int, buf_len: int, option: int, drop_prob: float,
                   interpret: bool):
    """The megakernel group body for one (engine, M̃, option, buf_len) group.

    Closes over the objective's PURE methods + static config only (the
    data tuple and every per-row array are runtime arguments), mirroring
    `repro.core.sweep._asysvrg_group_fn` — so the returned function lives
    in the persistent runner cache under the same rules, keyed with the
    fused flag and resolved kernel mode.
    """
    if engine == "hogwild":
        def row_fn(data, key, gamma, decay, tau, scheme_id, delay_id,
                   row_epochs, w0):
            return _hogwild_epochs_core(
                obj, data, w0, key, gamma, decay, tau, scheme_id, delay_id,
                epochs=epochs, total=total, buf_len=buf_len,
                drop_prob=drop_prob, row_epochs=row_epochs)
    else:
        def row_fn(data, key, eta, tau, scheme_id, delay_id, row_epochs, w0):
            return _asysvrg_epochs_core(
                obj, data, w0, key, eta, tau, scheme_id, delay_id,
                epochs=epochs, total=total, buf_len=buf_len, option=option,
                drop_prob=drop_prob, row_epochs=row_epochs)

    dim = obj.flat_dim

    def group(*all_args):
        data = all_args[:num_data]
        row_args = all_args[num_data:]
        return sweep_epoch_call(row_fn, data, row_args, epochs=epochs,
                                dim=dim, interpret=interpret)

    return group
