"""Serving-tier latency/throughput: eager per-request flush vs the
background daemon's deadline-coalesced batching, over real HTTP.

The experiment the async serving tier exists for: K closed-loop tenants
(each submits, waits for its result, repeats) drive one in-process
`SweepServer` through the stdlib HTTP client, at several offered loads
(tenant counts). Two serving policies:

  * EAGER — no flush daemon; every submit is followed by POST /flush, the
    synchronous-coordination baseline. No cross-tenant coalescing, and
    whatever batch width each flush happens to catch is the width XLA
    traces (drifting widths retrace even on a runner-cache hit).
  * DEADLINE-COALESCED — `FlushPolicy(max_delay_ms=…, stable_widths=True)`:
    submits return immediately, the daemon flushes the merged batch when
    the deadline (or size bound) fires, and the width registry pads merged
    groups to previously-compiled widths so the warm path stays at
    0 compiles.

Reported per (mode, load): p50/p95/mean request latency (client-side
submit→result), rows/s throughput, flushes, compiles during the measured
phase. Acceptance (asserted at the max load, after per-mode warm-up):
deadline-coalesced throughput ≥ 2× eager, with 0 compiles in the measured
coalesced phase. Writes ``BENCH_server_latency.json``; ``--quick`` is the
CI `server-smoke` configuration.
"""
from __future__ import annotations

import sys
import threading
import time

from benchmarks.artifacts import write_bench_json
from repro.core import LogisticRegression, SweepSpec
from repro.data.libsvm import make_synthetic_libsvm
from repro.server import FlushPolicy, SweepClient, SweepServer
from repro.server.metrics import percentile
from repro.service import SweepService, cache_stats

MAX_TENANTS = 6
ROWS_PER_REQUEST = 4
ACCEPT_SPEEDUP = 2.0


def _tenant_specs(tenant: int, round_: int) -> list:
    """One compatible 4-row probe (same static dims across tenants, own
    seeds) — the many-small-clients regime coalescing targets."""
    return [SweepSpec(scheme=("consistent", "inconsistent", "unlock")[c % 3],
                      step_size=(0.25, 0.5)[c % 2], tau=3, num_threads=4,
                      inner_steps=25, seed=10_000 * tenant + 10 * round_ + c)
            for c in range(ROWS_PER_REQUEST)]


def _drive(url: str, tenants: int, rounds: int, eager: bool):
    """Run the closed-loop tenant fleet; returns per-request latencies."""
    latencies, errors = [], []
    lock = threading.Lock()

    def tenant_loop(t: int):
        client = SweepClient(url, poll_s=5.0)
        try:
            for r in range(rounds):
                t0 = time.perf_counter()
                rid = client.submit(_tenant_specs(t, r), tenant=f"t{t}")
                if eager:
                    client.flush()
                client.result(rid, timeout=600)
                dt = time.perf_counter() - t0
                with lock:
                    latencies.append(dt)
        except Exception as e:               # surface, don't hang the fleet
            with lock:
                errors.append(e)

    threads = [threading.Thread(target=tenant_loop, args=(t,))
               for t in range(tenants)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return latencies, wall


def _measure(obj, epochs: int, tenants: int, rounds: int, eager: bool,
             max_delay_ms: float) -> dict:
    """One (mode, load) cell: fresh service + server, one warm-up wave
    (compiles + records widths), then the measured closed-loop phase."""
    svc = SweepService(obj, epochs=epochs)
    policy = (None if eager else
              FlushPolicy(max_rows=tenants * ROWS_PER_REQUEST,
                          max_delay_ms=max_delay_ms,
                          stable_widths=True, max_pad_factor=16.0))
    with SweepServer(svc, policy=policy) as server:
        _drive(server.url, tenants, 1, eager)          # warm-up wave
        base = cache_stats()
        latencies, wall = _drive(server.url, tenants, rounds, eager)
        delta = cache_stats().since(base)
        stats = svc.stats()
    n_requests = tenants * rounds
    rows = n_requests * ROWS_PER_REQUEST
    return {
        "mode": "eager" if eager else "coalesced",
        "tenants": tenants, "rounds": rounds, "requests": n_requests,
        "rows": rows,
        "wall_s": wall,
        "rows_per_s": rows / wall,
        "latency_p50_ms": percentile(latencies, 50) * 1e3,
        "latency_p95_ms": percentile(latencies, 95) * 1e3,
        "latency_mean_ms": sum(latencies) / len(latencies) * 1e3,
        "compiles_measured": delta.compiles,
        "flushes": stats.flushes,
        "rows_coalesced": stats.rows_coalesced,
        "rows_padded": stats.rows_padded,
        "cache_hit_rate": stats.cache_hit_rate,
    }


def run(quick: bool = False):
    ds = make_synthetic_libsvm("real-sim", seed=11,
                               scale=0.002 if quick else 0.01)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    epochs = 2 if quick else 4
    rounds = 3 if quick else 6
    loads = (2, MAX_TENANTS) if quick else (1, 2, 4, MAX_TENANTS)
    max_delay_ms = 20.0

    cells = []
    for tenants in loads:
        for eager in (True, False):
            cells.append(_measure(obj, epochs, tenants, rounds, eager,
                                  max_delay_ms))

    top = {c["mode"]: c for c in cells if c["tenants"] == MAX_TENANTS}
    speedup = top["coalesced"]["rows_per_s"] / top["eager"]["rows_per_s"]
    out = {
        "dataset": "real-sim", "epochs": epochs,
        "rows_per_request": ROWS_PER_REQUEST,
        "max_delay_ms": max_delay_ms,
        "loads": list(loads), "cells": cells,
        "coalesced_speedup_at_max_load": speedup,
        "coalesced_compiles_at_max_load": top["coalesced"][
            "compiles_measured"],
    }
    # acceptance: deadline coalescing must beat eager serving >= 2x at the
    # full tenant fleet, on a warm cache with zero measured compiles
    if top["coalesced"]["compiles_measured"]:
        raise AssertionError(
            "warm coalesced serving recompiled "
            f"({top['coalesced']['compiles_measured']} traces) — stable-"
            "width regression")
    if speedup < ACCEPT_SPEEDUP:
        raise AssertionError(
            f"deadline-coalesced serving only {speedup:.2f}x eager at "
            f"{MAX_TENANTS} tenants (acceptance: >= {ACCEPT_SPEEDUP}x)")
    return out


def main(quick: bool = True):
    out = run(quick=quick)
    write_bench_json("server_latency", out)
    print("name,us_per_call,derived")
    for c in out["cells"]:
        print(f"server_{c['mode']}_{c['tenants']}tenants,"
              f"{c['latency_p50_ms'] * 1e3:.1f},"
              f"p95_ms={c['latency_p95_ms']:.1f};"
              f"rows_per_s={c['rows_per_s']:.1f};"
              f"compiles={c['compiles_measured']};"
              f"flushes={c['flushes']}")
    print(f"server_coalesced_speedup,"
          f"{out['coalesced_speedup_at_max_load']:.2f},"
          f"at_{MAX_TENANTS}_tenants;warm_compiles="
          f"{out['coalesced_compiles_at_max_load']}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
