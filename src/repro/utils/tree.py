"""Pytree arithmetic helpers used throughout the optimizer stack.

All helpers are jit-safe (pure jnp) and operate leaf-wise on arbitrary
pytrees of arrays — the SVRG/AsySVRG core treats parameters, gradients and
control variates uniformly as trees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leaf-wise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a, b):
    """Global inner product <a, b> across all leaves.

    Uses sum(a*b) rather than vdot: vdot RESHAPES to 1-D, and flattening a
    2D-sharded tensor forces XLA to all-gather it (observed +24 GiB/device
    in the grad-clip of the 104B configs — EXPERIMENTS.md §Perf)."""
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.zeros((), jnp.float32))


def tree_l2norm(a):
    return jnp.sqrt(tree_dot(a, a))


def global_norm(tree):
    return tree_l2norm(tree)


def tree_size(tree) -> int:
    """Total number of elements (python int; works on ShapeDtypeStructs)."""
    return sum(int(jnp.prod(jnp.array(x.shape))) if x.shape else 1
               for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    total = 0
    for x in jax.tree.leaves(tree):
        n = 1
        for d in x.shape:
            n *= int(d)
        total += n * jnp.dtype(x.dtype).itemsize
    return total


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)
