"""Sweep-service throughput: cold vs warm runner cache, coalesced vs
sequential request dispatch.

Two measurements quantify what `repro.service` buys a grid-serving
deployment (the regime the paper's "compute cost per effective pass"
framing targets — repeated/concurrent grids, not one grid):

  * COLD vs WARM — the same `run_sweep` twice from an empty runner cache.
    The first call compiles its group runners; the second fetches them from
    the persistent cache and compiles NOTHING, so the warm/cold latency
    ratio isolates the XLA compilation tax a cache-less service pays on
    EVERY call. Reported as ``warm_cold_ratio`` (acceptance criterion) with
    the compile counters for both calls.
  * COALESCED vs SEQUENTIAL — K logical clients each holding a compatible
    slice of a grid. Sequential serving runs K warm `run_sweep` calls (K
    separate small-batch dispatches); the service admits all K requests and
    flushes ONCE, merging their rows into shared compiled groups (one big
    vmap batch per group, padding only the device-count remainder under
    ``--sharded``). Per-request results are bit-identical either way — the
    suite pins that; this benchmark records the throughput ratio.

Writes ``BENCH_service_throughput.json``. ``--quick`` shrinks the grid for
the CI smoke; ``--sharded`` runs every dispatch over the host's devices
(`make_sweep_mesh`), the CI `tier1-multidevice` smoke.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.artifacts import write_bench_json
from repro.core import LogisticRegression, SweepSpec, run_sweep
from repro.data.libsvm import make_synthetic_libsvm
from repro.launch.mesh import make_sweep_mesh
from repro.service import SweepService, cache_stats, clear_cache

N_CLIENTS = 6


def _client_specs(client: int, seeds, steps) -> list:
    """One client's compatible slice: same static dims, its own seeds."""
    return [SweepSpec(scheme=("consistent", "inconsistent", "unlock")[c % 3],
                      step_size=step, tau=3, num_threads=4, inner_steps=25,
                      seed=1000 * client + s)
            for c, (s, step) in enumerate((s, st) for s in seeds
                                          for st in steps)]


def run(quick: bool = False, sharded: bool = False):
    ds = make_synthetic_libsvm("real-sim", seed=11,
                               scale=0.002 if quick else 0.01)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=1e-3)
    epochs = 2 if quick else 4
    seeds = range(2) if quick else range(4)
    steps = (0.5,) if quick else (0.25, 0.5)
    clients = [_client_specs(k, seeds, steps) for k in range(N_CLIENTS)]
    mesh = make_sweep_mesh() if sharded and jax.device_count() > 1 else None

    # ---- cold vs warm: the recompilation tax the cache removes
    clear_cache()
    t0 = time.perf_counter()
    first = run_sweep(obj, epochs, clients[0], mesh=mesh)
    cold_s = time.perf_counter() - t0
    cold = cache_stats()
    t0 = time.perf_counter()
    second = run_sweep(obj, epochs, clients[0], mesh=mesh)
    warm_s = time.perf_counter() - t0
    warm = cache_stats().since(cold)
    np.testing.assert_array_equal(first.histories, second.histories)
    if warm.compiles:
        raise AssertionError(
            f"warm sweep recompiled ({warm.compiles} traces) — runner "
            "cache regression")

    # ---- sequential: K warm per-client dispatches (cache already warm for
    # this shape from the cold/warm phase, so this isolates dispatch cost)
    t0 = time.perf_counter()
    seq_results = [run_sweep(obj, epochs, specs, mesh=mesh)
                   for specs in clients]
    sequential_s = time.perf_counter() - t0

    # ---- coalesced: one flush serves all K clients from shared groups.
    # One warm-up flush first so BOTH paths measure steady-state serving
    # (the sequential loop above reused the cold/warm phase's compilation)
    svc = SweepService(obj, epochs=epochs, mesh=mesh)
    for specs in clients:
        svc.submit(specs)
    svc.flush()
    rids = [svc.submit(specs) for specs in clients]
    t0 = time.perf_counter()
    svc.flush()
    coalesced_s = time.perf_counter() - t0
    for rid, seq in zip(rids, seq_results):
        np.testing.assert_array_equal(svc.result(rid).histories,
                                      seq.histories)
    stats = svc.stats()

    rows = sum(len(s) for s in clients)
    return {
        "dataset": "real-sim", "epochs": epochs,
        "clients": N_CLIENTS, "rows_per_client": len(clients[0]),
        "devices": jax.device_count() if mesh is not None else 1,
        "cold_s": cold_s, "warm_s": warm_s,
        "warm_cold_ratio": warm_s / cold_s,
        "cold_compiles": cold.compiles, "warm_compiles": warm.compiles,
        "sequential_s": sequential_s, "coalesced_s": coalesced_s,
        "coalesced_speedup": sequential_s / coalesced_s,
        "sequential_rows_per_s": rows / sequential_s,
        "coalesced_rows_per_s": rows / coalesced_s,
        "rows_coalesced": stats.rows_coalesced,
        "groups_merged": stats.groups_merged,
        "groups_dispatched": stats.groups_dispatched,
        "cache_hit_rate": stats.cache_hit_rate,
        "service_compiles": stats.compiles,
    }


def main(quick: bool = True, sharded: bool = False):
    out = run(quick=quick, sharded=sharded)
    write_bench_json("service_throughput", out)
    print("name,us_per_call,derived")
    print(f"service_cold_sweep,{out['cold_s'] * 1e6:.1f},"
          f"compiles={out['cold_compiles']}")
    print(f"service_warm_sweep,{out['warm_s'] * 1e6:.1f},"
          f"warm_cold_ratio={out['warm_cold_ratio']:.3f};compiles=0")
    print(f"service_sequential_{out['clients']}req,"
          f"{out['sequential_s'] * 1e6:.1f},"
          f"rows_per_s={out['sequential_rows_per_s']:.1f}")
    print(f"service_coalesced_{out['clients']}req,"
          f"{out['coalesced_s'] * 1e6:.1f},"
          f"rows_per_s={out['coalesced_rows_per_s']:.1f};"
          f"speedup={out['coalesced_speedup']:.2f};"
          f"rows_coalesced={out['rows_coalesced']};"
          f"devices={out['devices']}")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv, sharded="--sharded" in sys.argv)
