"""Sequential SVRG (Johnson & Zhang 2013) — the τ=0 oracle.

The paper states: "If τ=0, the algorithm AsySVRG degenerates to the
sequential (single-thread) version of SVRG." This module IS that degenerate
case, used (a) as the single-thread baseline for the speedup metric and
(b) as the bit-exact oracle the delay engine must match at τ=0
(tested in tests/test_asysvrg_schemes.py).

For grid runs, serial SVRG is routed through the SAME compiled path as the
delay engine: `repro.core.sweep` maps ``SweepSpec(algo="svrg")`` onto
`asysvrg._epoch_core` with τ=0 / zero delays / consistent reads (specs are
normalized so the result reports exactly that), and SVRG rows share the
vmapped jit with AsySVRG rows of equal (M̃, option, buf_len) — buf_len is
pinned per row from (τ, num_threads), so give the svrg row the grid's
thread count to co-batch it, or leave ``num_threads=1`` for a lean
buf_len-1 group of its own. `sweep_spec` below builds the spec from
`run_svrg`'s arguments.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.objective import Objective
from repro.utils.tree import tree_zeros_like


def sweep_spec(step_size: float, num_inner: Optional[int] = None,
               option: int = 2, seed: int = 0):
    """`run_svrg(obj, E, step_size, num_inner, option, seed)` as a sweep row.

    The returned ``SweepSpec(algo="svrg")`` runs on the zero-delay degenerate
    path of the AsySVRG engine (`repro.core.sweep`); `num_inner=None` keeps
    the 2n default, resolved against the objective at `run_sweep` time.
    """
    from repro.core.sweep import SweepSpec   # deferred: keep core import-light
    return SweepSpec(algo="svrg", step_size=step_size,
                     inner_steps=num_inner or 0, option=option, seed=seed,
                     num_threads=1, scheme="consistent", tau=0)


class SVRGEpochStats(NamedTuple):
    w: jnp.ndarray
    obj: jnp.ndarray
    effective_passes: jnp.ndarray


def svrg_epoch(obj: Objective, w, key, step_size: float,
               num_inner: int, option: int = 2):
    """One outer iteration of Algorithm 1 with p=1.

    u_0 = w; full gradient μ = ∇f(w); num_inner inner updates
    v_m = ∇f_{i_m}(u_m) − ∇f_{i_m}(u_0) + μ ;  u_{m+1} = u_m − η v_m.
    Option 1 returns the last iterate, option 2 the average (the paper's
    analysis uses option 2).

    ``w`` is the objective's param PYTREE (any single array is its own
    tree, so flat-vector objectives see the exact pre-protocol graphs —
    `jax.tree.map` over a bare array IS the plain op); the update/average
    arithmetic is leaf-wise, so MLP-style nested params run unchanged.
    """
    mu = obj.full_grad(w)
    u0 = w
    idx = jax.random.randint(key, (num_inner,), 0, obj.n)

    def body(carry, i):
        u, acc = carry
        gu = obj.sample_grad(u, i)
        g0 = obj.sample_grad(u0, i)
        u_next = jax.tree.map(
            lambda ul, gul, g0l, mul: ul - step_size * (gul - g0l + mul),
            u, gu, g0, mu)
        return (u_next, jax.tree.map(jnp.add, acc, u)), None

    (u_last, acc), _ = jax.lax.scan(body, (u0, tree_zeros_like(u0)), idx)
    if option == 1:
        return u_last
    return jax.tree.map(lambda a: a / num_inner, acc)


def run_svrg(obj: Objective, epochs: int, step_size: float,
             num_inner: Optional[int] = None, option: int = 2,
             seed: int = 0, w0=None):
    """Run SVRG for `epochs` outer iterations; returns (w, per-epoch loss).

    ``w``/``w0`` live in the objective's pytree param space (a bare (p,)
    vector for the flat objectives)."""
    num_inner = num_inner or 2 * obj.n
    w = obj.init_params() if w0 is None else w0
    key = jax.random.PRNGKey(seed)
    history = [float(obj.loss(w))]
    epoch_fn = jax.jit(
        lambda w, k: svrg_epoch(obj, w, k, step_size, num_inner, option))
    for _ in range(epochs):
        key, sub = jax.random.split(key)
        w = epoch_fn(w, sub)
        history.append(float(obj.loss(w)))
    return w, history
