"""Algorithmic invariants of the SVRG core (paper Algorithm 1 + Lemmas)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SVRGConfig
from repro.core import LogisticRegression, run_asysvrg
from repro.core.asysvrg import asysvrg_epoch, parallel_full_grad
from repro.core.svrg import svrg_epoch
from repro.data.libsvm import make_synthetic_libsvm


@pytest.fixture(scope="module")
def obj():
    ds = make_synthetic_libsvm("real-sim", seed=1, scale=0.01)
    return LogisticRegression(ds.X, ds.y, l2_reg=1e-3)


def test_partitioned_full_grad_exact(obj):
    """The paper's φ_a partition: Σ_a φ_a == n·∇f (thread partition exact)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (obj.p,)) * 0.3
    g = obj.full_grad(w)
    for p_threads in (1, 3, 8):
        gp = parallel_full_grad(obj, w, p_threads)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gp), atol=1e-6)


def test_control_variate_unbiased(obj):
    """E_i[v] = ∇f(u) — the SVRG estimator is unbiased (Eq. 2)."""
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (obj.p,)) * 0.1
    u0 = jnp.zeros(obj.p)
    mu = obj.full_grad(u0)
    vs = jnp.stack([obj.sample_grad(w, i) - obj.sample_grad(u0, i) + mu
                    for i in range(obj.n)])
    np.testing.assert_allclose(np.asarray(vs.mean(0)),
                               np.asarray(obj.full_grad(w)), atol=1e-5)


def test_variance_vanishes_at_snapshot_optimum(obj):
    """Var[v] -> 0 as u -> u_0 (the variance-reduction property that gives
    the linear rate; plain SGD keeps nonzero variance)."""
    key = jax.random.PRNGKey(2)
    u0 = jax.random.normal(key, (obj.p,)) * 0.1
    mu = obj.full_grad(u0)

    def var_at(u):
        vs = jnp.stack([obj.sample_grad(u, i) - obj.sample_grad(u0, i) + mu
                        for i in range(0, obj.n, 7)])
        return float(jnp.mean(jnp.sum((vs - vs.mean(0)) ** 2, -1)))

    v_far = var_at(u0 + 0.5)
    v_near = var_at(u0 + 0.01)
    v_at = var_at(u0)
    assert v_at < 1e-10
    assert v_near < v_far


def test_tau_zero_matches_sequential_svrg(obj):
    """τ=0 ⇒ AsySVRG degenerates to sequential SVRG (paper §3), bit-exact."""
    w = jnp.zeros(obj.p)
    key = jax.random.PRNGKey(3)
    cfg = SVRGConfig(scheme="consistent", step_size=1.0, num_threads=1,
                     tau=0, inner_steps=200, option=2)
    w_asy = asysvrg_epoch(obj, w, key, cfg)

    # reference: same RNG consumption pattern as the engine
    k_idx, k_delay, k_scan = jax.random.split(key, 3)
    idx = jax.random.randint(k_idx, (200,), 0, obj.n)
    mu = obj.full_grad(w)
    u, acc = w, jnp.zeros_like(w)
    for i in np.asarray(idx):
        v = obj.sample_grad(u, i) - obj.sample_grad(w, i) + mu
        u = u - 1.0 * v
        acc = acc + u
    np.testing.assert_allclose(np.asarray(w_asy), np.asarray(acc / 200),
                               rtol=1e-5, atol=1e-6)


def test_option1_vs_option2(obj):
    """Option 1 (last iterate) and option 2 (average) both converge; the
    engine honors the switch."""
    f0 = float(obj.loss(jnp.zeros(obj.p)))
    for option in (1, 2):
        cfg = SVRGConfig(scheme="consistent", step_size=1.0, num_threads=4,
                         tau=3, option=option)
        res = run_asysvrg(obj, epochs=2, cfg=cfg, seed=4)
        assert res.history[-1] < f0


def test_svrg_epoch_reduces_objective(obj):
    w = jnp.zeros(obj.p)
    w1 = svrg_epoch(obj, w, jax.random.PRNGKey(5), step_size=1.0,
                    num_inner=2 * obj.n)
    assert float(obj.loss(w1)) < float(obj.loss(w))


def test_smoothness_bound_valid(obj):
    """L from smoothness() upper-bounds observed gradient Lipschitz ratios
    (Assumption 1)."""
    L = obj.smoothness()
    key = jax.random.PRNGKey(6)
    for _ in range(5):
        key, k1, k2 = jax.random.split(key, 3)
        a = jax.random.normal(k1, (obj.p,)) * 0.3
        b = jax.random.normal(k2, (obj.p,)) * 0.3
        num = float(jnp.linalg.norm(obj.full_grad(a) - obj.full_grad(b)))
        den = float(jnp.linalg.norm(a - b))
        assert num <= L * den * (1 + 1e-4)
