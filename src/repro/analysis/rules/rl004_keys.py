"""RL004 — key-completeness for the sweep grouping / runner cache.

The bug class this exists for is PR 3's ``buf_len`` incident: a new
static was added to ``_Resolved`` but not to the group key, so two specs
differing only in ``buf_len`` were batched into ONE compiled program and
the second silently ran with the first's buffer bound. The same hazard
exists one layer down in ``service/cache.py``: a ``get_group_runner``
parameter that never reaches ``runner_key`` lets two different programs
alias one cache slot.

The checker is structural, anchored on the shapes that actually exist in
``repro/core/sweep.py`` and ``repro/service/cache.py``:

  1. Every field of the ``_Resolved`` NamedTuple must either appear as an
     ``r.<field>`` element of the group-key tuple built via
     ``groups.setdefault((...), ...)`` in ``plan_sweep``, or be packed
     into the per-row runtime arrays in ``_dispatch_group``
     (``resolved[c].<field>`` / ``specs[c].<field>``). A field that is
     neither keyed nor row-data can silently alias groups — exactly the
     buf_len failure. Fields that are genuinely derived/accounting-only
     are suppressed AT THE FIELD DECLARATION with a reason.

  2. Every parameter of ``get_group_runner`` must be forwarded into its
     ``runner_key(...)`` call, and every parameter of ``runner_key`` must
     be read somewhere in its body (an accepted-but-ignored key parameter
     is the cache-aliasing bug waiting to happen).

It activates by CONTENT, not path: any scanned file defining both
``class _Resolved`` and ``plan_sweep`` gets check 1 (so fixture trees in
tests exercise it); the cache file is found among the scanned set by it
defining both ``runner_key`` and ``get_group_runner``, falling back to
the on-disk sibling ``../service/cache.py`` of the sweep file when the
lint run was scoped to core/ only.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence, Set

from repro.analysis.astutil import FUNC_NODES, param_names
from repro.analysis.diagnostics import Diagnostic
from repro.analysis.files import SourceFile, load_file


def _find_class(tree: ast.AST, name: str) -> Optional[ast.ClassDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _find_func(tree: ast.AST, name: str):
    for node in ast.walk(tree):
        if isinstance(node, FUNC_NODES) and node.name == name:
            return node
    return None


def _resolved_fields(cls: ast.ClassDef) -> List[ast.AnnAssign]:
    return [stmt for stmt in cls.body
            if isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)]


def _group_key_attrs(plan: ast.AST) -> Set[str]:
    """Attribute names used in the tuple handed to groups.setdefault()."""
    attrs: Set[str] = set()
    for node in ast.walk(plan):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "setdefault" and node.args
                and isinstance(node.args[0], ast.Tuple)):
            for el in node.args[0].elts:
                if isinstance(el, ast.Attribute):
                    attrs.add(el.attr)
    return attrs


def _packed_attrs(dispatch: ast.AST) -> Set[str]:
    """Fields read off subscripted rows (resolved[c].tau, specs[c].seed) —
    the per-row runtime arrays."""
    attrs: Set[str] = set()
    for node in ast.walk(dispatch):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Subscript)):
            attrs.add(node.attr)
    return attrs


def _names_read(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _check_sweep(sf: SourceFile, out: List[Diagnostic]) -> None:
    cls = _find_class(sf.tree, "_Resolved")
    plan = _find_func(sf.tree, "plan_sweep")
    if cls is None or plan is None:
        return
    keyed = _group_key_attrs(plan)
    if not keyed:
        out.append(Diagnostic(
            sf.path, plan.lineno, "RL004",
            "plan_sweep builds no groups.setdefault((...)) key tuple — "
            "the group-key anchor RL004 checks against is gone; restore "
            "it or update the checker"))
        return
    dispatch = _find_func(sf.tree, "_dispatch_group")
    packed = _packed_attrs(dispatch) if dispatch is not None else set()
    for field in _resolved_fields(cls):
        name = field.target.id
        if name not in keyed and name not in packed:
            out.append(Diagnostic(
                sf.path, field.lineno, "RL004",
                f"_Resolved.{name} reaches neither the plan_sweep group "
                "key nor _dispatch_group's per-row runtime arrays — specs "
                f"differing only in {name!r} would alias one compiled "
                "program (the PR-3 buf_len bug); key it, pack it, or "
                "suppress here with the derivation argument"))


def _check_cache(sf: SourceFile, out: List[Diagnostic]) -> None:
    key_fn = _find_func(sf.tree, "runner_key")
    getter = _find_func(sf.tree, "get_group_runner")
    if key_fn is None or getter is None:
        return
    # runner_key: every accepted parameter must be read in the body
    read = set()
    for stmt in key_fn.body:
        read |= _names_read(stmt)
    for name in param_names(key_fn):
        if name not in read:
            out.append(Diagnostic(
                sf.path, key_fn.lineno, "RL004",
                f"runner_key accepts {name!r} but never reads it — the "
                "parameter does not reach the cache key, so programs "
                f"differing in {name!r} alias one runner"))
    # get_group_runner: every parameter forwarded into runner_key(...)
    call = None
    for node in ast.walk(getter):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "runner_key"):
            call = node
            break
    if call is None:
        out.append(Diagnostic(
            sf.path, getter.lineno, "RL004",
            "get_group_runner never calls runner_key — the runner lookup "
            "is not keyed"))
        return
    forwarded: Set[str] = set()
    for arg in call.args:
        forwarded |= _names_read(arg)
    for kw in call.keywords:
        forwarded |= _names_read(kw.value)
    for name in param_names(getter):
        if name not in forwarded:
            out.append(Diagnostic(
                sf.path, call.lineno, "RL004",
                f"get_group_runner parameter {name!r} is not forwarded "
                "into runner_key(...) — two calls differing only in "
                f"{name!r} would fetch the same cached runner"))


def _is_sweep_file(sf: SourceFile) -> bool:
    return (_find_class(sf.tree, "_Resolved") is not None
            and _find_func(sf.tree, "plan_sweep") is not None)


def _is_cache_file(sf: SourceFile) -> bool:
    return (_find_func(sf.tree, "runner_key") is not None
            and _find_func(sf.tree, "get_group_runner") is not None)


def check_project(files: Sequence[SourceFile]) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    cache_seen = False
    sweep_file: Optional[SourceFile] = None
    for sf in files:
        if _is_sweep_file(sf):
            sweep_file = sf
            _check_sweep(sf, out)
        if _is_cache_file(sf):
            cache_seen = True
            _check_cache(sf, out)
    if not cache_seen and sweep_file is not None:
        # lint run scoped to core/ — pull the sibling cache module from disk
        sibling = (Path(sweep_file.path).resolve().parent.parent
                   / "service" / "cache.py")
        if sibling.is_file():
            sf = load_file(sibling)
            if sf is not None and _is_cache_file(sf):
                _check_cache(sf, out)
    return out
