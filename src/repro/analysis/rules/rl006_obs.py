"""RL006 — observability brackets compiled programs, never enters them.

The obs contract (repro.obs): tracing spans wrap runner *calls*, metrics
observe on the host after dispatch, and telemetry is recomputed from
already-returned arrays. A timing or tracing call INSIDE a jitted scope
is broken either way it lands: as a traced no-op it silently measures
nothing (host Python runs once, at trace time, so the "span" would time
the trace, not the execution), and anything that does escape to the host
(callbacks) perturbs the compiled program the cache key cannot see —
which is exactly how "telemetry changed my bits" bugs are born.

Flagged inside any function named ``*_core`` (the house convention for
jit-traced numeric bodies, nested functions included) and anywhere in a
``kernels/**/kernel.py`` module:

  * wall-clock reads: ``time.monotonic`` / ``perf_counter`` / ``time`` /
    ``process_time`` / ``thread_time`` (+ ``_ns`` variants);
  * the tracer API: ``tracer()``, ``enable_tracing``, ``disable_tracing``
    and any ``.span`` / ``.span_all`` / ``.span_active`` / ``.annotate``
    / ``.new_trace`` / ``.record_error`` method call;
  * histogram recording: any ``.observe(...)`` call;
  * the live-progress bus: ``progress_bus`` / ``ProgressBus`` /
    ``enable_progress`` / ``disable_progress`` and ``.publish`` /
    ``.watch`` method calls;
  * the divergence watchdog: ``Watchdog`` / ``enforce_group`` /
    ``first_bad_epoch`` (host-side numpy inspection by contract);
  * the performance ledger: ``ledger`` / ``enable_ledger`` /
    ``disable_ledger`` / ``note_compile`` and ``.record_dispatch``
    method calls;
  * any reference into ``repro.obs`` (aliased module access included).

Fix: move the measurement to the call site that dispatches the jitted
function (see `repro.core.sweep._dispatch_group` for the pattern), or
recompute the quantity outside jit like `repro.obs.telemetry` does.
"""
from __future__ import annotations

import ast
from pathlib import PurePath
from typing import List

from repro.analysis.astutil import FUNC_NODES, call_name, dotted_name
from repro.analysis.diagnostics import Diagnostic

_TIMING_CALLS = {
    f"time.{fn}{suffix}"
    for fn in ("monotonic", "perf_counter", "time", "process_time",
               "thread_time")
    for suffix in ("", "_ns")
}
_TRACER_CALLS = {"tracer", "enable_tracing", "disable_tracing"}
# live-obs entry points (PR 10): progress bus, watchdog, perf ledger —
# all host-side by contract, so any call inside a jitted scope is a bug
_PROGRESS_CALLS = {"progress_bus", "ProgressBus", "enable_progress",
                   "disable_progress"}
_WATCHDOG_CALLS = {"Watchdog", "enforce_group", "first_bad_epoch"}
_LEDGER_CALLS = {"ledger", "enable_ledger", "disable_ledger",
                 "note_compile"}
_OBS_METHODS = {"span", "span_all", "span_active", "annotate", "new_trace",
                "record_error", "observe", "publish", "watch",
                "record_dispatch"}


def _kernel_module(path: str) -> bool:
    p = PurePath(path)
    return p.name == "kernel.py" and "kernels" in p.parts


def _why(node: ast.Call) -> str:
    """Non-empty reason when this call is an obs/timing escape."""
    name = call_name(node) or ""
    if name in _TIMING_CALLS:
        return f"wall-clock read `{name}(...)`"
    last = name.rsplit(".", 1)[-1]
    if last in _TRACER_CALLS:
        return f"tracer API call `{name}(...)`"
    if last in _PROGRESS_CALLS:
        return f"progress-bus call `{name}(...)`"
    if last in _WATCHDOG_CALLS:
        return f"watchdog call `{name}(...)`"
    if last in _LEDGER_CALLS:
        return f"ledger call `{name}(...)`"
    if "." in name and last in _OBS_METHODS:
        return f"obs recording call `{name}(...)`"
    return ""


def _scan(path: str, scope: ast.AST, where: str,
          out: List[Diagnostic], seen: set) -> None:
    for node in ast.walk(scope):
        why = ""
        if isinstance(node, ast.Call):
            why = _why(node)
        elif isinstance(node, ast.Attribute):
            name = dotted_name(node) or ""
            if name.startswith("repro.obs") or name.startswith("obs."):
                why = f"reference into repro.obs (`{name}`)"
        if why and (node.lineno, why) not in seen:
            seen.add((node.lineno, why))
            out.append(Diagnostic(
                path, node.lineno, "RL006",
                f"{why} inside {where} — observability must bracket the "
                "compiled program, not run inside it (time/record at the "
                "dispatch site, or recompute outside jit like "
                "repro.obs.telemetry)"))


def check(path: str, tree: ast.AST, source: str) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    seen: set = set()
    if _kernel_module(path):
        _scan(path, tree, "a Pallas kernel module", out, seen)
    for node in ast.walk(tree):
        if isinstance(node, FUNC_NODES) and node.name.endswith("_core"):
            _scan(path, node, f"jitted scope `{node.name}`", out, seen)
    return out
