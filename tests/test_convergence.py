"""Theorem-facing convergence-rate checks (Thms 1–2 qualitative content)."""
import numpy as np
import pytest

# multi-epoch rate fits over full datasets: minutes of scan time — excluded
# from the default CI job (see .github/workflows/ci.yml)
pytestmark = pytest.mark.slow

from repro.config import SVRGConfig
from repro.core import LogisticRegression, run_asysvrg, run_hogwild, run_svrg
from repro.data.libsvm import make_synthetic_libsvm


@pytest.fixture(scope="module")
def problem():
    ds = make_synthetic_libsvm("rcv1", seed=7, scale=0.02)
    obj = LogisticRegression(ds.X, ds.y, l2_reg=3e-3)
    _, f_star = obj.optimum(max_iter=4000)
    return obj, f_star


def _rate(history, f_star):
    """Geometric fit: mean log-ratio of consecutive gaps (negative=linear)."""
    g = np.maximum(np.asarray(history) - f_star, 1e-14)
    return float(np.mean(np.log(g[1:] / g[:-1])))


def test_asysvrg_rate_is_linear_hogwild_is_not(problem):
    """AsySVRG: per-epoch gap ratio stays bounded < 1 (linear/geometric).
    Hogwild! with decaying steps stalls — its late-epoch ratios drift to 1
    (sub-linear)."""
    obj, f_star = problem
    cfg = SVRGConfig(scheme="inconsistent", step_size=2.0, num_threads=8,
                     tau=7)
    svrg = run_asysvrg(obj, epochs=10, cfg=cfg, seed=0)
    hog = run_hogwild(obj, epochs=30, step_size=2.0, num_threads=8, seed=0)

    g_svrg = np.maximum(np.asarray(svrg.history) - f_star, 1e-14)
    g_hog = np.maximum(np.asarray(hog.history) - f_star, 1e-14)
    # contraction ratios while ABOVE the numerical floor (SVRG may hit the
    # 1e-14 floor within a few epochs — that IS linear convergence)
    live = g_svrg[:-1] > 1e-10
    r_svrg = np.median((g_svrg[1:] / g_svrg[:-1])[live])
    r_hog = np.median(g_hog[20:] / g_hog[19:-1])
    assert r_svrg < 0.7, r_svrg           # geometric contraction
    assert r_hog > r_svrg                 # hogwild contracts slower/stalls


def test_smaller_step_converges_slower_but_safely(problem):
    obj, f_star = problem
    rates = {}
    for eta in (0.5, 2.0):
        cfg = SVRGConfig(scheme="consistent", step_size=eta, num_threads=4,
                         tau=3)
        res = run_asysvrg(obj, epochs=5, cfg=cfg, seed=1)
        rates[eta] = _rate(res.history, f_star)
        assert res.history[-1] <= res.history[0]
    assert rates[2.0] < rates[0.5]        # larger stable step → faster rate


def test_sequential_svrg_baseline_rate(problem):
    """The p=1 baseline used for the speedup denominator converges
    linearly too (sanity for benchmarks/fig1_speedup)."""
    obj, f_star = problem
    _, hist = run_svrg(obj, epochs=6, step_size=2.0)
    assert _rate(hist, f_star) < -0.3
